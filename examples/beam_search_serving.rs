//! **End-to-end driver** (EXPERIMENTS.md §E2E): the full three-layer
//! stack on a real serving workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example beam_search_serving
//! ```
//!
//! What it does:
//! 1. starts the coordinator over the AOT artifacts (PJRT CPU engines,
//!    device-resident projection weights, dynamic batcher),
//! 2. starts the TCP server and drives it with concurrent clients
//!    running **beam-search decoding** over the synthetic LM — the
//!    workload §4 of the paper motivates (Softmax + TopK per step),
//! 3. repeats the same load in `safe` and `online` serving modes and
//!    reports throughput + latency percentiles for both,
//! 4. verifies the two modes produce *identical* token sequences
//!    (Algorithm 4 is exact, not an approximation).

use std::sync::Arc;
use std::time::{Duration, Instant};

use onlinesoftmax::config::{ServeConfig, ServingMode};
use onlinesoftmax::coordinator::Coordinator;
use onlinesoftmax::server::{client::Client, Server};

const BEAMS: usize = 8; // concurrent beam-search clients
const WIDTH: usize = 4; // beam width
const STEPS: usize = 24; // decode steps per beam
const K: usize = 5; // paper's K

fn run_mode(mode: ServingMode) -> (Vec<Vec<i32>>, f64, Vec<Duration>) {
    let mut cfg = ServeConfig::default();
    cfg.artifacts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.mode = mode;
    cfg.addr = "127.0.0.1:0".into();
    cfg.max_batch = 16;
    cfg.max_wait = Duration::from_micros(800);
    cfg.workers = 2;

    let coordinator = Arc::new(Coordinator::start(&cfg).expect("coordinator"));
    let server = Server::bind(&cfg.addr, coordinator, BEAMS + 2).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let server_thread = std::thread::spawn(move || {
        let _ = server.serve();
    });

    let t0 = Instant::now();
    // Each client runs an independent beam search over the wire.
    let outcomes: Vec<(Vec<i32>, Vec<Duration>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..BEAMS)
            .map(|b| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let mut lats = Vec::with_capacity(WIDTH * STEPS);
                    // beam state: (session, tokens, logprob)
                    let sid = client.open_session().expect("session");
                    let mut beam: Vec<(u64, Vec<i32>, f64)> =
                        vec![(sid, vec![(b as i32) * 31 % 8192], 0.0)];
                    for _ in 0..STEPS {
                        let mut candidates: Vec<(usize, f64, i32)> = Vec::new();
                        for (h, (sid, tokens, lp)) in beam.iter().enumerate() {
                            let t = Instant::now();
                            let (vals, idx) = client
                                .lm_step(*sid, *tokens.last().unwrap(), Some(K))
                                .expect("lm_step");
                            lats.push(t.elapsed());
                            for (v, i) in vals.iter().zip(&idx) {
                                candidates.push((h, lp + (*v as f64).max(1e-30).ln(), *i as i32));
                            }
                        }
                        candidates.sort_by(|a, b| {
                            b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)).then(a.2.cmp(&b.2))
                        });
                        candidates.truncate(WIDTH);
                        let mut next = Vec::with_capacity(WIDTH);
                        for &(parent, lp, tok) in &candidates {
                            // fork the parent's post-step state server-side
                            // (no replay): O(1) per expansion.
                            let (psid, ptokens, _) = &beam[parent];
                            let sid = client.fork_session(*psid).expect("fork");
                            let mut tokens = ptokens.clone();
                            tokens.push(tok);
                            next.push((sid, tokens, lp));
                        }
                        for (sid, _, _) in &beam {
                            client.close_session(*sid).ok();
                        }
                        beam = next;
                    }
                    let best = beam
                        .iter()
                        .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
                        .unwrap()
                        .1
                        .clone();
                    for (sid, _, _) in &beam {
                        client.close_session(*sid).ok();
                    }
                    (best, lats)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = server_thread.join();

    let sequences: Vec<Vec<i32>> = outcomes.iter().map(|(s, _)| s.clone()).collect();
    let mut lats: Vec<Duration> = outcomes.into_iter().flat_map(|(_, l)| l).collect();
    lats.sort();
    (sequences, wall, lats)
}

fn main() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        println!("backend: AOT artifacts (PJRT engines)");
    } else {
        println!("backend: host kernels (build `make artifacts` for the PJRT path)");
    }
    println!(
        "end-to-end beam-search serving: {BEAMS} clients × width {WIDTH} × {STEPS} steps, K={K}"
    );

    let mut report = Vec::new();
    let mut all_sequences = Vec::new();
    for mode in [ServingMode::Safe, ServingMode::Online] {
        println!("\n--- mode: {} ---", mode.as_str());
        let (sequences, wall, lats) = run_mode(mode);
        let steps_total = lats.len();
        let pick = |q: f64| lats[((q * (steps_total - 1) as f64) as usize).min(steps_total - 1)];
        println!(
            "wall {:.2}s → {:.0} decode-steps/s; lm_step latency p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
            wall,
            steps_total as f64 / wall,
            pick(0.5).as_secs_f64() * 1e3,
            pick(0.95).as_secs_f64() * 1e3,
            pick(0.99).as_secs_f64() * 1e3,
        );
        println!("best sequence (client 0): {:?}", &sequences[0]);
        report.push((mode, wall, steps_total as f64 / wall));
        all_sequences.push(sequences);
    }

    assert_eq!(
        all_sequences[0], all_sequences[1],
        "safe and online modes must decode identical sequences (Alg 4 is exact)"
    );
    println!("\n✓ safe and online modes produced IDENTICAL beam-search outputs");
    println!(
        "throughput: safe {:.0} steps/s vs online {:.0} steps/s",
        report[0].2, report[1].2
    );
}
