//! Sharded-vocabulary serving: §3.1's parallel online normalizer as a
//! distributed-system feature.
//!
//! ```bash
//! make artifacts && cargo run --release --example sharded_vocab
//! ```
//!
//! The projection matrix is split across 4 vocabulary shards, each on
//! its own PJRT engine thread.  Every decode executes all shards in
//! parallel; each returns a partial `(m, d, topk)` and the coordinator
//! merges with the ⊕ operator (eq. 4) in rust.  The example verifies
//! shard-merge answers equal single-engine answers bit-for-bit in the
//! indices, and compares latency.

use std::time::{Duration, Instant};

use onlinesoftmax::config::{ServeConfig, ServingMode};
use onlinesoftmax::coordinator::{Coordinator, Payload, Reply};
use onlinesoftmax::rng::Xoshiro256pp;

const TIMEOUT: Duration = Duration::from_secs(60);
const REQUESTS: usize = 64;

fn run(shards: usize) -> (Vec<(Vec<f32>, Vec<i64>)>, Duration) {
    let mut cfg = ServeConfig::default();
    cfg.artifacts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.mode = ServingMode::Online;
    cfg.shards = shards;
    cfg.max_wait = Duration::from_micros(200);
    let coord = Coordinator::start(&cfg).expect("coordinator");

    let hidden_len = coord.executor().hidden();
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let inputs: Vec<Vec<f32>> = (0..REQUESTS).map(|_| rng.logits(hidden_len, 1.0)).collect();

    // warmup (compile + param upload)
    coord
        .call(Payload::DecodeTopK { hidden: inputs[0].clone(), k: Some(5) }, TIMEOUT)
        .expect("warmup");

    let t0 = Instant::now();
    let mut results = Vec::with_capacity(REQUESTS);
    for h in &inputs {
        match coord.call(Payload::DecodeTopK { hidden: h.clone(), k: Some(5) }, TIMEOUT) {
            Ok(Reply::TopK { vals, idx }) => results.push((vals, idx)),
            other => panic!("unexpected {other:?}"),
        }
    }
    let elapsed = t0.elapsed();
    coord.shutdown();
    (results, elapsed)
}

fn main() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("decode top-5 over {REQUESTS} requests, unsharded vs 4 vocabulary shards\n");
    let (r1, t1) = run(1);
    println!("unsharded:   {:?} total, {:.2}ms/request", t1, t1.as_secs_f64() * 1e3 / REQUESTS as f64);
    let (r4, t4) = run(4);
    println!("4 shards:    {:?} total, {:.2}ms/request", t4, t4.as_secs_f64() * 1e3 / REQUESTS as f64);

    // ⊕-merged shard results must equal the single-engine answers.
    let mut max_rel = 0f32;
    for (a, b) in r1.iter().zip(&r4) {
        assert_eq!(a.1, b.1, "top-k indices must match exactly");
        for (x, y) in a.0.iter().zip(&b.0) {
            max_rel = max_rel.max((x - y).abs() / x.abs().max(1e-9));
        }
    }
    println!("\n✓ indices identical across sharding; max value divergence {max_rel:.2e}");
    println!("  (the ⊕ merge is exact up to fp reassociation — §3.1)");
}
