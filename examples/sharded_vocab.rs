//! Sharded-vocabulary serving: §3.1's parallel online normalizer as a
//! distributed-system feature.
//!
//! ```bash
//! cargo run --release --example sharded_vocab            # host shard engine
//! make artifacts && cargo run --release --example sharded_vocab   # PJRT path
//! ```
//!
//! The projection matrix is split across vocabulary shards; every
//! decode executes all shards in parallel, each returning a partial
//! `(m, d, topk)`, and the coordinator merges with the ⊕ operator
//! (eq. 4) in rust.  With AOT artifacts built, the shards run on PJRT
//! engine threads; without them, the in-process shard-reduction engine
//! (`onlinesoftmax::shard`) runs the same per-shard fused scans on a
//! worker pool.  Either way the example verifies shard-merge answers
//! equal single-worker answers bit-for-bit in the indices, and compares
//! latency.

use std::time::{Duration, Instant};

use onlinesoftmax::config::{BackendKind, ServeConfig, ServingMode};
use onlinesoftmax::coordinator::{Coordinator, Payload, Reply, RequestOptions};
use onlinesoftmax::rng::Xoshiro256pp;

const TIMEOUT: Duration = Duration::from_secs(60);
const REQUESTS: usize = 64;

fn config(artifacts: bool, shards: usize) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.mode = ServingMode::Online;
    cfg.max_wait = Duration::from_micros(200);
    if artifacts {
        cfg.artifacts_dir =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        cfg.backend = BackendKind::Artifacts;
        cfg.shards = shards;
    } else {
        cfg.backend = BackendKind::Host;
        // A deliberately large host vocabulary so the sharded path has
        // real work per shard; threshold low enough that it engages.
        cfg.vocab = 262_144;
        cfg.hidden = 64;
        cfg.shard_threshold = 16_384;
        cfg.host_shards = shards; // 0 = one worker per core
        if shards == 1 {
            cfg.shard_threshold = usize::MAX; // force the serial kernel
        }
    }
    cfg
}

fn run(cfg: &ServeConfig) -> (Vec<(Vec<f32>, Vec<i64>)>, Duration) {
    let coord = Coordinator::start(cfg).expect("coordinator");

    let hidden_len = coord.executor().hidden();
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let inputs: Vec<Vec<f32>> = (0..REQUESTS).map(|_| rng.logits(hidden_len, 1.0)).collect();

    // warmup (compile + param upload on PJRT; pool spin-up on host)
    coord
        .call_opts(
            Payload::DecodeTopK { hidden: inputs[0].clone() },
            RequestOptions::with_k(5),
            TIMEOUT,
        )
        .expect("warmup");

    let t0 = Instant::now();
    let mut results = Vec::with_capacity(REQUESTS);
    for h in &inputs {
        match coord.call_opts(
            Payload::DecodeTopK { hidden: h.clone() },
            RequestOptions::with_k(5),
            TIMEOUT,
        ) {
            Ok(Reply::TopK { vals, idx }) => results.push((vals, idx)),
            other => panic!("unexpected {other:?}"),
        }
    }
    let elapsed = t0.elapsed();
    coord.shutdown();
    (results, elapsed)
}

fn main() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists();
    if artifacts {
        println!("decode top-5 over {REQUESTS} requests: PJRT engines, 1 vs 4 vocab shards\n");
    } else {
        println!(
            "decode top-5 over {REQUESTS} requests: host shard engine \
             (V=262144), serial vs sharded\n(build artifacts with `make artifacts` \
             to run the same comparison on PJRT engines)\n"
        );
    }

    let (r1, t1) = run(&config(artifacts, 1));
    println!(
        "serial:      {:?} total, {:.2}ms/request",
        t1,
        t1.as_secs_f64() * 1e3 / REQUESTS as f64
    );
    let (r4, t4) = run(&config(artifacts, if artifacts { 4 } else { 0 }));
    println!(
        "sharded:     {:?} total, {:.2}ms/request ({:.2}x)",
        t4,
        t4.as_secs_f64() * 1e3 / REQUESTS as f64,
        t1.as_secs_f64() / t4.as_secs_f64()
    );

    // ⊕-merged shard results must equal the single-worker answers.
    let mut max_rel = 0f32;
    for (a, b) in r1.iter().zip(&r4) {
        assert_eq!(a.1, b.1, "top-k indices must match exactly");
        for (x, y) in a.0.iter().zip(&b.0) {
            max_rel = max_rel.max((x - y).abs() / x.abs().max(1e-9));
        }
    }
    println!("\n✓ indices identical across sharding; max value divergence {max_rel:.2e}");
    println!("  (the ⊕ merge is exact up to fp reassociation — §3.1)");
}
