//! Quickstart: the core library API in two minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Covers the paper's four algorithms, the ⊕ monoid, and the analytic
//! access model — no artifacts or server needed.

use onlinesoftmax::analytic::{DeviceModel, Pipeline};
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::softmax::{self, fused, monoid::MD, Algorithm};

fn main() {
    // Random logits like the paper's benchmark inputs.
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    let logits = rng.logits(10_000, 6.0);

    // --- Algorithms 1-3: softmax three ways -----------------------------
    let y_naive = softmax::compute(&logits, Algorithm::Naive);
    let y_safe = softmax::compute(&logits, Algorithm::Safe);
    let y_online = softmax::compute(&logits, Algorithm::Online);
    println!("softmax sums (≈1): naive={:.6} safe={:.6} online={:.6}",
        y_naive.iter().sum::<f32>(),
        y_safe.iter().sum::<f32>(),
        y_online.iter().sum::<f32>());

    // Safety: naive (Algorithm 1 verbatim, scalar) dies on large
    // logits; online does not (paper §2-3).  The *vectorized* naive
    // saturates instead of overflowing — use the scalar form to see
    // the true failure mode.
    let hot: Vec<f32> = logits.iter().map(|x| x + 120.0).collect();
    let mut naive_hot = vec![0.0; hot.len()];
    softmax::scalar::naive(&hot, &mut naive_hot);
    let online_hot = softmax::compute(&hot, Algorithm::Online);
    println!(
        "after +120 shift: naive finite? {}  online finite? {}",
        naive_hot.iter().all(|v| v.is_finite()),
        online_hot.iter().all(|v| v.is_finite())
    );
    assert!(!naive_hot.iter().all(|v| v.is_finite()), "Alg 1 must overflow here");

    // --- §3.1: the ⊕ monoid — split anywhere, merge, same answer --------
    let (left, right) = logits.split_at(3000);
    let whole = softmax::vectorized::online_normalizer(&logits);
    let merged = softmax::vectorized::online_normalizer(left)
        .combine(softmax::vectorized::online_normalizer(right));
    println!("⊕ merge: whole=(m {:.4}, d {:.4})  merged=(m {:.4}, d {:.4})",
        whole.m, whole.d, merged.m, merged.d);
    assert_eq!(whole.m, merged.m);

    // --- Algorithm 4: fused online softmax + top-k ----------------------
    let (vals, idx) = fused::online_topk(&logits, 5);
    println!("top-5 next-token probabilities:");
    for (v, i) in vals.iter().zip(&idx) {
        println!("  token {i:>6}  p = {v:.5}");
    }

    // --- the paper's access arithmetic ----------------------------------
    let v100 = DeviceModel::v100();
    println!(
        "\nanalytic V100 speedups at V=25000, batch 4000:\n  online vs safe softmax: {:.2}x (paper ~1.3x)\n  fused Alg4 vs safe-unfused: {:.2}x (paper ~5x)",
        v100.speedup(Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax, 25_000, 4000),
        v100.speedup(Pipeline::SafeUnfusedTopK, Pipeline::OnlineFusedTopK, 25_000, 4000)
    );

    // MD is also usable directly for streaming normalization:
    let mut md = MD::IDENTITY;
    for &x in &logits[..100] {
        md = md.push(x);
    }
    println!("\nstreaming (m, d) after 100 elements: ({:.4}, {:.4})", md.m, md.d);

    // --- the shard-reduction engine: ⊕ across a worker pool -------------
    use onlinesoftmax::shard::{ShardEngine, ShardEngineConfig};
    let engine = ShardEngine::new(ShardEngineConfig { threshold: 4096, ..Default::default() });
    let (svals, sidx) = engine.fused_topk(&logits, 5);
    assert_eq!(sidx, idx, "sharded Algorithm 4 selects the same tokens");
    println!(
        "sharded fused top-5 on {} workers agrees with single-thread (max Δp = {:.2e})",
        engine.workers(),
        vals.iter().zip(&svals).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max)
    );
}
