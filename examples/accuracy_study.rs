//! Numerical-accuracy study — the paper's §2/§6 safety claims, measured.
//!
//! ```bash
//! cargo run --release --example accuracy_study
//! ```
//!
//! * Where does naive softmax (Algorithm 1) start returning NaN/Inf,
//!   and how do safe/online behave there?
//! * "If one is using Naive Softmax then switching to Online version
//!   improves numerical accuracy" (§6) — quantified against an f64
//!   reference.
//! * Error of the ⊕ tree reduction vs the sequential fold.

use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::softmax::{self, monoid, Algorithm};

/// f64 reference softmax.
fn softmax_f64(x: &[f32]) -> Vec<f64> {
    let m = x.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b as f64));
    let exps: Vec<f64> = x.iter().map(|&v| ((v as f64) - m).exp()).collect();
    let d: f64 = exps.iter().sum();
    exps.iter().map(|e| e / d).collect()
}

/// Max relative error over entries that carry probability mass
/// (want ≥ 1e-12): below that, fp32 storage itself cannot represent
/// the value and relative error is meaningless noise.
fn max_rel_error(y: &[f32], want: &[f64]) -> f64 {
    y.iter()
        .zip(want)
        .filter(|(_, &b)| b >= 1e-12)
        .map(|(&a, &b)| ((a as f64 - b) / b).abs())
        .fold(0.0, f64::max)
}

/// Total-variation distance — the distribution-level error.
fn tv_distance(y: &[f32], want: &[f64]) -> f64 {
    0.5 * y.iter().zip(want).map(|(&a, &b)| (a as f64 - b).abs()).sum::<f64>()
}

fn main() {
    let v = 4096;
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let base = rng.logits(v, 3.0);

    println!("=== overflow cliff: shift logits by +offset, check finiteness ===");
    println!("(scalar kernels — faithful to the paper's pseudocode)");
    println!("{:>8} {:>10} {:>10} {:>10}", "offset", "naive", "safe", "online");
    for offset in [0.0f32, 40.0, 80.0, 85.0, 90.0, 120.0, 300.0] {
        let x: Vec<f32> = base.iter().map(|v| v + offset).collect();
        let mut y = vec![0.0f32; x.len()];
        let mut finite = |f: &dyn Fn(&[f32], &mut [f32])| {
            f(&x, &mut y);
            if y.iter().all(|p| p.is_finite()) { "ok" } else { "NaN/Inf" }
        };
        println!(
            "{:>8} {:>10} {:>10} {:>10}",
            offset,
            finite(&softmax::scalar::naive),
            finite(&softmax::scalar::safe),
            finite(&softmax::scalar::online)
        );
    }

    println!("\n=== accuracy vs f64 reference ===");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "scale", "naive rel", "safe rel", "online rel", "naive tv", "safe tv", "online tv"
    );
    for scale in [0.5f32, 2.0, 8.0, 20.0] {
        let x = Xoshiro256pp::seed_from_u64(100).logits(v, scale);
        let want = softmax_f64(&x);
        let rel = |a: Algorithm| max_rel_error(&softmax::compute(&x, a), &want);
        let tv = |a: Algorithm| tv_distance(&softmax::compute(&x, a), &want);
        println!(
            "{:>8} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e}",
            scale,
            rel(Algorithm::Naive),
            rel(Algorithm::Safe),
            rel(Algorithm::Online),
            tv(Algorithm::Naive),
            tv(Algorithm::Safe),
            tv(Algorithm::Online)
        );
    }

    println!("\n=== normalizer d: sequential fold vs ⊕ tree reduction vs f64 ===");
    println!("{:>10} {:>14} {:>14}", "V", "seq rel err", "tree rel err");
    for n in [1_000usize, 10_000, 100_000, 1_000_000] {
        let x = Xoshiro256pp::seed_from_u64(n as u64).logits(n, 5.0);
        // f64 reference normalizer
        let m = x.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b as f64));
        let d64: f64 = x.iter().map(|&v| ((v as f64) - m).exp()).sum();
        // sequential Algorithm 3
        let seq = onlinesoftmax::softmax::scalar::online_normalizer(&x);
        // pairwise ⊕ tree over 1024-element leaves
        let leaves: Vec<monoid::MD> = x
            .chunks(1024)
            .map(onlinesoftmax::softmax::vectorized::online_normalizer)
            .collect();
        let tree = monoid::tree_reduce(&leaves);
        let rel = |d: f32| ((d as f64 - d64) / d64).abs();
        println!("{:>10} {:>14.3e} {:>14.3e}", n, rel(seq.d), rel(tree.d));
    }

    println!(
        "\nconclusions:\n\
         • naive overflows past x ≈ 88.7 (fp32 exp limit); safe/online never do.\n\
         • online matches safe's accuracy — same (m, d), one fewer pass (Theorem 1).\n\
         • the ⊕ tree is as accurate as (usually better than) the sequential fold,\n\
           so the parallel/sharded evaluation orders cost nothing numerically."
    );
}
