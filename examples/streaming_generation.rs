//! Server-side streaming generation (protocol v2): one request frame,
//! N streamed token frames, decode batched across concurrent streams.
//!
//! ```bash
//! cargo run --release --example streaming_generation
//! ```
//!
//! What it does:
//! 1. starts the host-backend server (no artifacts needed),
//! 2. streams a generation over one connection and prints each token
//!    frame as it arrives,
//! 3. replays the same trajectory with per-token v1-style `lm_step`
//!    round-trips and verifies the selections are identical,
//! 4. runs several concurrent streams and reads the batch-occupancy
//!    metrics from the `stats` RPC to show cross-stream batching.

use std::sync::Arc;
use std::time::{Duration, Instant};

use onlinesoftmax::config::{BackendKind, ServeConfig, ServingMode};
use onlinesoftmax::coordinator::Coordinator;
use onlinesoftmax::json::Value;
use onlinesoftmax::server::{client::Client, Server};

const TOKENS: usize = 16;
const K: usize = 5;
const STREAMS: usize = 4;

fn main() {
    let mut cfg = ServeConfig::default();
    cfg.backend = BackendKind::Host;
    cfg.mode = ServingMode::Online;
    cfg.vocab = 8192;
    cfg.hidden = 64;
    cfg.shard_threshold = 2048;
    cfg.max_wait = Duration::from_millis(2);
    cfg.addr = "127.0.0.1:0".into();

    let coordinator = Arc::new(Coordinator::start(&cfg).expect("coordinator"));
    let server = Server::bind(&cfg.addr, coordinator, STREAMS + 2).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let server_thread = std::thread::spawn(move || {
        let _ = server.serve();
    });

    // --- one stream, one connection round-trip ---------------------------
    let mut client = Client::connect(&addr).expect("connect");
    let sid = client.open_session().expect("session");
    println!("streaming {TOKENS} tokens from prompt [7, 42] (k={K}):");
    let t0 = Instant::now();
    let mut stream = client.generate(sid, &[7, 42], TOKENS, Some(K)).expect("generate");
    let mut streamed = Vec::new();
    for frame in &mut stream {
        let frame = frame.expect("token frame");
        println!(
            "  #{:<2} token {:>6}  p = {:.5}",
            frame.index, frame.token, frame.vals[0]
        );
        streamed.push(frame);
    }
    let stream_time = t0.elapsed();
    let final_tokens = stream.tokens().to_vec();
    println!("stream done in {stream_time:?} — one request frame on the wire");

    // --- the v1 equivalent: one round-trip per token ---------------------
    let sid2 = client.open_session().expect("session");
    let t0 = Instant::now();
    client.lm_step(sid2, 7, Some(K)).expect("prompt feed");
    let mut cur = 42i32;
    let mut stepped = Vec::new();
    for _ in 0..TOKENS {
        let (_vals, idx) = client.lm_step(sid2, cur, Some(K)).expect("lm_step");
        cur = idx[0] as i32;
        stepped.push(cur);
    }
    let step_time = t0.elapsed();
    assert_eq!(final_tokens, stepped, "streamed and stepped selections are identical");
    println!(
        "per-token lm_step replay: {step_time:?} over {} round-trips → identical tokens ✓",
        TOKENS + 1
    );

    // --- concurrent streams share decode batches -------------------------
    println!("\nrunning {STREAMS} concurrent streams of {TOKENS} tokens...");
    std::thread::scope(|scope| {
        for w in 0..STREAMS {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let sid = c.open_session().expect("session");
                let frames = c
                    .generate_all(sid, &[17 * (w as i32 + 1)], TOKENS, Some(K))
                    .expect("stream");
                assert_eq!(frames.len(), TOKENS);
            });
        }
    });
    let stats = client.stats().expect("stats");
    let peak = stats
        .get("metrics")
        .and_then(|m| m.get("gauges"))
        .and_then(|g| g.get("coordinator.batch.lm_step.peak"))
        .and_then(Value::as_i64)
        .unwrap_or(0);
    println!(
        "peak lm_step batch occupancy: {peak} (>1 ⇒ streams shared decode batches)"
    );

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = server_thread.join();
}
