//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text
//! produced by `python/compile/aot.py`) into PJRT CPU clients and
//! executes them from the serving hot path.  Python never runs here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, variants,
//!   batch buckets).
//! * [`tensor`] — the host tensor type crossing the boundary.
//! * [`engine`] — thread-confined PJRT clients behind `Send` handles,
//!   plus the [`engine::EnginePool`] used for sharded execution.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{default_artifacts_dir, Engine, EnginePool, EngineStats, Input};
pub use manifest::{ArtifactEntry, DType, Manifest, TensorSpec};
pub use tensor::Tensor;
