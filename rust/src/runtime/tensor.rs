//! Host tensor type crossing the coordinator ↔ PJRT boundary.

use anyhow::{anyhow, bail, Result};

use super::manifest::{DType, TensorSpec};

/// A host-resident dense tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor::F32 { shape, data })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor::I32 { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got {:?}", self.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor, got {:?}", self.dtype()),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    /// Validate against a manifest spec.
    pub fn check_spec(&self, spec: &TensorSpec, what: &str) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!("{what}: shape {:?} does not match artifact spec {:?}", self.shape(), spec.shape);
        }
        if self.dtype() != spec.dtype {
            bail!("{what}: dtype {:?} does not match artifact spec {:?}", self.dtype(), spec.dtype);
        }
        Ok(())
    }

    // ----- xla interop ----------------------------------------------------

    pub(super) fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub(super) fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Tensor::f32(dims, lit.to_vec::<f32>()?),
            xla::ElementType::S32 => Tensor::i32(dims, lit.to_vec::<i32>()?),
            other => Err(anyhow!("unsupported artifact output element type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates_element_count() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn spec_checking() {
        let t = Tensor::f32(vec![2, 4], vec![0.0; 8]).unwrap();
        let good = TensorSpec { shape: vec![2, 4], dtype: DType::F32 };
        let bad_shape = TensorSpec { shape: vec![4, 2], dtype: DType::F32 };
        let bad_dtype = TensorSpec { shape: vec![2, 4], dtype: DType::I32 };
        assert!(t.check_spec(&good, "in0").is_ok());
        assert!(t.check_spec(&bad_shape, "in0").is_err());
        assert!(t.check_spec(&bad_dtype, "in0").is_err());
    }

    #[test]
    fn accessors() {
        let t = Tensor::i32(vec![3], vec![7, 8, 9]).unwrap();
        assert_eq!(t.elements(), 3);
        assert_eq!(t.as_i32().unwrap(), &[7, 8, 9]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.into_i32().unwrap(), vec![7, 8, 9]);
    }
}
