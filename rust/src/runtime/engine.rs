//! PJRT engine: a dedicated OS thread owning a `PjRtClient` (the xla
//! crate's client is `Rc`-based and so thread-confined), fed through a
//! channel by a clonable, `Send` [`Engine`] handle.
//!
//! * executables are compiled lazily from HLO **text** and cached,
//! * inputs are validated against the manifest before dispatch,
//! * an [`EnginePool`] runs one engine thread per shard so vocabulary
//!   shards execute concurrently (each engine has its own client).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactEntry, Manifest};
use super::tensor::Tensor;
use crate::exec::channel::{bounded, oneshot, OnceSender, Sender};

/// One executable input: inline host data, or a reference to a
/// device-resident parameter registered earlier (weights uploaded once —
/// the serving path's hot-loop never re-transfers the projection matrix).
#[derive(Clone, Debug)]
pub enum Input {
    Inline(Tensor),
    Param(String),
}

enum Cmd {
    Execute { name: String, inputs: Vec<Input>, reply: OnceSender<Result<Vec<Tensor>>> },
    RegisterParam { key: String, tensor: Tensor, reply: OnceSender<Result<()>> },
    Warmup { names: Vec<String>, reply: OnceSender<Result<()>> },
    Stats { reply: OnceSender<EngineStats> },
    Shutdown,
}

/// Counters exposed by each engine thread.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub executions: u64,
    pub compiled: u64,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

/// Clonable, `Send` handle to one engine thread.
#[derive(Clone)]
pub struct Engine {
    tx: Sender<Cmd>,
    manifest: Arc<Manifest>,
}

impl Engine {
    /// Spawn an engine thread over an artifacts directory.
    pub fn start(artifacts_dir: &std::path::Path) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(artifacts_dir)?);
        Self::start_with_manifest(manifest, "engine")
    }

    /// Spawn with a shared manifest (used by [`EnginePool`]).
    pub fn start_with_manifest(manifest: Arc<Manifest>, name: &str) -> Result<Engine> {
        let (tx, rx) = bounded::<Cmd>(256);
        let thread_manifest = manifest.clone();
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || engine_loop(thread_manifest, rx))
            .context("spawning engine thread")?;
        Ok(Engine { tx, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact by name with inline host inputs.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.execute_mixed(name, inputs.into_iter().map(Input::Inline).collect())
    }

    /// Execute with a mix of inline tensors and device-resident params.
    /// Blocks until the result is ready.
    pub fn execute_mixed(&self, name: &str, inputs: Vec<Input>) -> Result<Vec<Tensor>> {
        // Validate inline inputs against the manifest *before* crossing
        // the channel so callers get immediate, attributable errors.
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}` (run `make artifacts`?)"))?;
        validate_inputs(entry, &inputs)?;
        let (otx, orx) = oneshot();
        self.tx
            .send(Cmd::Execute { name: name.to_string(), inputs, reply: otx })
            .map_err(|_| anyhow!("engine thread terminated"))?;
        orx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    /// Upload a tensor to the engine's device once, for reuse by name
    /// in [`Input::Param`] positions (projection weights, embeddings).
    pub fn register_param(&self, key: &str, tensor: Tensor) -> Result<()> {
        let (otx, orx) = oneshot();
        self.tx
            .send(Cmd::RegisterParam { key: key.to_string(), tensor, reply: otx })
            .map_err(|_| anyhow!("engine thread terminated"))?;
        orx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    /// Pre-compile a set of artifacts (avoids first-request latency).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        let (otx, orx) = oneshot();
        self.tx
            .send(Cmd::Warmup {
                names: names.iter().map(|s| s.to_string()).collect(),
                reply: otx,
            })
            .map_err(|_| anyhow!("engine thread terminated"))?;
        orx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (otx, orx) = oneshot();
        self.tx
            .send(Cmd::Stats { reply: otx })
            .map_err(|_| anyhow!("engine thread terminated"))?;
        orx.recv().map_err(|_| anyhow!("engine thread dropped reply"))
    }

    /// Ask the engine thread to exit once queued work drains.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Cmd::Shutdown);
    }
}

fn validate_inputs(entry: &ArtifactEntry, inputs: &[Input]) -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        bail!(
            "artifact `{}` expects {} inputs, got {}",
            entry.name,
            entry.inputs.len(),
            inputs.len()
        );
    }
    for (i, (input, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
        if let Input::Inline(t) = input {
            t.check_spec(spec, &format!("{} input {i}", entry.name))?;
        }
        // Param shapes are checked at registration + execute time on the
        // engine thread (the buffer's on-device shape is authoritative).
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Engine thread
// ---------------------------------------------------------------------------

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
}

fn engine_loop(manifest: Arc<Manifest>, rx: crate::exec::channel::Receiver<Cmd>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            crate::error!("runtime.engine", "failed to create PJRT client: {e}");
            // Drain commands with errors so callers unblock.
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Cmd::Execute { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                    }
                    Cmd::RegisterParam { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                    }
                    Cmd::Warmup { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                    }
                    Cmd::Stats { reply } => {
                        let _ = reply.send(EngineStats::default());
                    }
                    Cmd::Shutdown => return,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, Loaded> = HashMap::new();
    // (host literal, device buffer): the literal backs the async copy.
    let mut params: HashMap<String, (xla::Literal, xla::PjRtBuffer)> = HashMap::new();
    let mut stats = EngineStats::default();

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Execute { name, inputs, reply } => {
                let result =
                    run_one(&client, &manifest, &mut cache, &params, &mut stats, &name, inputs);
                let _ = reply.send(result);
            }
            Cmd::RegisterParam { key, tensor, reply } => {
                // NOTE: PJRT's host→device transfer is asynchronous and
                // borrows the source literal; the literal is kept alive
                // in the params map for the buffer's entire lifetime.
                let result = tensor.to_literal().and_then(|lit| {
                    client
                        .buffer_from_host_literal(None, &lit)
                        .map(|buf| (lit, buf))
                        .map_err(|e| anyhow!("uploading param `{key}`: {e}"))
                });
                match result {
                    Ok(entry) => {
                        params.insert(key, entry);
                        let _ = reply.send(Ok(()));
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Cmd::Warmup { names, reply } => {
                let mut result = Ok(());
                for name in &names {
                    if let Err(e) = ensure_loaded(&client, &manifest, &mut cache, &mut stats, name)
                    {
                        result = Err(e);
                        break;
                    }
                }
                let _ = reply.send(result);
            }
            Cmd::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Cmd::Shutdown => break,
        }
    }
}

fn ensure_loaded<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &'a mut HashMap<String, Loaded>,
    stats: &mut EngineStats,
    name: &str,
) -> Result<&'a Loaded> {
    if !cache.contains_key(name) {
        let entry = manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        let path = manifest.hlo_path(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling artifact `{name}`"))?;
        let dt = t0.elapsed().as_secs_f64();
        stats.compiled += 1;
        stats.compile_secs += dt;
        crate::debug!("runtime.engine", "compiled `{name}` in {:.1}ms", dt * 1e3);
        cache.insert(name.to_string(), Loaded { exe });
    }
    Ok(&cache[name])
}

fn run_one(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &mut HashMap<String, Loaded>,
    params: &HashMap<String, (xla::Literal, xla::PjRtBuffer)>,
    stats: &mut EngineStats,
    name: &str,
    inputs: Vec<Input>,
) -> Result<Vec<Tensor>> {
    let loaded = ensure_loaded(client, manifest, cache, stats, name)?;
    let t0 = Instant::now();
    // Stage inline tensors as device buffers, then splice in the
    // pre-registered parameter buffers by reference.  The staged
    // literals MUST outlive the execution: PJRT's host→device copy is
    // asynchronous and reads the literal's memory until the compute
    // consuming it has been synchronized (to_literal_sync below).
    let mut staged_lits: Vec<xla::Literal> = Vec::new();
    let mut staged: Vec<xla::PjRtBuffer> = Vec::new();
    let mut arg_refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
    let mut staged_idx: Vec<usize> = Vec::with_capacity(inputs.len());
    const PARAM_SENTINEL: usize = usize::MAX;
    for input in &inputs {
        match input {
            Input::Inline(t) => {
                let lit = t.to_literal()?;
                staged.push(
                    client
                        .buffer_from_host_literal(None, &lit)
                        .map_err(|e| anyhow!("staging input for `{name}`: {e}"))?,
                );
                staged_lits.push(lit);
                staged_idx.push(staged.len() - 1);
            }
            Input::Param(_) => staged_idx.push(PARAM_SENTINEL),
        }
    }
    for (input, &si) in inputs.iter().zip(&staged_idx) {
        match input {
            Input::Inline(_) => arg_refs.push(&staged[si]),
            Input::Param(key) => arg_refs.push(
                params
                    .get(key)
                    .map(|(_lit, buf)| buf)
                    .ok_or_else(|| anyhow!("param `{key}` not registered on this engine"))?,
            ),
        }
    }
    let result = loaded
        .exe
        .execute_b::<&xla::PjRtBuffer>(&arg_refs)
        .with_context(|| format!("executing artifact `{name}`"))?;
    let lit = result
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| anyhow!("artifact `{name}` returned no buffers"))?
        .to_literal_sync()?;
    // Outputs are synchronized; the staged host literals may drop now.
    drop(staged_lits);
    stats.executions += 1;
    stats.execute_secs += t0.elapsed().as_secs_f64();
    // aot.py lowers with return_tuple=True: single tuple of outputs.
    let parts = lit.to_tuple()?;
    let entry = manifest.get(name).expect("validated above");
    let outputs: Vec<Tensor> = parts
        .iter()
        .map(Tensor::from_literal)
        .collect::<Result<_>>()
        .with_context(|| format!("decoding outputs of `{name}`"))?;
    if outputs.len() != entry.outputs.len() {
        bail!(
            "artifact `{name}` returned {} outputs, manifest says {}",
            outputs.len(),
            entry.outputs.len()
        );
    }
    for (i, (t, spec)) in outputs.iter().zip(&entry.outputs).enumerate() {
        t.check_spec(spec, &format!("{name} output {i}"))?;
    }
    Ok(outputs)
}

// ---------------------------------------------------------------------------
// Engine pool
// ---------------------------------------------------------------------------

/// N engine threads (each with its own PJRT client) for concurrent
/// shard execution.  Work is routed by index (`shard % n`).
pub struct EnginePool {
    engines: Vec<Engine>,
}

impl EnginePool {
    pub fn start(artifacts_dir: &std::path::Path, n: usize) -> Result<EnginePool> {
        assert!(n > 0);
        let manifest = Arc::new(Manifest::load(artifacts_dir)?);
        let engines = (0..n)
            .map(|i| Engine::start_with_manifest(manifest.clone(), &format!("engine-{i}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(EnginePool { engines })
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// Engine serving shard/stream `i`.
    pub fn engine(&self, i: usize) -> &Engine {
        &self.engines[i % self.engines.len()]
    }

    pub fn manifest(&self) -> &Manifest {
        self.engines[0].manifest()
    }

    pub fn shutdown(&self) {
        for e in &self.engines {
            e.shutdown();
        }
    }
}

/// Artifacts directory resolution: `$OSMAX_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("OSMAX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
