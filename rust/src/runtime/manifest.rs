//! Artifact manifest: the machine-readable index emitted by
//! `python/compile/aot.py` describing every AOT-compiled executable
//! (shapes, dtypes, variant metadata).  The rust side trusts nothing
//! implicit — shapes are validated here and re-validated against the
//! actual HLO program shape after compilation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{self, Value};

/// Element dtype of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype `{s}`"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape+dtype of one input/output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<TensorSpec> {
        let shape = v
            .require("shape")?
            .as_array()
            .ok_or_else(|| anyhow!("shape must be an array"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(v.require("dtype")?.as_str().unwrap_or(""))?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT executable's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// HLO text file, relative to the artifacts directory.
    pub file: PathBuf,
    /// Variant key, e.g. `softmax_safe`, `decode_partial`.
    pub variant: String,
    pub batch: usize,
    pub vocab: usize,
    pub hidden: Option<usize>,
    pub k: Option<usize>,
    pub shard_count: Option<usize>,
    pub full_vocab: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactEntry {
    fn from_json(v: &Value) -> Result<ArtifactEntry> {
        let name = v.require("name")?.as_str().unwrap_or("").to_string();
        let get_usize = |key: &str| v.get(key).and_then(Value::as_usize);
        Ok(ArtifactEntry {
            file: PathBuf::from(v.require("file")?.as_str().unwrap_or("")),
            variant: v.require("variant")?.as_str().unwrap_or("").to_string(),
            batch: get_usize("batch")
                .ok_or_else(|| anyhow!("artifact `{name}` missing batch"))?,
            vocab: get_usize("vocab")
                .ok_or_else(|| anyhow!("artifact `{name}` missing vocab"))?,
            hidden: get_usize("hidden"),
            k: get_usize("k"),
            shard_count: get_usize("shard_count"),
            full_vocab: get_usize("full_vocab"),
            inputs: v
                .require("inputs")?
                .as_array()
                .ok_or_else(|| anyhow!("inputs must be an array"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?,
            outputs: v
                .require("outputs")?
                .as_array()
                .ok_or_else(|| anyhow!("outputs must be an array"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?,
            name,
        })
    }
}

/// The parsed manifest: entries indexed by name and by (variant, batch).
#[derive(Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    entries: Vec<ArtifactEntry>,
    by_name: BTreeMap<String, usize>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` to AOT-compile the models",
                path.display()
            )
        })?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let format = v.require("format")?.as_usize().unwrap_or(0);
        if format != 1 {
            bail!("unsupported manifest format {format} (expected 1)");
        }
        let mut entries = Vec::new();
        let mut by_name = BTreeMap::new();
        for e in v.require("artifacts")?.as_array().unwrap_or(&[]) {
            let entry = ArtifactEntry::from_json(e)?;
            if by_name.insert(entry.name.clone(), entries.len()).is_some() {
                bail!("duplicate artifact name `{}`", entry.name);
            }
            entries.push(entry);
        }
        if entries.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries, by_name })
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// All entries for a variant, sorted by batch size ascending.
    pub fn variant(&self, variant: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> =
            self.entries.iter().filter(|e| e.variant == variant).collect();
        v.sort_by_key(|e| e.batch);
        v
    }

    /// Smallest batch bucket ≥ `n` for a variant (the batcher's padding
    /// target); falls back to the largest bucket if `n` exceeds all.
    pub fn bucket_for(&self, variant: &str, n: usize) -> Option<&ArtifactEntry> {
        let entries = self.variant(variant);
        entries.iter().find(|e| e.batch >= n).copied().or_else(|| entries.last().copied())
    }

    /// Batch bucket list for a variant.
    pub fn buckets(&self, variant: &str) -> Vec<usize> {
        self.variant(variant).iter().map(|e| e.batch).collect()
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> String {
        r#"{
          "format": 1,
          "artifacts": [
            {"name": "softmax_safe_b1_v64", "file": "a.hlo.txt",
             "variant": "softmax_safe", "batch": 1, "vocab": 64,
             "inputs": [{"shape": [1, 64], "dtype": "float32"}],
             "outputs": [{"shape": [1, 64], "dtype": "float32"}]},
            {"name": "softmax_safe_b8_v64", "file": "b.hlo.txt",
             "variant": "softmax_safe", "batch": 8, "vocab": 64,
             "inputs": [{"shape": [8, 64], "dtype": "float32"}],
             "outputs": [{"shape": [8, 64], "dtype": "float32"}]},
            {"name": "decode_partial_b1", "file": "c.hlo.txt",
             "variant": "decode_partial", "batch": 1, "vocab": 16,
             "hidden": 8, "k": 3, "shard_count": 4, "full_vocab": 64,
             "inputs": [{"shape": [1, 8], "dtype": "float32"},
                         {"shape": [16, 8], "dtype": "float32"}],
             "outputs": [{"shape": [1], "dtype": "float32"},
                          {"shape": [1], "dtype": "float32"},
                          {"shape": [1, 3], "dtype": "float32"},
                          {"shape": [1, 3], "dtype": "int32"}]}
          ]
        }"#
        .to_string()
    }

    fn load_sample() -> Manifest {
        let dir = std::env::temp_dir().join(format!("osmax-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn loads_and_indexes() {
        let m = load_sample();
        assert_eq!(m.entries().len(), 3);
        let e = m.get("decode_partial_b1").unwrap();
        assert_eq!(e.k, Some(3));
        assert_eq!(e.shard_count, Some(4));
        assert_eq!(e.inputs[1].shape, vec![16, 8]);
        assert_eq!(e.outputs[3].dtype, DType::I32);
    }

    #[test]
    fn bucket_selection() {
        let m = load_sample();
        assert_eq!(m.bucket_for("softmax_safe", 1).unwrap().batch, 1);
        assert_eq!(m.bucket_for("softmax_safe", 2).unwrap().batch, 8);
        assert_eq!(m.bucket_for("softmax_safe", 100).unwrap().batch, 8, "clamps to largest");
        assert!(m.bucket_for("nonexistent", 1).is_none());
        assert_eq!(m.buckets("softmax_safe"), vec![1, 8]);
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { shape: vec![4, 64], dtype: DType::F32 };
        assert_eq!(t.elements(), 256);
    }

    #[test]
    fn rejects_bad_format() {
        let dir = std::env::temp_dir().join(format!("osmax-badfmt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"format": 99, "artifacts": []}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_has_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // Integration-lite: if `make artifacts` has run, the real
        // manifest must parse and contain the serving variants.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for variant in ["softmax_safe", "decode_topk_safe", "decode_topk_online", "decode_partial"] {
                assert!(!m.variant(variant).is_empty(), "missing variant {variant}");
            }
        }
    }
}
