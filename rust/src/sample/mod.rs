//! Fused Gumbel-top-k sampling state for the single-sweep scan.
//!
//! The Gumbel-max trick turns sampling into selection: perturb each
//! tempered logit with an i.i.d. Gumbel(0,1) draw and take the argmax —
//! the result is a sample from `softmax(x / T)`.  Taking the top-k by
//! perturbed score samples k tokens *without replacement* from the same
//! distribution (Gumbel-top-k).  Because each perturbation is a pure
//! function of `(seed, global index)`, the perturbed scores compose
//! with the paper's ⊕ merge law exactly like raw logits do: any
//! shard/grid/backend decomposition sees identical perturbations, so
//! the fused single sweep of Algorithm 4 can track a sampled candidate
//! set alongside the exact online normalizer with zero extra passes.
//!
//! Everything here is deterministic given `(seed, temperature)`:
//!
//! * [`gumbel`] — the counter-based per-index draw (SplitMix64-style
//!   finalizer; the python reference in `compile/golden.py` implements
//!   the same spec bit for bit).
//! * [`SampledBuffer`] — the (K+1)-slot insertion buffer of Algorithm 4
//!   keyed by *perturbed score* while remembering each candidate's raw
//!   logit, so the merged state can still report exact untempered
//!   probabilities `e^{x−m}/d`.
//! * [`derive_step_seed`] — per-decode-step seed derivation for
//!   streaming generation (one request seed, a distinct stream per
//!   step, no inter-step correlation).

use crate::softmax::fastexp::fast_exp;
use crate::softmax::monoid::MD;

/// Golden-ratio increment of the counter stream (SplitMix64's gamma).
const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// Domain separator for [`derive_step_seed`], so step seeds never
/// collide with the per-index draw stream of the same request seed.
const STEP_STREAM: u64 = 0xA5A5_5A5A_C3C3_3C3C;

/// Per-request sampling parameters, threaded from [`RequestOptions`]
/// (`crate::coordinator::RequestOptions`) down to every per-tile scan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleSpec {
    /// Seed of the counter-based draw stream.  Same seed ⇒ bitwise-
    /// identical perturbations regardless of decomposition.
    pub seed: u64,
    /// Softmax temperature; perturbed score is `x/T + Gumbel`.  Must be
    /// finite and > 0 (validated at admission, asserted here).
    pub temperature: f32,
}

/// The SplitMix64 output finalizer over an arbitrary 64-bit counter:
/// `seed` selects the stream, `counter` indexes into it.  Stateless —
/// any evaluation order over any partition of the counters produces
/// the same values.
#[inline]
pub fn counter_hash(seed: u64, counter: u64) -> u64 {
    let mut z = seed.wrapping_add(counter.wrapping_add(1).wrapping_mul(GAMMA));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The per-index Gumbel(0,1) draw: `g = −ln(−ln(u))` where `u ∈ (0,1)`
/// comes from the top 53 bits of [`counter_hash`] (offset by ½ulp so
/// `u` is never 0 or 1 and the double logarithm is always finite).
/// Computed in f64 and rounded once to f32, matching the python
/// reference exactly.
#[inline]
pub fn gumbel(seed: u64, index: i64) -> f32 {
    let h = counter_hash(seed, index as u64);
    let u = ((h >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64);
    (-(-u.ln()).ln()) as f32
}

/// The perturbed selection score of one logit: `x/T + Gumbel(seed, i)`.
/// `−∞` (vocabulary padding) stays `−∞` and NaN stays NaN under this
/// arithmetic, so masked and poisoned inputs keep the exclusion
/// behaviour of the deterministic top-k scan.
#[inline]
pub fn perturb(x: f32, index: i64, spec: SampleSpec) -> f32 {
    debug_assert!(spec.temperature.is_finite() && spec.temperature > 0.0);
    (x / spec.temperature) + gumbel(spec.seed, index)
}

/// Derive the seed of decode step `step` from a request-level seed.
/// Each streamed token gets its own draw stream — otherwise a repeated
/// hidden state would repeat its sampled token forever — while the
/// whole stream stays a pure function of the request seed.  Uses a
/// domain-separated [`counter_hash`] stream so step seeds never alias
/// the per-index draws.
#[inline]
pub fn derive_step_seed(seed: u64, step: u64) -> u64 {
    counter_hash(seed ^ STEP_STREAM, step)
}

/// The sampled analogue of [`TopKBuffer`](crate::topk::TopKBuffer): the
/// same (K+1)-slot descending insertion buffer of Algorithm 4, ordered
/// by **perturbed score** while carrying each candidate's raw logit so
/// finalization can report exact untempered probabilities.
///
/// Structure and semantics mirror `TopKBuffer` slot for slot: sentinel
/// `(−∞, −∞, −1)` entries, strict-`<` bubbling (incumbent wins score
/// ties), NaN scores structurally excluded (they fail both the fast
/// reject and every bubble comparison, so they never enter the visible
/// `k` window), and an associative [`merge`](Self::merge) — the ⊕ law
/// the shard tree reduction relies on.
#[derive(Clone, Debug)]
pub struct SampledBuffer {
    /// Perturbed scores, descending; length K+1 (slot K is scratch).
    s: Vec<f32>,
    /// Raw (untempered, unperturbed) logits aligned with `s`.
    x: Vec<f32>,
    /// Global indices aligned with `s`.
    p: Vec<i64>,
    k: usize,
}

impl SampledBuffer {
    /// Initialize with −∞ scores/logits and −1 indices.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        Self {
            s: vec![f32::NEG_INFINITY; k + 1],
            x: vec![f32::NEG_INFINITY; k + 1],
            p: vec![-1; k + 1],
            k,
        }
    }

    /// The buffer's k.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Insert `(score, logit, index)` via slot K+1 and bubble it up —
    /// lines 8–15 of Algorithm 4 keyed by perturbed score.
    #[inline]
    pub fn push(&mut self, score: f32, logit: f32, index: i64) {
        let k = self.k;
        // Fast reject: strictly-not-better than the current k-th score.
        // (Equal scores lose to the incumbent, like line 11's strict `<`.)
        if score <= self.s[k - 1] {
            return;
        }
        self.s[k] = score;
        self.x[k] = logit;
        self.p[k] = index;
        let mut i = k;
        while i >= 1 && self.s[i - 1] < self.s[i] {
            self.s.swap(i - 1, i);
            self.x.swap(i - 1, i);
            self.p.swap(i - 1, i);
            i -= 1;
        }
    }

    /// The first K `(score, logit, index)` triples, descending by score.
    pub fn entries(&self) -> impl Iterator<Item = (f32, f32, i64)> + '_ {
        (0..self.k).map(|i| (self.s[i], self.x[i], self.p[i]))
    }

    /// Perturbed scores only (descending).
    pub fn scores(&self) -> &[f32] {
        &self.s[..self.k]
    }

    /// Selected global indices, descending by perturbed score.
    pub fn indices(&self) -> &[i64] {
        &self.p[..self.k]
    }

    /// Number of real (non-sentinel) entries.
    pub fn len_filled(&self) -> usize {
        self.p[..self.k].iter().filter(|&&i| i >= 0).count()
    }

    /// Associative merge (lane/thread/shard combination): re-insert the
    /// other buffer's real entries.  Incumbent-wins tie-breaking makes
    /// ascending-shard merge order reproduce the whole-row scan.
    pub fn merge(&mut self, other: &SampledBuffer) {
        assert_eq!(self.k, other.k, "cannot merge buffers of different k");
        for (s, x, i) in other.entries() {
            if i >= 0 {
                self.push(s, x, i);
            }
        }
    }
}

/// Scan a tile into a fresh sampled buffer: perturb each element with
/// its per-index draw and track the top-k by perturbed score.  `base`
/// globalizes indices (shards pass their range start), and — because
/// the draw is keyed by the *global* index — every decomposition of a
/// row produces partials that merge to the identical selection.
pub fn scan_sampled(tile: &[f32], k: usize, base: i64, spec: SampleSpec) -> SampledBuffer {
    let mut buf = SampledBuffer::new(k);
    for (i, &v) in tile.iter().enumerate() {
        let idx = base + i as i64;
        buf.push(perturb(v, idx, spec), v, idx);
    }
    buf
}

/// Lines 17–19 of Algorithm 4 over a merged sampled buffer: report the
/// **untempered** probability `e^{x−m}/d` of each sampled token, in
/// descending perturbed-score order (the sampled ranking).  Sentinel
/// slots (k > real candidates) are skipped like the deterministic path.
pub fn finalize_sampled(buf: &SampledBuffer, md: MD) -> (Vec<f32>, Vec<i64>) {
    let inv = 1.0 / md.d;
    let mut vals = Vec::with_capacity(buf.k());
    let mut idx = Vec::with_capacity(buf.k());
    for (_, x, i) in buf.entries() {
        if i >= 0 {
            vals.push(fast_exp(x - md.m) * inv);
            idx.push(i);
        }
    }
    (vals, idx)
}

/// Whole-row convenience: one fused sweep producing the exact online
/// normalizer (the reference scalar scan) plus the sampled selection.
/// This is the per-row path the executor uses below the sharding
/// threshold; the sharded grid path computes the same thing via
/// per-tile [`scan_sampled`] partials and the ⊕ tree reduction.
pub fn sampled_topk(x: &[f32], k: usize, spec: SampleSpec) -> (Vec<f32>, Vec<i64>) {
    let (md, _) = crate::softmax::fused::fused_partial(x, k, 0);
    let buf = scan_sampled(x, k, 0, spec);
    finalize_sampled(&buf, md)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    const SPEC: SampleSpec = SampleSpec { seed: 42, temperature: 1.0 };

    fn logits(n: usize, seed: u64) -> Vec<f32> {
        Xoshiro256pp::seed_from_u64(seed).logits(n, 6.0)
    }

    #[test]
    fn counter_hash_is_stateless_and_seed_sensitive() {
        assert_eq!(counter_hash(7, 3), counter_hash(7, 3));
        assert_ne!(counter_hash(7, 3), counter_hash(7, 4));
        assert_ne!(counter_hash(7, 3), counter_hash(8, 3));
        // the counter stream has no fixed point at zero
        assert_ne!(counter_hash(0, 0), 0);
    }

    #[test]
    fn gumbel_draws_are_finite_and_deterministic() {
        for idx in 0..10_000i64 {
            let g = gumbel(123, idx);
            assert!(g.is_finite(), "index {idx} drew {g}");
            assert_eq!(g, gumbel(123, idx));
        }
    }

    #[test]
    fn gumbel_sample_moments_match_distribution() {
        // Gumbel(0,1): mean = γ ≈ 0.5772, variance = π²/6 ≈ 1.6449.
        let n = 200_000i64;
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for i in 0..n {
            let g = gumbel(9, i) as f64;
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5772).abs() < 0.01, "mean {mean}");
        assert!((var - 1.6449).abs() < 0.03, "var {var}");
    }

    #[test]
    fn perturb_preserves_masking_semantics() {
        assert_eq!(perturb(f32::NEG_INFINITY, 5, SPEC), f32::NEG_INFINITY);
        assert!(perturb(f32::NAN, 5, SPEC).is_nan());
        let cold = SampleSpec { seed: 42, temperature: 0.5 };
        let hot = SampleSpec { seed: 42, temperature: 2.0 };
        // lower temperature stretches the logit's contribution
        assert_eq!(perturb(3.0, 7, cold) - gumbel(42, 7), 6.0);
        assert_eq!(perturb(3.0, 7, hot) - gumbel(42, 7), 1.5);
    }

    #[test]
    fn scan_matches_bruteforce_argsort() {
        let x = logits(800, 3);
        let k = 7;
        let buf = scan_sampled(&x, k, 0, SPEC);
        let mut scored: Vec<(f32, i64)> = x
            .iter()
            .enumerate()
            .map(|(i, &v)| (perturb(v, i as i64, SPEC), i as i64))
            .collect();
        scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let want: Vec<i64> = scored[..k].iter().map(|&(_, i)| i).collect();
        assert_eq!(buf.indices(), &want[..]);
    }

    #[test]
    fn merge_equals_whole_scan_for_any_split() {
        let x = logits(1000, 5);
        let k = 5;
        let whole = scan_sampled(&x, k, 0, SPEC);
        for chunk in [37usize, 100, 512, 999] {
            let mut merged = SampledBuffer::new(k);
            for (c, tile) in x.chunks(chunk).enumerate() {
                merged.merge(&scan_sampled(tile, k, (c * chunk) as i64, SPEC));
            }
            assert_eq!(merged.indices(), whole.indices(), "chunk={chunk}");
            assert_eq!(merged.scores(), whole.scores(), "chunk={chunk}");
        }
    }

    #[test]
    fn nan_and_neg_inf_are_excluded_k_beyond_v_leaves_sentinels() {
        let x = [1.0f32, f32::NAN, f32::NEG_INFINITY, 2.0];
        let buf = scan_sampled(&x, 4, 0, SPEC);
        assert_eq!(buf.len_filled(), 2, "only the two finite logits enter");
        assert!(buf.indices()[..2].iter().all(|&i| i == 0 || i == 3));
        assert_eq!(&buf.indices()[2..], &[-1, -1]);
        assert!(buf.scores().iter().all(|s| !s.is_nan()));
    }

    #[test]
    fn different_seeds_select_differently() {
        let x = logits(4096, 8);
        let a = scan_sampled(&x, 3, 0, SampleSpec { seed: 1, temperature: 1.0 });
        let b = scan_sampled(&x, 3, 0, SampleSpec { seed: 2, temperature: 1.0 });
        assert_ne!(a.indices(), b.indices());
    }

    #[test]
    fn low_temperature_converges_to_greedy() {
        // As T → 0 the tempered logit dominates the O(1) Gumbel noise,
        // so the sampled argmax is the deterministic argmax.
        let x = logits(512, 11);
        let spec = SampleSpec { seed: 77, temperature: 1e-4 };
        let (_, idx) = sampled_topk(&x, 1, spec);
        let (_, greedy) = crate::softmax::fused::online_topk(&x, 1);
        assert_eq!(idx, greedy);
    }

    #[test]
    fn finalize_reports_untempered_probabilities() {
        let x = logits(300, 13);
        let spec = SampleSpec { seed: 5, temperature: 0.7 };
        let (vals, idx) = sampled_topk(&x, 4, spec);
        assert_eq!(vals.len(), 4);
        let (md, _) = crate::softmax::fused::fused_partial(&x, 4, 0);
        for (v, &i) in vals.iter().zip(&idx) {
            let want = fast_exp(x[i as usize] - md.m) / md.d;
            assert_eq!(*v, want, "index {i}");
        }
    }

    #[test]
    fn step_seeds_are_distinct_and_domain_separated() {
        let mut seen = std::collections::HashSet::new();
        for step in 0..1000u64 {
            assert!(seen.insert(derive_step_seed(99, step)));
            // never aliases the per-index hash stream of the same seed
            assert_ne!(derive_step_seed(99, step), counter_hash(99, step));
        }
    }

    #[test]
    #[should_panic(expected = "k must be positive")]
    fn zero_k_panics() {
        SampledBuffer::new(0);
    }
}
