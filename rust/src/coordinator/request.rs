//! Request/response types flowing through the coordinator.

use std::time::Instant;

use crate::exec::channel::OnceSender;

/// Monotonic request identifier.
pub type RequestId = u64;

/// What a client asks of the serving system.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Full probability vector over raw logits (Figures 1–2 workload).
    Softmax { logits: Vec<f32> },
    /// Top-k next-token probabilities for a hidden state — the beam
    /// search decode step (Figures 3–4 workload).  `k = None` uses the
    /// server default.
    DecodeTopK { hidden: Vec<f32>, k: Option<usize> },
    /// One recurrent LM step: advance `session`'s state with `token`,
    /// then decode top-k (the end-to-end example's path).
    LmStep { session: u64, token: i32, k: Option<usize> },
}

/// Result returned to the submitting client.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    Softmax { probs: Vec<f32> },
    TopK { vals: Vec<f32>, idx: Vec<i64> },
}

/// Errors surfaced to clients (stringly: crosses the wire as JSON).
pub type ReplyResult = Result<Reply, String>;

/// A queued request with its response channel and admission timestamp.
pub struct Request {
    pub id: RequestId,
    pub payload: Payload,
    pub reply: OnceSender<ReplyResult>,
    pub enqueued: Instant,
}

impl Request {
    pub fn new(id: RequestId, payload: Payload, reply: OnceSender<ReplyResult>) -> Request {
        Request { id, payload, reply, enqueued: Instant::now() }
    }

    /// Routing class — requests of different classes never share a batch.
    pub fn class(&self) -> BatchClass {
        match &self.payload {
            Payload::Softmax { .. } => BatchClass::Softmax,
            Payload::DecodeTopK { .. } => BatchClass::Decode,
            Payload::LmStep { .. } => BatchClass::LmStep,
        }
    }
}

/// Batchable request classes (one executable family per class).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BatchClass {
    Softmax,
    Decode,
    LmStep,
}

impl BatchClass {
    pub const ALL: [BatchClass; 3] = [BatchClass::Softmax, BatchClass::Decode, BatchClass::LmStep];

    pub fn name(self) -> &'static str {
        match self {
            BatchClass::Softmax => "softmax",
            BatchClass::Decode => "decode",
            BatchClass::LmStep => "lm_step",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::channel::oneshot;

    #[test]
    fn class_routing() {
        let (tx, _rx) = oneshot();
        let r = Request::new(1, Payload::Softmax { logits: vec![1.0] }, tx);
        assert_eq!(r.class(), BatchClass::Softmax);
        let (tx, _rx) = oneshot();
        let r = Request::new(2, Payload::DecodeTopK { hidden: vec![], k: Some(3) }, tx);
        assert_eq!(r.class(), BatchClass::Decode);
        let (tx, _rx) = oneshot();
        let r = Request::new(3, Payload::LmStep { session: 9, token: 5, k: None }, tx);
        assert_eq!(r.class(), BatchClass::LmStep);
        assert_eq!(BatchClass::Decode.name(), "decode");
    }
}
