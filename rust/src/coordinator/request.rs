//! Request/response types flowing through the coordinator — the typed
//! v2 serving surface.
//!
//! Three things changed from the v1 surface and together they define
//! the v2 API (see `docs/PROTOCOL.md` for the wire rendition):
//!
//! * **[`RequestOptions`]** ride on every request: top-k, sampling
//!   temperature and seed (seeded Gumbel-top-k sampling on the decode
//!   classes), a [`Priority`] class, an optional
//!   deadline, and an opaque client tag.  The batcher uses priority and
//!   deadline for flush ordering; the executor rejects requests whose
//!   deadline expired while queued.
//! * **[`Payload::Generate`]** expresses multi-token generation as one
//!   request: the coordinator runs the decode loop server-side,
//!   re-enqueueing each step into the shared batcher so concurrent
//!   streams batch together (see [`super::generate`]).
//! * **[`ServeError`]** replaces stringly errors: a machine-readable
//!   [`ErrorCode`] plus a human message, end to end — executor to wire.

// xtask:atomics-allowlist: SeqCst
// SeqCst: test-only observation flags (hook/sink interleaving checks);
// production code in this module uses no atomics.

use std::fmt;
use std::time::{Duration, Instant};

use crate::exec::channel::OnceSender;

/// Monotonic request identifier.
pub type RequestId = u64;

/// Machine-readable error classification, carried on the wire as the
/// v2 `error.code` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The frame itself was malformed or used the protocol incorrectly
    /// (bad JSON, unknown op, unsupported version, missing fields).
    BadRequest,
    /// A well-formed request carried invalid values (wrong vector
    /// length, out-of-range `k`, unsupported temperature).
    InvalidArgument,
    /// The named session does not exist.
    NotFound,
    /// The per-request deadline or the server request timeout elapsed
    /// before a reply was produced.
    DeadlineExceeded,
    /// The admission queue is full (backpressure rejection).
    Overloaded,
    /// The coordinator is draining and admits no new requests.
    ShuttingDown,
    /// Unexpected execution failure (batch execution error, dropped
    /// reply channel).
    Internal,
}

impl ErrorCode {
    /// Every code, in wire-name order (documented in docs/PROTOCOL.md).
    pub const ALL: [ErrorCode; 7] = [
        ErrorCode::BadRequest,
        ErrorCode::InvalidArgument,
        ErrorCode::NotFound,
        ErrorCode::DeadlineExceeded,
        ErrorCode::Overloaded,
        ErrorCode::ShuttingDown,
        ErrorCode::Internal,
    ];

    /// The wire name of this code.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::InvalidArgument => "invalid_argument",
            ErrorCode::NotFound => "not_found",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }

    /// Inverse of [`Self::as_str`] (client-side decoding).
    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.as_str() == s)
    }
}

/// A typed serving error: code + message.  This is what crosses the
/// wire (structured in v2, message-string in v1 with the code riding
/// along) and what every coordinator/executor path returns.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeError {
    pub code: ErrorCode,
    pub message: String,
}

impl ServeError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ServeError {
        ServeError { code, message: message.into() }
    }

    pub fn bad_request(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::BadRequest, message)
    }

    pub fn invalid(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::InvalidArgument, message)
    }

    pub fn not_found(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::NotFound, message)
    }

    pub fn deadline(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::DeadlineExceeded, message)
    }

    pub fn overloaded(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::Overloaded, message)
    }

    pub fn shutting_down(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::ShuttingDown, message)
    }

    pub fn internal(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::Internal, message)
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ServeError {}

/// Batcher scheduling class.  `Interactive` requests flush ahead of
/// `Batch` requests of the same [`BatchClass`]; classes themselves
/// still never mix in one executed batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive (the default).
    #[default]
    Interactive,
    /// Throughput traffic that tolerates queueing behind interactive
    /// requests.
    Batch,
}

impl Priority {
    /// Ordering rank: lower is more urgent.
    pub(crate) fn rank(self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// Per-request options, carried by every payload (v2 surface).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestOptions {
    /// Top-k override; `None` uses the server's `default_k`.
    pub k: Option<usize>,
    /// Sampling temperature.  Must be finite and `> 0`; any value
    /// other than `1.0` requires a `seed` (tempered sampling is only
    /// meaningful on the sampled decode path).
    pub temperature: f32,
    /// Sampling seed.  `Some` switches decode classes from greedy
    /// top-k to seeded Gumbel-top-k sampling (without replacement,
    /// ∝ `exp(x / temperature)`), computed inside the same fused
    /// single-sweep scan.  Bitwise-reproducible: the same seed always
    /// selects the same tokens regardless of sharding or backend.
    pub seed: Option<u64>,
    /// Batcher scheduling class.
    pub priority: Priority,
    /// Total handling budget measured from admission.  The batcher
    /// flushes early to honor it when it is tighter than `max_wait`,
    /// the server caps its wait with it, and the executor rejects the
    /// request with [`ErrorCode::DeadlineExceeded`] if it expires
    /// while queued.
    pub deadline: Option<Duration>,
    /// Opaque client-supplied tag (log/metric attribution only).
    pub client_tag: Option<String>,
}

impl Default for RequestOptions {
    fn default() -> Self {
        RequestOptions {
            k: None,
            temperature: 1.0,
            seed: None,
            priority: Priority::Interactive,
            deadline: None,
            client_tag: None,
        }
    }
}

impl RequestOptions {
    /// Default options with a top-k override — the most common
    /// non-default call shape.
    pub fn with_k(k: usize) -> RequestOptions {
        RequestOptions { k: Some(k), ..RequestOptions::default() }
    }
}

/// What a client asks of the serving system.  Per-request knobs that
/// used to ride on individual variants (`k`) live in
/// [`RequestOptions`] now.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Full probability vector over raw logits (Figures 1–2 workload).
    Softmax { logits: Vec<f32> },
    /// Top-k next-token probabilities for a hidden state — the beam
    /// search decode step (Figures 3–4 workload).
    DecodeTopK { hidden: Vec<f32> },
    /// One recurrent LM step: advance `session`'s state with `token`,
    /// then decode top-k (the end-to-end example's path).
    LmStep { session: u64, token: i32 },
    /// Server-side streaming generation: feed `prompt_tokens` into
    /// `session`, then greedily decode up to `max_tokens` tokens,
    /// streaming each one back.  This is a *streaming* operation: it
    /// never enters the batcher whole — the coordinator decomposes it
    /// into per-token `LmStep` work that shares decode batches with
    /// every other live stream (see [`super::generate`] and
    /// [`super::Coordinator::generate`]).
    Generate { session: u64, prompt_tokens: Vec<i32>, max_tokens: usize },
}

/// Result returned to the submitting client.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    Softmax { probs: Vec<f32> },
    TopK { vals: Vec<f32>, idx: Vec<i64> },
}

/// Typed result surfaced to clients.
pub type ReplyResult = Result<Reply, ServeError>;

/// Completion observer attached to a request's reply channel by the
/// coalescing front ([`super::front`]): called exactly once, with
/// `Some(result)` when the request completes (any path — executor,
/// batcher shed, admission rejection) or `None` if the request is
/// dropped unanswered (shutdown drop).  The front uses it to fan the
/// leader's result out to coalesced followers and to populate the
/// result cache.
pub type CompletionHook = Box<dyn FnOnce(Option<&ReplyResult>) + Send>;

/// A request's reply channel plus an optional completion hook.
///
/// Plain requests wrap their [`OnceSender`] (`From` impl); requests
/// elected coalescing *leader* by the front also carry a hook that
/// observes the result before it reaches the primary receiver.  The
/// hook fires on every exit path: `send` passes it the result, and
/// dropping the sink unanswered fires it with `None` so the front can
/// clean up its in-flight table instead of leaking waiters.
pub struct ReplySink {
    tx: Option<OnceSender<ReplyResult>>,
    hook: Option<CompletionHook>,
}

impl ReplySink {
    /// A sink that also notifies `hook` of the outcome.
    pub fn with_hook(tx: OnceSender<ReplyResult>, hook: CompletionHook) -> ReplySink {
        ReplySink { tx: Some(tx), hook: Some(hook) }
    }

    /// Deliver the result: hook first (fan-out / cache fill), then the
    /// primary receiver.  Same contract as [`OnceSender::send`]:
    /// `Err(value)` when the receiver is gone.
    pub fn send(mut self, result: ReplyResult) -> Result<(), ReplyResult> {
        if let Some(hook) = self.hook.take() {
            hook(Some(&result));
        }
        // panic-ok: `send` consumes self, so `tx` is present exactly once.
        self.tx.take().expect("sink sends once").send(result)
    }
}

impl From<OnceSender<ReplyResult>> for ReplySink {
    fn from(tx: OnceSender<ReplyResult>) -> ReplySink {
        ReplySink { tx: Some(tx), hook: None }
    }
}

impl Drop for ReplySink {
    fn drop(&mut self) {
        if let Some(hook) = self.hook.take() {
            hook(None);
        }
    }
}

/// A queued request with its response channel and admission timestamp.
pub struct Request {
    pub id: RequestId,
    pub payload: Payload,
    pub options: RequestOptions,
    pub reply: ReplySink,
    pub enqueued: Instant,
    /// Absolute deadline derived from `options.deadline` at admission.
    pub deadline: Option<Instant>,
    /// Flushes of this request's queue that boarded other work while
    /// this request stayed behind — the batcher's starvation guard
    /// promotes it once this passes a bound (see `Batcher::take`).
    pub(crate) boarding_skips: u32,
}

impl Request {
    /// A request with default options.
    pub fn new(
        id: RequestId,
        payload: Payload,
        reply: impl Into<ReplySink>,
    ) -> Request {
        Request::with_options(id, payload, RequestOptions::default(), reply)
    }

    /// A request carrying explicit per-request options.
    pub fn with_options(
        id: RequestId,
        payload: Payload,
        options: RequestOptions,
        reply: impl Into<ReplySink>,
    ) -> Request {
        let enqueued = Instant::now();
        let deadline = options.deadline.map(|d| enqueued + d);
        Request {
            id,
            payload,
            options,
            reply: reply.into(),
            enqueued,
            deadline,
            boarding_skips: 0,
        }
    }

    /// Routing class — requests of different classes never share a batch.
    pub fn class(&self) -> BatchClass {
        match &self.payload {
            Payload::Softmax { .. } => BatchClass::Softmax,
            Payload::DecodeTopK { .. } => BatchClass::Decode,
            // Generate decomposes into LmStep work; it never enters the
            // batcher whole (the coordinator rejects it at submit), but
            // the class keeps routing total.
            Payload::LmStep { .. } | Payload::Generate { .. } => BatchClass::LmStep,
        }
    }

    /// Latest instant by which this request's batch should flush: the
    /// batcher's `max_wait` bound, tightened by the per-request
    /// deadline when that is sooner.
    pub fn flush_at(&self, max_wait: Duration) -> Instant {
        let base = self.enqueued + max_wait;
        match self.deadline {
            Some(d) if d < base => d,
            _ => base,
        }
    }

    /// Whether the per-request deadline has already passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// What a `shard_scan` frame asks a worker to compute over its slice
/// of the vocabulary (the router tier's fan-out unit — see
/// `docs/PROTOCOL.md` §shard_scan).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardScanKind {
    /// Project each hidden-state row onto `[start, end)` of the vocab
    /// and run the fused Algorithm-4 scan → one `ShardPartial` per row.
    Decode,
    /// Rows are raw logit slices covering `[start, end)`; compute each
    /// row's partial online normalizer `(m, d)`.
    Softmax,
    /// Pass 2 of a distributed softmax: rows are the same logit slices,
    /// `norms` carries each row's *globally merged* `(m, d)`; scale to
    /// `e^{x−m}/d` probabilities.
    Scale,
}

impl ShardScanKind {
    /// Wire name of this scan kind.
    pub fn as_str(self) -> &'static str {
        match self {
            ShardScanKind::Decode => "decode",
            ShardScanKind::Softmax => "softmax",
            ShardScanKind::Scale => "scale",
        }
    }

    /// Inverse of [`Self::as_str`].
    pub fn parse(s: &str) -> Option<ShardScanKind> {
        match s {
            "decode" => Some(ShardScanKind::Decode),
            "softmax" => Some(ShardScanKind::Softmax),
            "scale" => Some(ShardScanKind::Scale),
            _ => None,
        }
    }
}

/// A decoded v2 `shard_scan` request: one batch of rows scanned against
/// the global vocabulary range `[start, end)` on a worker process.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardScan {
    /// What to compute (decides how `rows` is interpreted).
    pub kind: ShardScanKind,
    /// Global vocabulary range start (inclusive).
    pub start: usize,
    /// Global vocabulary range end (exclusive).
    pub end: usize,
    /// Top-k per row ([`ShardScanKind::Decode`] only).
    pub k: usize,
    /// Batch rows: hidden states (`Decode`) or logit slices of length
    /// `end − start` (`Softmax` / `Scale`).
    pub rows: Vec<Vec<f32>>,
    /// Per-row sampling spec (`Decode` only; aligned with `rows`).
    pub samples: Vec<Option<crate::sample::SampleSpec>>,
    /// Per-row merged normalizers (`Scale` only; aligned with `rows`).
    pub norms: Vec<crate::softmax::monoid::MD>,
}

/// What a worker returns for a [`ShardScan`], by kind.
#[derive(Clone, Debug)]
pub enum ShardScanReply {
    /// `Decode`: one `ShardPartial` per row (global indices).
    Partials(Vec<crate::shard::ShardPartial>),
    /// `Softmax`: one partial `(m, d)` per row.
    Norms(Vec<crate::softmax::monoid::MD>),
    /// `Scale`: one probability slice per row.
    Slices(Vec<Vec<f32>>),
}

/// Batchable request classes (one executable family per class).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BatchClass {
    Softmax,
    Decode,
    LmStep,
}

impl BatchClass {
    pub const ALL: [BatchClass; 3] = [BatchClass::Softmax, BatchClass::Decode, BatchClass::LmStep];

    pub fn name(self) -> &'static str {
        match self {
            BatchClass::Softmax => "softmax",
            BatchClass::Decode => "decode",
            BatchClass::LmStep => "lm_step",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::channel::oneshot;

    #[test]
    fn class_routing() {
        let (tx, _rx) = oneshot();
        let r = Request::new(1, Payload::Softmax { logits: vec![1.0] }, tx);
        assert_eq!(r.class(), BatchClass::Softmax);
        let (tx, _rx) = oneshot();
        let r = Request::new(2, Payload::DecodeTopK { hidden: vec![] }, tx);
        assert_eq!(r.class(), BatchClass::Decode);
        let (tx, _rx) = oneshot();
        let r = Request::new(3, Payload::LmStep { session: 9, token: 5 }, tx);
        assert_eq!(r.class(), BatchClass::LmStep);
        let (tx, _rx) = oneshot();
        let r = Request::new(
            4,
            Payload::Generate { session: 9, prompt_tokens: vec![1], max_tokens: 3 },
            tx,
        );
        assert_eq!(r.class(), BatchClass::LmStep, "generate routes as lm_step work");
        assert_eq!(BatchClass::Decode.name(), "decode");
    }

    #[test]
    fn default_options_are_neutral() {
        let o = RequestOptions::default();
        assert_eq!(o.k, None);
        assert_eq!(o.temperature, 1.0);
        assert_eq!(o.seed, None, "no seed: greedy decode");
        assert_eq!(o.priority, Priority::Interactive);
        assert!(o.deadline.is_none() && o.client_tag.is_none());
        assert_eq!(RequestOptions::with_k(7).k, Some(7));
    }

    #[test]
    fn flush_at_tightened_by_deadline() {
        let (tx, _rx) = oneshot();
        let r = Request::new(1, Payload::Softmax { logits: vec![] }, tx);
        let wait = Duration::from_millis(50);
        assert_eq!(r.flush_at(wait), r.enqueued + wait, "no deadline: max_wait bound");
        assert!(!r.expired(Instant::now()));

        let (tx, _rx) = oneshot();
        let opts = RequestOptions {
            deadline: Some(Duration::from_millis(5)),
            ..RequestOptions::default()
        };
        let r = Request::with_options(2, Payload::Softmax { logits: vec![] }, opts, tx);
        assert_eq!(r.flush_at(wait), r.deadline.unwrap(), "tighter deadline wins");
        assert!(r.expired(r.enqueued + Duration::from_millis(6)));
        assert!(!r.expired(r.enqueued + Duration::from_millis(4)));
    }

    #[test]
    fn error_codes_roundtrip_and_display() {
        for code in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::parse("bogus"), None);
        let e = ServeError::invalid("k=0 outside supported range");
        assert_eq!(e.code, ErrorCode::InvalidArgument);
        assert_eq!(e.to_string(), "invalid_argument: k=0 outside supported range");
    }

    #[test]
    fn reply_sink_hook_observes_send_and_drop() {
        use std::sync::atomic::{AtomicU8, Ordering};
        use std::sync::Arc;
        // 0 = not fired, 1 = fired with a result, 2 = fired on drop.
        let observe = |seen: &Arc<AtomicU8>| {
            let seen = seen.clone();
            Box::new(move |r: Option<&ReplyResult>| {
                seen.store(if r.is_some() { 1 } else { 2 }, Ordering::SeqCst);
            })
        };

        let seen = Arc::new(AtomicU8::new(0));
        let (tx, rx) = oneshot();
        let sink = ReplySink::with_hook(tx, observe(&seen));
        sink.send(Ok(Reply::Softmax { probs: vec![1.0] })).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 1, "hook saw the result");
        assert!(rx.recv().unwrap().is_ok(), "primary receiver still served");

        let seen = Arc::new(AtomicU8::new(0));
        let (tx, rx) = oneshot();
        drop(ReplySink::with_hook(tx, observe(&seen)));
        assert_eq!(seen.load(Ordering::SeqCst), 2, "hook saw the unanswered drop");
        assert!(rx.recv().is_err(), "receiver observes the dropped sender");
    }

    #[test]
    fn priority_parse_and_rank() {
        assert_eq!(Priority::parse("interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse("batch"), Some(Priority::Batch));
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::Interactive.rank() < Priority::Batch.rank());
        assert_eq!(Priority::default(), Priority::Interactive);
    }
}
