//! Server-side streaming generation: the decode loop that used to live
//! on the far side of the wire.
//!
//! Under the v1 surface, generating N tokens cost N client↔server
//! round-trips (`lm_step` one token at a time) — the fused single-sweep
//! kernel idled between wire hops, and cross-stream batching never
//! filled because each client's next step waited on its own socket.
//! [`Coordinator::generate`] runs that loop server-side instead: each
//! emitted token immediately re-enqueues the stream's next `LmStep`
//! into the **shared** batcher, so N concurrent streams batch every
//! decode step together through the sharded fused softmax+top-k engine
//! — one connection round-trip per *stream*, not per *token*.
//!
//! Determinism contract (pinned by the `stream_e2e` test): a `Generate`
//! request for N tokens produces **bitwise-identical** selections and
//! probabilities to N sequential v1 `lm_step` calls on a fresh session
//! — batch composition is a scheduling concern, never a numerics one
//! (the batch×shard grid's bitwise-identity property at the tier
//! above).
//!
//! The loop is driven by the caller's thread (a server connection
//! thread, a test, an example): `emit` is invoked once per decoded
//! token and may return `false` to cancel the stream (client gone).
//! The stream counts toward [`Coordinator::active_streams`] while
//! live.

// xtask:atomics-allowlist: Relaxed
// Relaxed: `active_streams` is a telemetry counter; stream lifecycle
// ordering is carried by the per-request reply channels.

use std::time::Instant;

use super::request::{Payload, Reply, RequestOptions, ServeError};
use super::Coordinator;
use crate::metrics;
use crate::sample;

/// Upper bound on `max_tokens` AND prompt length per stream.  Guards
/// the server against a hostile `max_tokens` scalar (JSON integers
/// range up to 2^53 — unbounded, a single request could drive an
/// allocation-failure abort) and bounds the silent prompt-feed phase:
/// prompt steps emit no wire frames, so an effectively unbounded
/// prompt (the 8 MiB frame limit alone admits ~10^6 tokens) would
/// starve the client's read timeout before the first token frame.
pub const MAX_STREAM_TOKENS: usize = 4096;

/// One streamed token: the greedy selection plus the full top-k
/// distribution the selection came from (what a v1 `lm_step` reply
/// carried).
#[derive(Clone, Debug, PartialEq)]
pub struct TokenFrame {
    /// 0-based index of this token within the stream.
    pub index: usize,
    /// The selected token (`idx[0]` — the greedy argmax, or the
    /// highest-perturbed-score draw on sampled streams), which also
    /// feeds the next step.
    pub token: i32,
    /// Top-k probabilities, descending.
    pub vals: Vec<f32>,
    /// Top-k token ids, aligned with `vals`.
    pub idx: Vec<i64>,
}

impl Coordinator {
    /// Run one generation stream to completion on the calling thread.
    ///
    /// Feeds `prompt_tokens` into `session` (advancing its state, one
    /// batched `LmStep` per token), then decodes up to `max_tokens`
    /// tokens — greedily, or by seeded Gumbel-top-k sampling when
    /// `options.seed` is set (each step's seed is derived from the
    /// stream seed, so a seeded stream is bitwise-reproducible) —
    /// calling `emit` with each [`TokenFrame`] as it is produced.
    /// Returns the selected tokens.
    ///
    /// `emit` returning `false` cancels the stream after the current
    /// token (the session keeps the state it has reached — identical
    /// to a v1 client disconnecting between `lm_step`s).
    ///
    /// `options.deadline` bounds the **whole stream**; each internal
    /// step is additionally capped by the configured request timeout.
    /// `options.k`/`priority`/`client_tag` ride on every internal step
    /// so the batcher schedules stream work like any other request.
    pub fn generate<F>(
        &self,
        session: u64,
        prompt_tokens: &[i32],
        max_tokens: usize,
        options: &RequestOptions,
        emit: F,
    ) -> Result<Vec<i32>, ServeError>
    where
        F: FnMut(&TokenFrame) -> bool,
    {
        self.active_streams.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        metrics::global().gauge("coordinator.active_streams").inc();
        metrics::global().counter("coordinator.streams").inc();
        let out = self.generate_inner(session, prompt_tokens, max_tokens, options, emit);
        metrics::global().gauge("coordinator.active_streams").dec();
        self.active_streams.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
        out
    }

    fn generate_inner<F>(
        &self,
        session: u64,
        prompt_tokens: &[i32],
        max_tokens: usize,
        options: &RequestOptions,
        mut emit: F,
    ) -> Result<Vec<i32>, ServeError>
    where
        F: FnMut(&TokenFrame) -> bool,
    {
        if prompt_tokens.is_empty() {
            return Err(ServeError::invalid("prompt_tokens must not be empty"));
        }
        if prompt_tokens.len() > MAX_STREAM_TOKENS {
            return Err(ServeError::invalid(format!(
                "prompt of {} tokens exceeds the per-stream limit {MAX_STREAM_TOKENS}",
                prompt_tokens.len()
            )));
        }
        if max_tokens == 0 {
            return Err(ServeError::invalid("max_tokens must be >= 1"));
        }
        if max_tokens > MAX_STREAM_TOKENS {
            return Err(ServeError::invalid(format!(
                "max_tokens {max_tokens} exceeds the per-stream limit {MAX_STREAM_TOKENS}"
            )));
        }
        if !self.executor.has_session(session) {
            return Err(ServeError::not_found(format!("unknown session {session}")));
        }
        let start = Instant::now();
        let overall = options.deadline.map(|d| start + d);
        // The stream deadline is enforced here as a whole-stream
        // budget; internal steps must not re-derive it from their own
        // admission times, so they carry no deadline of their own.
        let step_options = RequestOptions { deadline: None, ..options.clone() };

        let step = |token: i32, step_index: u64| -> Result<Reply, ServeError> {
            let timeout = match overall {
                Some(d) => {
                    let now = Instant::now();
                    if d <= now {
                        return Err(ServeError::deadline("stream deadline exhausted"));
                    }
                    (d - now).min(self.request_timeout)
                }
                None => self.request_timeout,
            };
            let mut opts = step_options.clone();
            // Sampled streams draw each step from its own derived seed:
            // reusing the stream seed verbatim would apply the *same*
            // perturbation pattern to every step's logits (perturbations
            // are pure functions of (seed, vocab index)), correlating
            // the whole trajectory.  The derivation is deterministic, so
            // a client replaying the stream one `lm_step` at a time with
            // the same per-step seeds reproduces it bitwise.
            if let Some(seed) = options.seed {
                opts.seed = Some(sample::derive_step_seed(seed, step_index));
            }
            self.call_opts(Payload::LmStep { session, token }, opts, timeout)
        };

        // Prompt feed: advance the session state through every prompt
        // token but the last, discarding the intermediate
        // distributions — exactly what a v1 client stepping its prompt
        // does.  The last prompt token seeds the decode loop.
        for (i, &t) in prompt_tokens[..prompt_tokens.len() - 1].iter().enumerate() {
            step(t, i as u64)?;
        }
        // panic-ok: the wire layer rejects empty prompts before submit.
        let mut cur = *prompt_tokens.last().expect("nonempty prompt");

        let tokens_emitted = metrics::global().counter("coordinator.stream.tokens");
        let mut selected = Vec::with_capacity(max_tokens);
        for index in 0..max_tokens {
            // Step indices continue the prompt-feed count so every
            // `LmStep` in the stream has a unique derived seed.
            let reply = step(cur, (prompt_tokens.len() - 1 + index) as u64)?;
            let Reply::TopK { vals, idx } = reply else {
                return Err(ServeError::internal("lm_step produced a non-topk reply"));
            };
            let Some(&top) = idx.first() else {
                return Err(ServeError::internal("lm_step produced an empty top-k"));
            };
            let token = top as i32;
            selected.push(token);
            tokens_emitted.inc();
            let frame = TokenFrame { index, token, vals, idx };
            if !emit(&frame) {
                break; // consumer gone: stop decoding, keep state
            }
            cur = token;
        }
        Ok(selected)
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::config::{BackendKind, ServeConfig, ServingMode};

    fn coordinator() -> Coordinator {
        let mut cfg = ServeConfig::default();
        cfg.backend = BackendKind::Host;
        cfg.mode = ServingMode::Online;
        cfg.vocab = 512;
        cfg.hidden = 16;
        cfg.host_shards = 2;
        cfg.shard_threshold = 128;
        cfg.workers = 2;
        cfg.max_wait = Duration::from_micros(200);
        Coordinator::start(&cfg).unwrap()
    }

    #[test]
    fn generate_matches_sequential_lm_steps() {
        let coord = coordinator();
        let opts = RequestOptions::with_k(4);

        // Streamed generation on one session.
        let s1 = coord.open_session();
        let mut frames = Vec::new();
        let tokens = coord
            .generate(s1, &[3, 9], 5, &opts, |f| {
                frames.push(f.clone());
                true
            })
            .unwrap();
        assert_eq!(tokens.len(), 5);
        assert_eq!(frames.len(), 5);

        // The same trajectory, client-driven, on a fresh session.
        let s2 = coord.open_session();
        let mut cur = 0i32;
        for (i, want) in frames.iter().enumerate() {
            let token = if i == 0 {
                // prompt feed
                coord
                    .call_opts(
                        Payload::LmStep { session: s2, token: 3 },
                        opts.clone(),
                        Duration::from_secs(30),
                    )
                    .unwrap();
                9
            } else {
                cur
            };
            let reply = coord
                .call_opts(
                    Payload::LmStep { session: s2, token },
                    opts.clone(),
                    Duration::from_secs(30),
                )
                .unwrap();
            let Reply::TopK { vals, idx } = reply else { panic!("non-topk") };
            assert_eq!(vals, want.vals, "step {i}: bitwise-identical probabilities");
            assert_eq!(idx, want.idx, "step {i}: identical selections");
            cur = idx[0] as i32;
            assert_eq!(cur, want.token);
        }
        coord.shutdown();
    }

    #[test]
    fn generate_rejects_bad_streams() {
        let coord = coordinator();
        let opts = RequestOptions::default();
        let err = coord.generate(999, &[1], 3, &opts, |_| true).unwrap_err();
        assert_eq!(err.code, crate::coordinator::ErrorCode::NotFound, "{err}");
        let s = coord.open_session();
        let err = coord.generate(s, &[], 3, &opts, |_| true).unwrap_err();
        assert_eq!(err.code, crate::coordinator::ErrorCode::InvalidArgument, "{err}");
        let err = coord.generate(s, &[1], 0, &opts, |_| true).unwrap_err();
        assert_eq!(err.code, crate::coordinator::ErrorCode::InvalidArgument, "{err}");
        let err = coord
            .generate(s, &[1], MAX_STREAM_TOKENS + 1, &opts, |_| true)
            .unwrap_err();
        assert_eq!(err.code, crate::coordinator::ErrorCode::InvalidArgument, "{err}");
        assert!(err.message.contains("per-stream limit"), "{err}");
        let long_prompt = vec![1i32; MAX_STREAM_TOKENS + 1];
        let err = coord.generate(s, &long_prompt, 1, &opts, |_| true).unwrap_err();
        assert_eq!(err.code, crate::coordinator::ErrorCode::InvalidArgument, "{err}");
        coord.shutdown();
    }

    #[test]
    fn sampled_generate_is_seed_reproducible() {
        let coord = coordinator();
        let run = |seed: u64| {
            let s = coord.open_session();
            let opts = RequestOptions {
                k: Some(4),
                temperature: 0.8,
                seed: Some(seed),
                ..RequestOptions::default()
            };
            let mut frames = Vec::new();
            let tokens = coord
                .generate(s, &[3, 9], 6, &opts, |f| {
                    frames.push(f.clone());
                    true
                })
                .unwrap();
            (tokens, frames)
        };
        let (t1, f1) = run(42);
        let (t2, f2) = run(42);
        assert_eq!(t1, t2, "same seed: identical token stream");
        for (a, b) in f1.iter().zip(&f2) {
            assert_eq!(a.idx, b.idx, "same seed: bitwise-identical selections");
            assert_eq!(a.vals, b.vals, "same seed: bitwise-identical probabilities");
        }
        let (t3, _) = run(43);
        assert_ne!(t1, t3, "different seeds: trajectories diverge");
        coord.shutdown();
    }

    #[test]
    fn emit_false_cancels_stream() {
        let coord = coordinator();
        let s = coord.open_session();
        let mut seen = 0;
        let tokens = coord
            .generate(s, &[5], 10, &RequestOptions::with_k(3), |_| {
                seen += 1;
                seen < 3
            })
            .unwrap();
        assert_eq!(seen, 3, "emit called until it declined");
        assert_eq!(tokens.len(), 3, "selections up to the cancel point");
        assert_eq!(coord.active_streams(), 0, "stream accounting restored");
        coord.shutdown();
    }

    #[test]
    fn exhausted_stream_deadline_is_typed() {
        let coord = coordinator();
        let s = coord.open_session();
        let opts = RequestOptions {
            deadline: Some(Duration::ZERO),
            ..RequestOptions::default()
        };
        let err = coord.generate(s, &[1], 4, &opts, |_| true).unwrap_err();
        assert_eq!(err.code, crate::coordinator::ErrorCode::DeadlineExceeded, "{err}");
        coord.shutdown();
    }
}
