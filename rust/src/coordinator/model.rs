//! Synthetic model weights for the serving system.
//!
//! The paper evaluates kernels on random fp32 logits; the serving
//! system needs an actual projection layer (and, for the end-to-end
//! example, a tiny recurrent LM).  Weights are generated determin-
//! istically from the config seed with the crate PRNG — the same seed
//! always serves the same model, so tests and clients can assert exact
//! numerics.  Scales follow common initializer conventions (≈1/√H).

use crate::rng::Xoshiro256pp;
use crate::runtime::Tensor;

/// Deterministic synthetic LM weights sized to the artifact shapes.
pub struct SyntheticLm {
    pub vocab: usize,
    pub hidden: usize,
    /// Projection matrix, row-major (vocab, hidden).
    pub w: Vec<f32>,
    /// Token embeddings, row-major (vocab, hidden).
    pub emb: Vec<f32>,
    /// Recurrent state weights (hidden, hidden).
    pub w1: Vec<f32>,
    /// Input weights (hidden, hidden).
    pub w2: Vec<f32>,
}

impl SyntheticLm {
    pub fn generate(vocab: usize, hidden: usize, seed: u64) -> SyntheticLm {
        let scale = 1.0 / (hidden as f32).sqrt();
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut gen = |n: usize, s: f32| -> Vec<f32> {
            let mut v = vec![0.0f32; n];
            rng.fill_logits(&mut v, s);
            v
        };
        SyntheticLm {
            vocab,
            hidden,
            w: gen(vocab * hidden, scale),
            emb: gen(vocab * hidden, 1.0),
            w1: gen(hidden * hidden, scale * 0.5),
            w2: gen(hidden * hidden, scale * 0.5),
        }
    }

    /// Projection weights for one vocabulary shard (rows `[lo, hi)`).
    pub fn w_shard(&self, shard: usize, shards: usize) -> Vec<f32> {
        assert!(self.vocab % shards == 0, "vocab must divide shards");
        let vs = self.vocab / shards;
        let lo = shard * vs * self.hidden;
        let hi = (shard + 1) * vs * self.hidden;
        self.w[lo..hi].to_vec()
    }

    pub fn w_tensor(&self) -> Tensor {
        // panic-ok: dims match the buffer length by construction (new()).
        Tensor::f32(vec![self.vocab, self.hidden], self.w.clone()).expect("shape")
    }

    pub fn w_shard_tensor(&self, shard: usize, shards: usize) -> Tensor {
        let vs = self.vocab / shards;
        // panic-ok: w_shard slices exactly vs*hidden elements.
        Tensor::f32(vec![vs, self.hidden], self.w_shard(shard, shards)).expect("shape")
    }

    pub fn emb_tensor(&self) -> Tensor {
        // panic-ok: dims match the buffer length by construction (new()).
        Tensor::f32(vec![self.vocab, self.hidden], self.emb.clone()).expect("shape")
    }

    pub fn w1_tensor(&self) -> Tensor {
        // panic-ok: dims match the buffer length by construction (new()).
        Tensor::f32(vec![self.hidden, self.hidden], self.w1.clone()).expect("shape")
    }

    pub fn w2_tensor(&self) -> Tensor {
        // panic-ok: dims match the buffer length by construction (new()).
        Tensor::f32(vec![self.hidden, self.hidden], self.w2.clone()).expect("shape")
    }

    /// Host-side projection `logits = h · Wᵀ` for one row (reference /
    /// fallback path; the hot path runs the AOT artifact instead).
    pub fn project_row(&self, h: &[f32]) -> Vec<f32> {
        self.project_range(h, 0, self.vocab)
    }

    /// Host-side projection restricted to vocabulary rows `[lo, hi)` —
    /// the per-shard leaf of the host backend's sharded decode: each
    /// shard materializes only its own slice of the logits before the
    /// fused scan, so the full logits vector never exists in memory.
    pub fn project_range(&self, h: &[f32], lo: usize, hi: usize) -> Vec<f32> {
        assert_eq!(h.len(), self.hidden);
        assert!(lo <= hi && hi <= self.vocab, "range [{lo}, {hi}) outside vocab");
        let mut logits = vec![0.0f32; hi - lo];
        for (j, out) in logits.iter_mut().enumerate() {
            let row = &self.w[(lo + j) * self.hidden..(lo + j + 1) * self.hidden];
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(h) {
                acc += a * b;
            }
            *out = acc;
        }
        logits
    }

    /// One recurrent LM state update, mirroring the python graph
    /// (`compile.model.toy_lm_step`): `s' = tanh(s·W1 + E[token]·W2)`.
    /// Used by the host backend; the artifact backend executes the same
    /// graph AOT-compiled.
    pub fn lm_step_row(&self, state: &[f32], token: i32) -> Vec<f32> {
        assert_eq!(state.len(), self.hidden);
        let t = token as usize;
        assert!(t < self.vocab, "token {token} outside vocab {}", self.vocab);
        let h = self.hidden;
        let e = &self.emb[t * h..(t + 1) * h];
        let mut new = vec![0.0f32; h];
        for (j, out) in new.iter_mut().enumerate() {
            // column j of W1 / W2 (row-major (H, H) matrices)
            let mut acc = 0.0f32;
            for d in 0..h {
                acc += state[d] * self.w1[d * h + j] + e[d] * self.w2[d * h + j];
            }
            *out = acc.tanh();
        }
        new
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = SyntheticLm::generate(64, 8, 42);
        let b = SyntheticLm::generate(64, 8, 42);
        let c = SyntheticLm::generate(64, 8, 43);
        assert_eq!(a.w, b.w);
        assert_ne!(a.w, c.w);
        assert_eq!(a.w.len(), 64 * 8);
    }

    #[test]
    fn shards_partition_w() {
        let m = SyntheticLm::generate(64, 8, 1);
        let mut joined = Vec::new();
        for s in 0..4 {
            joined.extend(m.w_shard(s, 4));
        }
        assert_eq!(joined, m.w);
    }

    #[test]
    fn project_row_matches_manual() {
        let m = SyntheticLm::generate(8, 4, 2);
        let h = [1.0f32, -1.0, 0.5, 2.0];
        let logits = m.project_row(&h);
        let mut want = 0.0f32;
        for d in 0..4 {
            want += m.w[3 * 4 + d] * h[d];
        }
        assert!((logits[3] - want).abs() < 1e-6);
    }

    #[test]
    fn tensors_have_declared_shapes() {
        let m = SyntheticLm::generate(32, 8, 3);
        assert_eq!(m.w_tensor().shape(), &[32, 8]);
        assert_eq!(m.w_shard_tensor(1, 4).shape(), &[8, 8]);
        assert_eq!(m.w1_tensor().shape(), &[8, 8]);
    }

    #[test]
    fn project_range_slices_full_projection() {
        let m = SyntheticLm::generate(24, 6, 5);
        let h: Vec<f32> = (0..6).map(|i| (i as f32 * 0.7).cos()).collect();
        let full = m.project_row(&h);
        let mut joined = Vec::new();
        for (lo, hi) in [(0usize, 10usize), (10, 11), (11, 24)] {
            joined.extend(m.project_range(&h, lo, hi));
        }
        assert_eq!(joined, full, "shard slices must concatenate to the full row");
        assert!(m.project_range(&h, 5, 5).is_empty());
    }

    #[test]
    fn lm_step_row_is_deterministic_and_bounded() {
        let m = SyntheticLm::generate(16, 8, 7);
        let s0 = vec![0.0f32; 8];
        let a = m.lm_step_row(&s0, 3);
        let b = m.lm_step_row(&s0, 3);
        let c = m.lm_step_row(&s0, 4);
        assert_eq!(a, b);
        assert_ne!(a, c, "different tokens diverge the state");
        assert!(a.iter().all(|v| v.abs() <= 1.0), "tanh keeps state in [-1, 1]");
        // step again from the new state — no panics, still bounded
        let d = m.lm_step_row(&a, 0);
        assert!(d.iter().all(|v| v.abs() <= 1.0));
    }
}
