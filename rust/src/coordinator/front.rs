//! Request-coalescing + result-cache front — the "fetcher" half of the
//! fetcher/executor split, sitting between [`super::Coordinator`]'s
//! submit paths and the [`super::batcher`].
//!
//! The paper's optimization buys softmax sweeps with fewer memory
//! passes; this layer makes sure those sweeps are not spent on
//! *redundant* work:
//!
//! * **Coalescing** — identical in-flight `(payload, options)`
//!   requests collapse into one execution.  The first arrival becomes
//!   the *leader* and enters the batcher; later identical arrivals
//!   become *followers* whose reply channels are parked in an
//!   in-flight table.  When the leader completes (any path: executor
//!   reply, batcher shed, admission rejection), a [`CompletionHook`]
//!   on its [`ReplySink`] fans the result out to every follower —
//!   bitwise-identical clones of one computation.
//! * **Caching** — successful decode/softmax results land in a keyed
//!   LRU; a later identical request is answered from the cache without
//!   touching the batcher at all.
//!
//! **Keying.**  The key is the payload's exact f32 bit pattern plus
//! the *effective* options: resolved top-k (`options.k` or the
//! server's `default_k` — `None` and `Some(default_k)` are the same
//! request), priority, temperature bits, and sampling seed (seeded
//! selections are deterministic, so equal seeds are the same
//! computation).  Requests differing only in `tag` or `deadline`
//! coalesce (the result is identical either way); requests differing
//! in `k`, priority, or seed never share a key.
//! Only stateless payloads ([`Payload::Softmax`],
//! [`Payload::DecodeTopK`]) participate: `LmStep`/`Generate` advance
//! per-session state, so identical-looking calls are *not* the same
//! computation and always bypass the front.
//!
//! **Follower fate.**  Followers share the leader's outcome,
//! including typed errors: a leader shed at its deadline answers its
//! followers `deadline_exceeded` too, even followers that carried no
//! deadline of their own.  That is the documented cost of coalescing
//! on a key that ignores deadlines; callers who cannot accept a
//! shared fate disable coalescing (`cache_coalesce false`).  A leader
//! dropped unanswered (shutdown teardown) drops its followers'
//! senders, which surface as disconnected-channel errors.
//!
//! Metrics: `coordinator.cache.{hits,misses,coalesced}` counters and
//! the `coordinator.cache.entries` gauge (process-global), plus
//! per-instance counts via [`Front::stats`] for the `stats` RPC.

// xtask:atomics-allowlist: Relaxed
// Relaxed: hit/miss/coalesced statistics counters only — monotonic
// telemetry with no ordering role; the cache and in-flight tables are
// guarded by the state mutex.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::request::{
    CompletionHook, Payload, Reply, ReplyResult, ReplySink, RequestOptions,
};
use crate::exec::channel::{OnceReceiver, OnceSender};
use crate::exec::oneshot;
use crate::metrics;

/// Front configuration (see `docs/CONFIG.md`: `--cache-*`).
#[derive(Clone, Copy, Debug)]
pub struct FrontPolicy {
    /// LRU result-cache entries; `0` disables caching.
    pub cache_capacity: usize,
    /// Dedupe identical in-flight requests into one execution.
    pub coalesce: bool,
    /// The server's default top-k — folded into the key so `k: None`
    /// and an explicit `k = default_k` coalesce.
    pub default_k: usize,
}

/// Per-instance counters (the `stats` RPC's `cache` object).  The
/// process-global metrics counters aggregate across every coordinator
/// in a test binary; these scope to one [`Front`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrontStats {
    pub hits: u64,
    pub misses: u64,
    pub coalesced: u64,
    pub entries: usize,
}

/// What [`Front::admit`] decided for a request.
pub enum Admission {
    /// The reply is already on its way (cache hit) or will arrive with
    /// the in-flight leader's result (coalesced follower): nothing to
    /// submit to the batcher.
    Resolved(OnceReceiver<ReplyResult>),
    /// Execute: submit a request carrying this sink.  For cacheable
    /// payloads the sink's completion hook fans out to followers and
    /// fills the cache; bypassing payloads get a plain sink.
    Execute(ReplySink, OnceReceiver<ReplyResult>),
}

/// Stateless payloads keyed by exact f32 bit patterns.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum KeyPayload {
    Softmax(Vec<u32>),
    Decode(Vec<u32>),
}

/// Coalescing/cache identity of a request: payload bits + effective
/// options.  `tag` and `deadline` are deliberately absent.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct FrontKey {
    payload: KeyPayload,
    k: usize,
    priority: u8,
    temperature: u32,
    /// Sampling seed: seeded requests only coalesce with requests
    /// carrying the *same* seed (selections are seed-deterministic, so
    /// equal seeds are bitwise the same computation; different seeds
    /// are different draws).
    seed: Option<u64>,
}

struct FrontState {
    cache: Lru,
    /// Followers waiting on an in-flight leader, by key.
    inflight: HashMap<FrontKey, Vec<OnceSender<ReplyResult>>>,
}

/// The coalescing + caching front.  Shared (`Arc`) between the
/// coordinator's submit paths and the completion hooks it plants on
/// leader requests.
pub struct Front {
    policy: FrontPolicy,
    state: Mutex<FrontState>,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

impl Front {
    pub fn new(policy: FrontPolicy) -> Front {
        Front {
            policy,
            state: Mutex::new(FrontState {
                cache: Lru::new(policy.cache_capacity),
                inflight: HashMap::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> FrontPolicy {
        self.policy
    }

    /// Route one request: answer it from the cache, park it behind an
    /// identical in-flight leader, or hand back the sink the caller
    /// must submit.  Never blocks on anything but the front's own lock.
    pub fn admit(self: &Arc<Front>, payload: &Payload, options: &RequestOptions) -> Admission {
        let (tx, rx) = oneshot();
        if self.policy.cache_capacity == 0 && !self.policy.coalesce {
            return Admission::Execute(ReplySink::from(tx), rx);
        }
        let Some(key) = self.key_for(payload, options) else {
            // Stateful payload: always executes.
            return Admission::Execute(ReplySink::from(tx), rx);
        };
        let mut st = self.state.lock().unwrap();
        if let Some(reply) = st.cache.get(&key) {
            drop(st);
            self.hits.fetch_add(1, Ordering::Relaxed);
            metrics::global().counter("coordinator.cache.hits").inc();
            let _ = tx.send(Ok(reply));
            return Admission::Resolved(rx);
        }
        if self.policy.coalesce {
            if let Some(waiters) = st.inflight.get_mut(&key) {
                waiters.push(tx);
                drop(st);
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                metrics::global().counter("coordinator.cache.coalesced").inc();
                return Admission::Resolved(rx);
            }
            st.inflight.insert(key.clone(), Vec::new());
        }
        drop(st);
        self.misses.fetch_add(1, Ordering::Relaxed);
        metrics::global().counter("coordinator.cache.misses").inc();
        let front = self.clone();
        let hook: CompletionHook = Box::new(move |result| front.complete(&key, result));
        Admission::Execute(ReplySink::with_hook(tx, hook), rx)
    }

    /// Leader completion (from its sink's hook): fill the cache on
    /// success, fan the result out to followers.  `None` means the
    /// leader was dropped unanswered — clean the in-flight entry and
    /// let the followers' channels disconnect.
    fn complete(&self, key: &FrontKey, result: Option<&ReplyResult>) {
        let mut st = self.state.lock().unwrap();
        let waiters = st.inflight.remove(key).unwrap_or_default();
        if let Some(Ok(reply)) = result {
            st.cache.insert(key.clone(), reply.clone());
            metrics::global()
                .gauge("coordinator.cache.entries")
                .set(st.cache.len() as i64);
        }
        drop(st);
        if let Some(result) = result {
            for w in waiters {
                let _ = w.send(result.clone());
            }
        }
    }

    /// Per-instance counters (the `stats` RPC's `cache` object).
    pub fn stats(&self) -> FrontStats {
        FrontStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            entries: self.state.lock().unwrap().cache.len(),
        }
    }

    /// The coalescing/cache key, or `None` for payloads that must
    /// bypass the front (session-stateful work).
    fn key_for(&self, payload: &Payload, options: &RequestOptions) -> Option<FrontKey> {
        let payload = match payload {
            Payload::Softmax { logits } => KeyPayload::Softmax(f32_bits(logits)),
            Payload::DecodeTopK { hidden } => KeyPayload::Decode(f32_bits(hidden)),
            Payload::LmStep { .. } | Payload::Generate { .. } => return None,
        };
        Some(FrontKey {
            payload,
            k: options.k.unwrap_or(self.policy.default_k),
            priority: options.priority.rank(),
            temperature: options.temperature.to_bits(),
            seed: options.seed,
        })
    }
}

/// Exact bit patterns — the cache must never unify values that merely
/// compare equal (f32 `==` conflates 0.0/-0.0 and excludes NaN).
fn f32_bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------

/// Keyed LRU over [`Reply`] values.  Recency is tracked with a lazy
/// order queue: every touch stamps the entry and appends `(key,
/// stamp)`; eviction pops stale pairs until it finds a live one, and
/// the queue is compacted when it outgrows the map by a constant
/// factor — amortized O(1) per operation, no intrusive list.
struct Lru {
    cap: usize,
    map: HashMap<FrontKey, CacheEntry>,
    order: VecDeque<(FrontKey, u64)>,
    clock: u64,
}

struct CacheEntry {
    reply: Reply,
    stamp: u64,
}

impl Lru {
    fn new(cap: usize) -> Lru {
        Lru { cap, map: HashMap::new(), order: VecDeque::new(), clock: 0 }
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn get(&mut self, key: &FrontKey) -> Option<Reply> {
        if self.cap == 0 {
            return None;
        }
        self.clock += 1;
        let clock = self.clock;
        let entry = self.map.get_mut(key)?;
        entry.stamp = clock;
        let reply = entry.reply.clone();
        self.order.push_back((key.clone(), clock));
        self.compact_if_bloated();
        Some(reply)
    }

    fn insert(&mut self, key: FrontKey, reply: Reply) {
        if self.cap == 0 {
            return;
        }
        self.clock += 1;
        let clock = self.clock;
        self.map.insert(key.clone(), CacheEntry { reply, stamp: clock });
        self.order.push_back((key, clock));
        while self.map.len() > self.cap {
            // panic-ok: `order` holds one slot per live cache entry.
            let (k, s) = self.order.pop_front().expect("order covers every live entry");
            if self.map.get(&k).is_some_and(|e| e.stamp == s) {
                self.map.remove(&k);
            }
        }
        self.compact_if_bloated();
    }

    fn compact_if_bloated(&mut self) {
        if self.order.len() > self.cap.saturating_mul(8).max(64) {
            let map = &self.map;
            self.order.retain(|(k, s)| map.get(k).is_some_and(|e| e.stamp == *s));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Priority, ServeError};
    use std::time::Duration;

    fn front(cache_capacity: usize, coalesce: bool) -> Arc<Front> {
        Arc::new(Front::new(FrontPolicy { cache_capacity, coalesce, default_k: 5 }))
    }

    fn softmax(logits: &[f32]) -> Payload {
        Payload::Softmax { logits: logits.to_vec() }
    }

    fn reply(probs: &[f32]) -> Reply {
        Reply::Softmax { probs: probs.to_vec() }
    }

    #[test]
    fn coalesces_identical_requests_and_caches_the_result() {
        let f = front(16, true);
        let payload = softmax(&[1.0, 2.0, 3.0]);
        let leader = f.admit(&payload, &RequestOptions::default());
        let Admission::Execute(sink, leader_rx) = leader else {
            panic!("first arrival leads")
        };
        // Identical request differing only in tag + deadline: follower.
        let opts = RequestOptions {
            deadline: Some(Duration::from_secs(5)),
            client_tag: Some("other".into()),
            ..RequestOptions::default()
        };
        let Admission::Resolved(follower_rx) = f.admit(&payload, &opts) else {
            panic!("identical in-flight request coalesces")
        };
        sink.send(Ok(reply(&[0.1, 0.2, 0.7]))).unwrap();
        let a = leader_rx.recv().unwrap().unwrap();
        let b = follower_rx.recv().unwrap().unwrap();
        assert_eq!(a, b, "fanned-out reply identical to the leader's");
        // Third arrival after completion: served from the cache.
        let Admission::Resolved(rx) = f.admit(&payload, &RequestOptions::default()) else {
            panic!("completed result is cached")
        };
        assert_eq!(rx.recv().unwrap().unwrap(), a, "cached reply identical");
        assert_eq!(
            f.stats(),
            FrontStats { hits: 1, misses: 1, coalesced: 1, entries: 1 }
        );
    }

    #[test]
    fn differing_k_or_priority_never_share_a_key() {
        let f = front(16, true);
        let payload = Payload::DecodeTopK { hidden: vec![1.0, 2.0] };
        let keep: Vec<Admission> = [
            RequestOptions { k: Some(3), ..RequestOptions::default() },
            RequestOptions { k: Some(4), ..RequestOptions::default() },
            RequestOptions { priority: Priority::Batch, k: Some(3), ..RequestOptions::default() },
        ]
        .iter()
        .map(|opts| {
            let a = f.admit(&payload, opts);
            assert!(matches!(a, Admission::Execute(..)), "distinct key executes");
            a
        })
        .collect();
        assert_eq!(f.stats().coalesced, 0);
        assert_eq!(f.stats().misses, 3);
        drop(keep);
    }

    #[test]
    fn explicit_default_k_coalesces_with_unset_k() {
        // `k: None` resolves to default_k (5): same effective request.
        let f = front(16, true);
        let payload = Payload::DecodeTopK { hidden: vec![4.0] };
        let lead = f.admit(&payload, &RequestOptions::default());
        assert!(matches!(lead, Admission::Execute(..)));
        let follow = f.admit(&payload, &RequestOptions::with_k(5));
        assert!(matches!(follow, Admission::Resolved(_)), "k=5 == resolved default");
        assert_eq!(f.stats().coalesced, 1);
        drop(lead);
    }

    #[test]
    fn stateful_payloads_always_bypass() {
        let f = front(16, true);
        let step = Payload::LmStep { session: 1, token: 7 };
        for _ in 0..2 {
            assert!(
                matches!(f.admit(&step, &RequestOptions::default()), Admission::Execute(..)),
                "identical LmSteps are different computations"
            );
        }
        assert_eq!(f.stats(), FrontStats::default(), "bypass leaves no trace");
    }

    #[test]
    fn errors_fan_out_but_are_not_cached() {
        let f = front(16, true);
        let payload = softmax(&[9.0]);
        let Admission::Execute(sink, leader_rx) = f.admit(&payload, &RequestOptions::default())
        else {
            panic!("leads")
        };
        let Admission::Resolved(follower_rx) = f.admit(&payload, &RequestOptions::default())
        else {
            panic!("coalesces")
        };
        let _ = sink.send(Err(ServeError::invalid("bad width")));
        assert_eq!(leader_rx.recv().unwrap().unwrap_err().message, "bad width");
        assert_eq!(
            follower_rx.recv().unwrap().unwrap_err().message,
            "bad width",
            "followers share the leader's typed error"
        );
        // The failure is not cached: the next arrival executes again.
        assert!(matches!(
            f.admit(&payload, &RequestOptions::default()),
            Admission::Execute(..)
        ));
        assert_eq!(f.stats().entries, 0);
        assert_eq!(f.stats().misses, 2);
    }

    #[test]
    fn dropped_leader_releases_followers_and_the_key() {
        let f = front(16, true);
        let payload = softmax(&[3.0]);
        let Admission::Execute(sink, leader_rx) = f.admit(&payload, &RequestOptions::default())
        else {
            panic!("leads")
        };
        let Admission::Resolved(follower_rx) = f.admit(&payload, &RequestOptions::default())
        else {
            panic!("coalesces")
        };
        drop(sink); // leader torn down unanswered (e.g. shutdown)
        assert!(leader_rx.recv().is_err(), "leader channel disconnects");
        assert!(follower_rx.recv().is_err(), "follower channel disconnects");
        // The key is free again: new arrivals elect a fresh leader
        // instead of parking behind a dead one.
        assert!(matches!(
            f.admit(&payload, &RequestOptions::default()),
            Admission::Execute(..)
        ));
    }

    #[test]
    fn coalesce_off_still_caches_and_vice_versa() {
        // coalesce=false: concurrent identicals both execute, but a
        // completed result still serves later hits.
        let f = front(16, false);
        let payload = softmax(&[5.0]);
        let Admission::Execute(sink, _rx) = f.admit(&payload, &RequestOptions::default())
        else {
            panic!("executes")
        };
        assert!(matches!(
            f.admit(&payload, &RequestOptions::default()),
            Admission::Execute(..)
        ));
        sink.send(Ok(reply(&[1.0]))).unwrap();
        assert!(matches!(
            f.admit(&payload, &RequestOptions::default()),
            Admission::Resolved(_)
        ));
        assert_eq!(f.stats().hits, 1);

        // cache=0 with coalescing on: in-flight dedupe works, nothing
        // is retained after completion.
        let f = front(0, true);
        let Admission::Execute(sink, _rx) = f.admit(&payload, &RequestOptions::default())
        else {
            panic!("executes")
        };
        assert!(matches!(
            f.admit(&payload, &RequestOptions::default()),
            Admission::Resolved(_)
        ));
        sink.send(Ok(reply(&[1.0]))).unwrap();
        assert!(matches!(
            f.admit(&payload, &RequestOptions::default()),
            Admission::Execute(..)
        ));
        assert_eq!(f.stats().entries, 0);
    }

    #[test]
    fn negative_zero_and_nan_bits_are_distinct_keys() {
        let f = front(16, true);
        let a = f.admit(&softmax(&[0.0]), &RequestOptions::default());
        let b = f.admit(&softmax(&[-0.0]), &RequestOptions::default());
        assert!(matches!(a, Admission::Execute(..)));
        assert!(matches!(b, Admission::Execute(..)), "-0.0 is a different request");
        drop((a, b));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut lru = Lru::new(2);
        let key = |v: f32| FrontKey {
            payload: KeyPayload::Softmax(vec![v.to_bits()]),
            k: 5,
            priority: 0,
            temperature: 1.0f32.to_bits(),
            seed: None,
        };
        lru.insert(key(1.0), reply(&[1.0]));
        lru.insert(key(2.0), reply(&[2.0]));
        assert!(lru.get(&key(1.0)).is_some(), "touch 1 → 2 is now LRU");
        lru.insert(key(3.0), reply(&[3.0]));
        assert_eq!(lru.len(), 2);
        assert!(lru.get(&key(2.0)).is_none(), "LRU entry evicted");
        assert!(lru.get(&key(1.0)).is_some());
        assert!(lru.get(&key(3.0)).is_some());
        // Churn far past the compaction bound: the order queue stays
        // bounded relative to the map.
        for i in 0..10_000 {
            lru.insert(key(i as f32), reply(&[i as f32]));
            let _ = lru.get(&key(i as f32));
        }
        assert_eq!(lru.len(), 2);
        assert!(lru.order.len() <= 64 + 2, "lazy queue compacted: {}", lru.order.len());
    }
}
