//! Continuous dynamic batcher.
//!
//! Requests are admitted into a bounded queue (backpressure beyond
//! capacity) and coalesced into batches by a vLLM-style policy:
//!
//! * a batch closes as soon as `max_batch` same-class requests are
//!   waiting, or
//! * when the most urgent waiting request has aged past its flush
//!   bound — `max_wait`, tightened by the request's own deadline when
//!   that is sooner (see [`Request::flush_at`]) — whichever comes
//!   first;
//! * among queues that are due, the one holding the most urgent
//!   [`Priority`] waiter flushes first (ties broken by earliest flush
//!   bound), and when a queue holds more waiters than `max_batch`,
//!   interactive requests board the batch ahead of batch-priority
//!   ones (FIFO within each priority);
//! * requests of different [`BatchClass`]es never mix (they execute
//!   different artifacts);
//! * batches are padded up to the artifact bucket sizes by the executor
//!   (see [`super::executor`]), so the batcher only bounds, never pads.
//!
//! On the host backend a formed batch becomes the **rows dimension** of
//! the executor's batch×shard grid dispatch: `max_batch` therefore
//! bounds rows-per-grid (further capped by `grid_rows`), and a larger
//! `max_wait` trades first-request latency for wider grids and better
//! pool occupancy.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::{BatchClass, Priority, Request};

/// Batch-formation policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: Duration::from_millis(2), queue_capacity: 1024 }
    }
}

/// Why a batch was closed (metrics / tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    Full,
    Deadline,
    Shutdown,
}

struct State {
    queues: HashMap<BatchClass, VecDeque<Request>>,
    total: usize,
    shutdown: bool,
}

/// The shared batching queue.
pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<State>,
    /// Wakes batch-forming workers when requests arrive / shutdown.
    arrived: Condvar,
    /// Wakes producers when capacity frees up.
    freed: Condvar,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        assert!(policy.max_batch > 0 && policy.queue_capacity >= policy.max_batch);
        Batcher {
            policy,
            state: Mutex::new(State {
                queues: HashMap::new(),
                total: 0,
                shutdown: false,
            }),
            arrived: Condvar::new(),
            freed: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Admit a request, blocking while the queue is at capacity
    /// (backpressure).  Returns `Err(request)` after shutdown.
    pub fn submit(&self, request: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return Err(request);
            }
            if st.total < self.policy.queue_capacity {
                st.queues.entry(request.class()).or_default().push_back(request);
                st.total += 1;
                drop(st);
                self.arrived.notify_one();
                return Ok(());
            }
            st = self.freed.wait(st).unwrap();
        }
    }

    /// Non-blocking admission (the server's overload path → 503-style
    /// rejection instead of unbounded latency).
    pub fn try_submit(&self, request: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown || st.total >= self.policy.queue_capacity {
            return Err(request);
        }
        st.queues.entry(request.class()).or_default().push_back(request);
        st.total += 1;
        drop(st);
        self.arrived.notify_one();
        Ok(())
    }

    /// Pull the next batch, blocking until one is ready per the policy.
    /// Returns `None` only at shutdown with empty queues.
    pub fn next_batch(&self) -> Option<(BatchClass, Vec<Request>, FlushReason)> {
        let mut st = self.state.lock().unwrap();
        loop {
            // A full batch in any class flushes immediately; among
            // several full queues the most urgent one goes first.
            let mut full: Option<((u8, Instant), BatchClass)> = None;
            for (&c, q) in st.queues.iter() {
                if q.len() < self.policy.max_batch {
                    continue;
                }
                let key = queue_urgency(q, self.policy.max_wait);
                if more_urgent(&full, key) {
                    full = Some((key, c));
                }
            }
            if let Some((_, class)) = full {
                return Some((class, self.take(&mut st, class), FlushReason::Full));
            }

            // Otherwise flush whichever queue is past its flush bound,
            // most urgent (priority, then earliest bound) first.
            // Priority only orders selection among DUE queues; the
            // sleep target must be the earliest bound across ALL
            // queues, or a deadline-tightened waiter in a
            // lower-priority class would expire unserved while the
            // worker slept toward a higher-priority queue's later
            // bound.
            let now = Instant::now();
            let mut best_due: Option<((u8, Instant), BatchClass)> = None;
            let mut best_any: Option<((u8, Instant), BatchClass)> = None;
            let mut next_wake: Option<Instant> = None;
            for (&c, q) in st.queues.iter() {
                if q.is_empty() {
                    continue;
                }
                let key = queue_urgency(q, self.policy.max_wait);
                if more_urgent(&best_any, key) {
                    best_any = Some((key, c));
                }
                if key.1 <= now && more_urgent(&best_due, key) {
                    best_due = Some((key, c));
                }
                next_wake = Some(match next_wake {
                    Some(w) if w <= key.1 => w,
                    _ => key.1,
                });
            }
            if let Some((_, class)) = best_due {
                return Some((class, self.take(&mut st, class), FlushReason::Deadline));
            }
            match best_any {
                Some((_, class)) => {
                    if st.shutdown {
                        return Some((class, self.take(&mut st, class), FlushReason::Shutdown));
                    }
                    // `wake > now` here: nothing was due, so every
                    // queue's bound lies in the future.
                    let wake = next_wake.expect("a nonempty queue exists");
                    let (guard, _) = self.arrived.wait_timeout(st, wake - now).unwrap();
                    st = guard;
                }
                None => {
                    if st.shutdown {
                        return None;
                    }
                    st = self.arrived.wait(st).unwrap();
                }
            }
        }
    }

    /// Drain up to `max_batch` requests from `class`'s queue.
    /// Interactive requests board ahead of batch-priority ones; order
    /// within each priority stays FIFO.  Requests left behind keep
    /// that (priority, FIFO) order for the next flush.
    fn take(&self, st: &mut State, class: BatchClass) -> Vec<Request> {
        let q = st.queues.get_mut(&class).expect("class must exist");
        let drained: Vec<Request> = q.drain(..).collect();
        let (mut batch, low): (Vec<Request>, Vec<Request>) = drained
            .into_iter()
            .partition(|r| r.options.priority == Priority::Interactive);
        batch.extend(low);
        let rest = batch.split_off(batch.len().min(self.policy.max_batch));
        for r in rest.into_iter().rev() {
            q.push_front(r);
        }
        st.total -= batch.len();
        self.freed.notify_all();
        batch
    }

    /// Current queued request count (metrics).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().total
    }

    /// Per-class queued request counts (the `stats` RPC's
    /// `queue_depths` field), in [`BatchClass::ALL`] order.
    pub fn class_depths(&self) -> Vec<(BatchClass, usize)> {
        let st = self.state.lock().unwrap();
        BatchClass::ALL
            .iter()
            .map(|&c| (c, st.queues.get(&c).map_or(0, |q| q.len())))
            .collect()
    }

    /// Begin shutdown: queued requests still drain via [`next_batch`].
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.arrived.notify_all();
        self.freed.notify_all();
    }
}

/// Does `key` outrank the current best candidate?
fn more_urgent(best: &Option<((u8, Instant), BatchClass)>, key: (u8, Instant)) -> bool {
    match best {
        None => true,
        Some((k, _)) => key < *k,
    }
}

/// A queue's urgency key: (rank of its most urgent waiter's priority,
/// earliest deadline-tightened flush instant).  Lower sorts first.
///
/// Deliberately a full scan: O(queued requests) per `next_batch`
/// wake, bounded by `queue_capacity`.  Maintaining the key
/// incrementally would have to survive `take`'s priority-partitioned
/// removal (the minimum can leave with any flush), which costs more
/// complexity than the scan at the depths this batcher is configured
/// for — revisit if `queue_capacity` grows beyond a few thousand.
fn queue_urgency(q: &VecDeque<Request>, max_wait: Duration) -> (u8, Instant) {
    debug_assert!(!q.is_empty(), "urgency of an empty queue");
    let mut prio = u8::MAX;
    let mut earliest: Option<Instant> = None;
    for r in q {
        prio = prio.min(r.options.priority.rank());
        let at = r.flush_at(max_wait);
        earliest = Some(match earliest {
            Some(e) if e <= at => e,
            _ => at,
        });
    }
    (prio, earliest.expect("nonempty queue"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Payload, RequestOptions};
    use crate::exec::channel::oneshot;
    use std::sync::Arc;

    fn req(id: u64, class: BatchClass) -> Request {
        req_opts(id, class, RequestOptions::default())
    }

    fn req_opts(id: u64, class: BatchClass, opts: RequestOptions) -> Request {
        let (tx, _rx) = oneshot();
        let payload = match class {
            BatchClass::Softmax => Payload::Softmax { logits: vec![id as f32] },
            BatchClass::Decode => Payload::DecodeTopK { hidden: vec![id as f32] },
            BatchClass::LmStep => Payload::LmStep { session: id, token: 0 },
        };
        Request::with_options(id, payload, opts, tx)
    }

    fn batcher(max_batch: usize, max_wait_ms: u64, cap: usize) -> Batcher {
        Batcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_capacity: cap,
        })
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = batcher(4, 10_000, 64);
        for i in 0..4 {
            b.submit(req(i, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        }
        let t0 = Instant::now();
        let (class, batch, reason) = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_millis(100), "must not wait for deadline");
        assert_eq!(class, BatchClass::Softmax);
        assert_eq!(batch.len(), 4);
        assert_eq!(reason, FlushReason::Full);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "FIFO order");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = batcher(16, 20, 64);
        b.submit(req(1, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let (class, batch, reason) = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(class, BatchClass::Decode);
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Deadline);
        assert!(waited >= Duration::from_millis(15), "honored max_wait: {waited:?}");
    }

    #[test]
    fn classes_never_mix() {
        let b = batcher(8, 5, 64);
        for i in 0..3 {
            b.submit(req(i, BatchClass::Softmax)).map_err(|_| ()).unwrap();
            b.submit(req(100 + i, BatchClass::Decode)).map_err(|_| ()).unwrap();
        }
        let (c1, b1, _) = b.next_batch().unwrap();
        let (c2, b2, _) = b.next_batch().unwrap();
        assert_ne!(c1, c2);
        assert!(b1.iter().all(|r| r.class() == c1));
        assert!(b2.iter().all(|r| r.class() == c2));
    }

    #[test]
    fn try_submit_rejects_when_full() {
        let b = batcher(2, 10_000, 2);
        assert!(b.try_submit(req(0, BatchClass::Softmax)).is_ok());
        assert!(b.try_submit(req(1, BatchClass::Softmax)).is_ok());
        assert!(b.try_submit(req(2, BatchClass::Softmax)).is_err(), "over capacity");
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn backpressure_unblocks_after_drain() {
        let b = Arc::new(batcher(2, 10_000, 2));
        b.submit(req(0, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.submit(req(2, BatchClass::Softmax)).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        let (_, batch, _) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t.join().unwrap(), "blocked submit completed after drain");
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = batcher(16, 10_000, 64);
        b.submit(req(7, BatchClass::LmStep)).map_err(|_| ()).unwrap();
        b.shutdown();
        let (_, batch, reason) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Shutdown);
        assert!(b.next_batch().is_none());
        assert!(b.submit(req(8, BatchClass::LmStep)).is_err(), "no admission after shutdown");
    }

    #[test]
    fn oldest_class_flushes_first_on_deadline() {
        let b = batcher(16, 30, 64);
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        b.submit(req(2, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let (class, _, _) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Softmax, "older waiter wins");
    }

    #[test]
    fn interactive_boards_before_batch_priority() {
        // 6 waiters, max_batch 4: the two interactive requests that
        // arrived *last* still board the first flush; FIFO is kept
        // within each priority class, and the leftovers flush next.
        let b = batcher(4, 5, 64);
        let batch_opts =
            RequestOptions { priority: Priority::Batch, ..RequestOptions::default() };
        for id in 0..4u64 {
            b.submit(req_opts(id, BatchClass::Softmax, batch_opts.clone()))
                .map_err(|_| ())
                .unwrap();
        }
        for id in 4..6u64 {
            b.submit(req(id, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        }
        let (_, first, _) = b.next_batch().unwrap();
        let ids: Vec<u64> = first.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![4, 5, 0, 1], "interactive first, FIFO within priority");
        let (_, second, _) = b.next_batch().unwrap();
        let ids: Vec<u64> = second.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3], "leftovers keep their order");
    }

    #[test]
    fn interactive_class_preempts_older_batch_class_when_both_due() {
        // Both queues are past their flush bound; the class holding an
        // interactive waiter flushes first even though the
        // batch-priority class has the older request.
        let b = batcher(16, 5, 64);
        let batch_opts =
            RequestOptions { priority: Priority::Batch, ..RequestOptions::default() };
        b.submit(req_opts(1, BatchClass::Softmax, batch_opts)).map_err(|_| ()).unwrap();
        b.submit(req(2, BatchClass::Decode)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(10)); // both now due
        let (class, _, _) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Decode, "interactive class wins among due queues");
        let (class, _, _) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Softmax);
    }

    #[test]
    fn tight_deadline_flushes_before_max_wait() {
        // max_wait is 10 s, but the request carries a 10 ms deadline:
        // the flush bound tightens to the deadline instead of parking
        // the worker for the full max_wait.
        let b = batcher(16, 10_000, 64);
        let opts = RequestOptions {
            deadline: Some(Duration::from_millis(10)),
            ..RequestOptions::default()
        };
        b.submit(req_opts(1, BatchClass::Decode, opts)).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let (class, batch, reason) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Decode);
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Deadline);
        assert!(
            t0.elapsed() < Duration::from_millis(5_000),
            "deadline-tightened flush, not max_wait: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn tight_deadline_in_lower_priority_class_wakes_the_worker() {
        // Sleep-target regression: the worker's wake-up must follow
        // the earliest flush bound across ALL queues.  Here the
        // higher-priority (interactive) class has a 10 s bound while a
        // batch-priority class carries a 20 ms deadline — the worker
        // must not sleep toward the interactive bound and let the
        // deadline expire unserved.
        let b = batcher(16, 10_000, 64);
        b.submit(req(1, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let opts = RequestOptions {
            priority: Priority::Batch,
            deadline: Some(Duration::from_millis(20)),
            ..RequestOptions::default()
        };
        b.submit(req_opts(2, BatchClass::Softmax, opts)).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let (class, _, reason) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Softmax, "tight-deadline class flushes first");
        assert_eq!(reason, FlushReason::Deadline);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "woke at the ~20 ms bound, not max_wait: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn class_depths_reports_per_class() {
        let b = batcher(16, 10_000, 64);
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(2, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(3, BatchClass::LmStep)).map_err(|_| ()).unwrap();
        let depths = b.class_depths();
        assert_eq!(depths.len(), BatchClass::ALL.len());
        let get = |c: BatchClass| depths.iter().find(|(d, _)| *d == c).unwrap().1;
        assert_eq!(get(BatchClass::Softmax), 2);
        assert_eq!(get(BatchClass::Decode), 0);
        assert_eq!(get(BatchClass::LmStep), 1);
    }
}
