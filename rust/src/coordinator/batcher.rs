//! Continuous dynamic batcher with per-class admission control.
//!
//! Requests are admitted into a bounded queue (backpressure beyond
//! capacity) and coalesced into batches by a vLLM-style policy:
//!
//! * a batch closes as soon as `max_batch` same-class requests are
//!   waiting, or
//! * when the most urgent waiting request has aged past its flush
//!   bound — `max_wait`, tightened by the request's own deadline when
//!   that is sooner (see [`Request::flush_at`]) — whichever comes
//!   first;
//! * among queues that are due, the one holding the most urgent
//!   [`Priority`] waiter flushes first (ties broken by earliest flush
//!   bound), and when a queue holds more waiters than `max_batch`,
//!   interactive requests board the batch ahead of batch-priority
//!   ones (FIFO within each priority), with a starvation guard: a
//!   batch-priority request passed over [`PROMOTE_AFTER_SKIPS`] times
//!   boards like interactive work;
//! * requests of different [`BatchClass`]es never mix (they execute
//!   different artifacts);
//! * batches are padded up to the artifact bucket sizes by the executor
//!   (see [`super::executor`]), so the batcher only bounds, never pads.
//!
//! **Admission control** (PR 6): beyond the global `queue_capacity`,
//! each [`Priority`] lane can carry its own quota
//! ([`BatchPolicy::interactive_cap`] / [`BatchPolicy::batch_cap`]).  A
//! request whose lane is at quota is rejected immediately with a typed
//! [`AdmitError::Overloaded`] — it never blocks — so a batch backlog
//! can no longer consume the whole queue and stall interactive
//! admission behind the `freed` condvar.  The global capacity keeps
//! the legacy blocking-backpressure behavior on [`Batcher::submit`],
//! now deadline-aware: a producer blocked on a full queue wakes when
//! its request's deadline passes and gets [`AdmitError::Expired`]
//! instead of enqueueing doomed work.  Queued requests whose deadline
//! expires before a worker picks them up are **shed**: answered with a
//! typed `deadline_exceeded` and dropped before they reach the
//! executor (`coordinator.admission.shed`), freeing their admission
//! slots for live work.
//!
//! On the host backend a formed batch becomes the **rows dimension** of
//! the executor's batch×shard grid dispatch: `max_batch` therefore
//! bounds rows-per-grid (further capped by `grid_rows`), and a larger
//! `max_wait` trades first-request latency for wider grids and better
//! pool occupancy.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::{BatchClass, Priority, Request, ServeError};
use crate::metrics;

/// Flushes that may pass over a batch-priority request before the
/// starvation guard promotes it to board ahead of newer interactive
/// arrivals (see [`Batcher::take`]).
pub const PROMOTE_AFTER_SKIPS: u32 = 4;

/// Batch-formation and admission policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
    /// Queued-request quota for the interactive lane; `0` = no
    /// dedicated cap (bounded by `queue_capacity` alone).  A request
    /// over its lane quota is rejected typed, never blocked.
    pub interactive_cap: usize,
    /// Queued-request quota for the batch lane; `0` = no dedicated cap.
    pub batch_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_capacity: 1024,
            interactive_cap: 0,
            batch_cap: 0,
        }
    }
}

impl BatchPolicy {
    /// The admission quota for `lane` (`0` = uncapped).
    fn lane_cap(&self, lane: Priority) -> usize {
        match lane {
            Priority::Interactive => self.interactive_cap,
            Priority::Batch => self.batch_cap,
        }
    }
}

/// Why a request was refused admission.  Each variant hands the
/// request back so the caller can answer its reply channel with the
/// matching typed [`ServeError`] (fanning it out to any coalesced
/// followers) instead of silently dropping it.
pub enum AdmitError {
    /// The request's priority lane (or, on the non-blocking path, the
    /// whole queue) is at capacity.
    Overloaded { request: Request, lane: Priority },
    /// The batcher is draining; no new admissions.
    ShuttingDown(Request),
    /// The request's deadline expired before admission — on entry, or
    /// while blocked on global-capacity backpressure.
    Expired(Request),
}

impl AdmitError {
    /// Recover the rejected request (for replying on its channel).
    pub fn into_request(self) -> Request {
        match self {
            AdmitError::Overloaded { request, .. } => request,
            AdmitError::ShuttingDown(request) => request,
            AdmitError::Expired(request) => request,
        }
    }
}

/// Why a batch was closed (metrics / tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    Full,
    Deadline,
    Shutdown,
}

struct State {
    queues: HashMap<BatchClass, VecDeque<Request>>,
    total: usize,
    /// Queued requests per [`Priority`] lane, indexed by
    /// [`Priority::rank`] — the lane-quota accounting.
    per_lane: [usize; 2],
    shutdown: bool,
}

impl State {
    fn lane_count(&self, lane: Priority) -> usize {
        self.per_lane[lane.rank() as usize]
    }

    fn enqueue(&mut self, request: Request) {
        self.per_lane[request.options.priority.rank() as usize] += 1;
        self.total += 1;
        self.queues.entry(request.class()).or_default().push_back(request);
    }

    /// Account one request leaving the queue (batched or shed).
    fn departed(&mut self, lane: Priority) {
        self.per_lane[lane.rank() as usize] -= 1;
        self.total -= 1;
    }
}

/// The shared batching queue.
pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<State>,
    /// Wakes batch-forming workers when requests arrive / shutdown.
    arrived: Condvar,
    /// Wakes producers when capacity frees up.
    freed: Condvar,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        assert!(policy.max_batch > 0 && policy.queue_capacity >= policy.max_batch);
        Batcher {
            policy,
            state: Mutex::new(State {
                queues: HashMap::new(),
                total: 0,
                per_lane: [0; 2],
                shutdown: false,
            }),
            arrived: Condvar::new(),
            freed: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Admit a request, blocking while the queue is at its **global**
    /// capacity (backpressure).  Lane quotas never block: a request
    /// over its lane's cap is rejected immediately with
    /// [`AdmitError::Overloaded`], so one lane's backlog cannot stall
    /// the other's admission.  The capacity wait is deadline-aware —
    /// a blocked producer whose request expires gets
    /// [`AdmitError::Expired`] instead of enqueueing doomed work.
    pub fn submit(&self, request: Request) -> Result<(), AdmitError> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return Err(AdmitError::ShuttingDown(request));
            }
            if request.expired(Instant::now()) {
                return Err(AdmitError::Expired(request));
            }
            let lane = request.options.priority;
            let cap = self.policy.lane_cap(lane);
            if cap != 0 && st.lane_count(lane) >= cap {
                return Err(AdmitError::Overloaded { request, lane });
            }
            if st.total < self.policy.queue_capacity {
                st.enqueue(request);
                drop(st);
                self.arrived.notify_one();
                return Ok(());
            }
            st = match request.deadline {
                // Bound the wait by the request's own deadline: on a
                // timed-out wake the loop's expiry check rejects it.
                Some(d) => {
                    let now = Instant::now();
                    if d <= now {
                        return Err(AdmitError::Expired(request));
                    }
                    self.freed.wait_timeout(st, d - now).unwrap().0
                }
                None => self.freed.wait(st).unwrap(),
            };
        }
    }

    /// Non-blocking admission (the server's overload path → 503-style
    /// rejection instead of unbounded latency).  Global capacity
    /// rejects typed here instead of blocking.
    pub fn try_submit(&self, request: Request) -> Result<(), AdmitError> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(AdmitError::ShuttingDown(request));
        }
        if request.expired(Instant::now()) {
            return Err(AdmitError::Expired(request));
        }
        let lane = request.options.priority;
        let cap = self.policy.lane_cap(lane);
        if (cap != 0 && st.lane_count(lane) >= cap) || st.total >= self.policy.queue_capacity {
            return Err(AdmitError::Overloaded { request, lane });
        }
        st.enqueue(request);
        drop(st);
        self.arrived.notify_one();
        Ok(())
    }

    /// Pull the next batch, blocking until one is ready per the policy.
    /// Returns `None` only at shutdown with empty queues.
    pub fn next_batch(&self) -> Option<(BatchClass, Vec<Request>, FlushReason)> {
        let mut st = self.state.lock().unwrap();
        loop {
            // Deadline-aware shedding: answer queued requests whose
            // deadline already passed with a typed error and drop them
            // here, before they burn a batch slot and a memory sweep
            // in the executor.
            self.shed_expired(&mut st, Instant::now());

            // A full batch in any class flushes immediately; among
            // several full queues the most urgent one goes first.
            let mut full: Option<((u8, Instant), BatchClass)> = None;
            for (&c, q) in st.queues.iter() {
                if q.len() < self.policy.max_batch {
                    continue;
                }
                let key = queue_urgency(q, self.policy.max_wait);
                if more_urgent(&full, key) {
                    full = Some((key, c));
                }
            }
            if let Some((_, class)) = full {
                return Some((class, self.take(&mut st, class), FlushReason::Full));
            }

            // Otherwise flush whichever queue is past its flush bound,
            // most urgent (priority, then earliest bound) first.
            // Priority only orders selection among DUE queues; the
            // sleep target must be the earliest bound across ALL
            // queues, or a deadline-tightened waiter in a
            // lower-priority class would expire unserved while the
            // worker slept toward a higher-priority queue's later
            // bound.
            let now = Instant::now();
            let mut best_due: Option<((u8, Instant), BatchClass)> = None;
            let mut best_any: Option<((u8, Instant), BatchClass)> = None;
            let mut next_wake: Option<Instant> = None;
            for (&c, q) in st.queues.iter() {
                if q.is_empty() {
                    continue;
                }
                let key = queue_urgency(q, self.policy.max_wait);
                if more_urgent(&best_any, key) {
                    best_any = Some((key, c));
                }
                if key.1 <= now && more_urgent(&best_due, key) {
                    best_due = Some((key, c));
                }
                next_wake = Some(match next_wake {
                    Some(w) if w <= key.1 => w,
                    _ => key.1,
                });
            }
            if let Some((_, class)) = best_due {
                return Some((class, self.take(&mut st, class), FlushReason::Deadline));
            }
            match best_any {
                Some((_, class)) => {
                    if st.shutdown {
                        return Some((class, self.take(&mut st, class), FlushReason::Shutdown));
                    }
                    // panic-ok: best_any is Some, so a nonempty queue
                    // exists and produced a wake bound (`wake > now`
                    // here: nothing was due yet).
                    let wake = next_wake.expect("a nonempty queue exists");
                    let (guard, _) = self.arrived.wait_timeout(st, wake - now).unwrap();
                    st = guard;
                }
                None => {
                    if st.shutdown {
                        return None;
                    }
                    st = self.arrived.wait(st).unwrap();
                }
            }
        }
    }

    /// Shed queued requests whose deadline has already passed: each is
    /// answered `deadline_exceeded` on its reply channel, counted on
    /// `coordinator.admission.shed`, and its admission slots (lane +
    /// global) are freed for live work.  The scan itself is O(queued)
    /// like every `next_batch` wake; queues are only rebuilt when they
    /// actually hold expired work.
    fn shed_expired(&self, st: &mut State, now: Instant) {
        let mut shed: Vec<Request> = Vec::new();
        for q in st.queues.values_mut() {
            if !q.iter().any(|r| r.expired(now)) {
                continue; // common case: nothing to rebuild
            }
            let mut keep = VecDeque::with_capacity(q.len());
            for r in q.drain(..) {
                if r.expired(now) {
                    shed.push(r);
                } else {
                    keep.push_back(r);
                }
            }
            *q = keep;
        }
        if shed.is_empty() {
            return;
        }
        metrics::global().counter("coordinator.admission.shed").add(shed.len() as u64);
        for r in shed {
            st.departed(r.options.priority);
            let _ = r.reply.send(Err(ServeError::deadline(
                "deadline expired while queued (shed before execution)",
            )));
        }
        self.freed.notify_all();
    }

    /// Drain up to `max_batch` requests from `class`'s queue.
    /// Interactive requests board ahead of batch-priority ones (FIFO
    /// within each priority), and a batch-priority request passed over
    /// [`PROMOTE_AFTER_SKIPS`] times boards like interactive work —
    /// the starvation guard against a continuous interactive trickle.
    ///
    /// Only the queue prefix up to the last boarding request is
    /// touched: requests beyond it keep their positions, so the common
    /// homogeneous-priority flush pops exactly `max_batch` items
    /// instead of draining and re-pushing the whole queue.
    fn take(&self, st: &mut State, class: BatchClass) -> Vec<Request> {
        let max = self.policy.max_batch;
        // panic-ok: every BatchClass is seeded into `queues` at startup.
        let q = st.queues.get_mut(&class).expect("class must exist");
        let batch: Vec<Request> = if q.len() <= max {
            // Everything boards — order the batch (priority, FIFO).
            let (mut high, low): (Vec<Request>, Vec<Request>) =
                q.drain(..).partition(boards);
            high.extend(low);
            high
        } else {
            // Oversubscribed: seat boarding-priority waiters first
            // (stop counting once a full batch of them exists), fill
            // the rest with the earliest others.
            let mut high_want = 0usize;
            for r in q.iter() {
                if boards(r) {
                    high_want += 1;
                    if high_want == max {
                        break;
                    }
                }
            }
            let low_want = max - high_want;
            let mut high_b: Vec<Request> = Vec::with_capacity(high_want);
            let mut low_b: Vec<Request> = Vec::with_capacity(low_want);
            let mut passed_over: Vec<Request> = Vec::new();
            while high_b.len() < high_want || low_b.len() < low_want {
                // panic-ok: high_want + low_want ≤ q.len() by the count above.
                let mut r = q.pop_front().expect("boarding counts bound the walk");
                if boards(&r) && high_b.len() < high_want {
                    high_b.push(r);
                } else if !boards(&r) && low_b.len() < low_want {
                    low_b.push(r);
                } else {
                    // Left behind while later arrivals board: one step
                    // closer to starvation-guard promotion.
                    r.boarding_skips += 1;
                    passed_over.push(r);
                }
            }
            for r in passed_over.into_iter().rev() {
                q.push_front(r);
            }
            high_b.extend(low_b);
            high_b
        };
        for r in &batch {
            st.departed(r.options.priority);
        }
        self.freed.notify_all();
        batch
    }

    /// Current queued request count (metrics).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().total
    }

    /// Per-class queued request counts (the `stats` RPC's
    /// `queue_depths` field), in [`BatchClass::ALL`] order.
    pub fn class_depths(&self) -> Vec<(BatchClass, usize)> {
        let st = self.state.lock().unwrap();
        BatchClass::ALL
            .iter()
            .map(|&c| (c, st.queues.get(&c).map_or(0, |q| q.len())))
            .collect()
    }

    /// Begin shutdown: queued requests still drain via [`next_batch`].
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.arrived.notify_all();
        self.freed.notify_all();
    }
}

/// Does this request board ahead of batch-priority work?  Interactive
/// requests always do; batch-priority requests do once the starvation
/// guard promotes them (passed over [`PROMOTE_AFTER_SKIPS`] flushes).
fn boards(r: &Request) -> bool {
    r.options.priority == Priority::Interactive || r.boarding_skips >= PROMOTE_AFTER_SKIPS
}

/// Does `key` outrank the current best candidate?
fn more_urgent(best: &Option<((u8, Instant), BatchClass)>, key: (u8, Instant)) -> bool {
    match best {
        None => true,
        Some((k, _)) => key < *k,
    }
}

/// A queue's urgency key: (rank of its most urgent waiter's priority,
/// earliest deadline-tightened flush instant).  Lower sorts first.
///
/// Deliberately a full scan: O(queued requests) per `next_batch`
/// wake, bounded by `queue_capacity`.  Maintaining the key
/// incrementally would have to survive `take`'s priority-partitioned
/// removal (the minimum can leave with any flush), which costs more
/// complexity than the scan at the depths this batcher is configured
/// for — revisit if `queue_capacity` grows beyond a few thousand.
fn queue_urgency(q: &VecDeque<Request>, max_wait: Duration) -> (u8, Instant) {
    debug_assert!(!q.is_empty(), "urgency of an empty queue");
    let mut prio = u8::MAX;
    let mut earliest: Option<Instant> = None;
    for r in q {
        prio = prio.min(r.options.priority.rank());
        let at = r.flush_at(max_wait);
        earliest = Some(match earliest {
            Some(e) if e <= at => e,
            _ => at,
        });
    }
    (prio, earliest.expect("nonempty queue")) // panic-ok: caller checked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Payload, RequestOptions};
    use crate::exec::channel::oneshot;
    use std::sync::Arc;

    fn req(id: u64, class: BatchClass) -> Request {
        req_opts(id, class, RequestOptions::default())
    }

    fn req_opts(id: u64, class: BatchClass, opts: RequestOptions) -> Request {
        let (req, _rx) = req_opts_rx(id, class, opts);
        req
    }

    fn req_opts_rx(
        id: u64,
        class: BatchClass,
        opts: RequestOptions,
    ) -> (Request, crate::exec::channel::OnceReceiver<crate::coordinator::ReplyResult>) {
        let (tx, rx) = oneshot();
        let payload = match class {
            BatchClass::Softmax => Payload::Softmax { logits: vec![id as f32] },
            BatchClass::Decode => Payload::DecodeTopK { hidden: vec![id as f32] },
            BatchClass::LmStep => Payload::LmStep { session: id, token: 0 },
        };
        (Request::with_options(id, payload, opts, tx), rx)
    }

    fn batcher(max_batch: usize, max_wait_ms: u64, cap: usize) -> Batcher {
        Batcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_capacity: cap,
            ..BatchPolicy::default()
        })
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = batcher(4, 10_000, 64);
        for i in 0..4 {
            b.submit(req(i, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        }
        let t0 = Instant::now();
        let (class, batch, reason) = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_millis(100), "must not wait for deadline");
        assert_eq!(class, BatchClass::Softmax);
        assert_eq!(batch.len(), 4);
        assert_eq!(reason, FlushReason::Full);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "FIFO order");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = batcher(16, 20, 64);
        b.submit(req(1, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let (class, batch, reason) = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(class, BatchClass::Decode);
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Deadline);
        assert!(waited >= Duration::from_millis(15), "honored max_wait: {waited:?}");
    }

    #[test]
    fn classes_never_mix() {
        let b = batcher(8, 5, 64);
        for i in 0..3 {
            b.submit(req(i, BatchClass::Softmax)).map_err(|_| ()).unwrap();
            b.submit(req(100 + i, BatchClass::Decode)).map_err(|_| ()).unwrap();
        }
        let (c1, b1, _) = b.next_batch().unwrap();
        let (c2, b2, _) = b.next_batch().unwrap();
        assert_ne!(c1, c2);
        assert!(b1.iter().all(|r| r.class() == c1));
        assert!(b2.iter().all(|r| r.class() == c2));
    }

    #[test]
    fn try_submit_rejects_when_full() {
        let b = batcher(2, 10_000, 2);
        assert!(b.try_submit(req(0, BatchClass::Softmax)).is_ok());
        assert!(b.try_submit(req(1, BatchClass::Softmax)).is_ok());
        assert!(b.try_submit(req(2, BatchClass::Softmax)).is_err(), "over capacity");
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn backpressure_unblocks_after_drain() {
        let b = Arc::new(batcher(2, 10_000, 2));
        b.submit(req(0, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.submit(req(2, BatchClass::Softmax)).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        let (_, batch, _) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t.join().unwrap(), "blocked submit completed after drain");
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = batcher(16, 10_000, 64);
        b.submit(req(7, BatchClass::LmStep)).map_err(|_| ()).unwrap();
        b.shutdown();
        let (_, batch, reason) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Shutdown);
        assert!(b.next_batch().is_none());
        assert!(b.submit(req(8, BatchClass::LmStep)).is_err(), "no admission after shutdown");
    }

    #[test]
    fn oldest_class_flushes_first_on_deadline() {
        let b = batcher(16, 30, 64);
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        b.submit(req(2, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let (class, _, _) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Softmax, "older waiter wins");
    }

    #[test]
    fn interactive_boards_before_batch_priority() {
        // 6 waiters, max_batch 4: the two interactive requests that
        // arrived *last* still board the first flush; FIFO is kept
        // within each priority class, and the leftovers flush next.
        let b = batcher(4, 5, 64);
        let batch_opts =
            RequestOptions { priority: Priority::Batch, ..RequestOptions::default() };
        for id in 0..4u64 {
            b.submit(req_opts(id, BatchClass::Softmax, batch_opts.clone()))
                .map_err(|_| ())
                .unwrap();
        }
        for id in 4..6u64 {
            b.submit(req(id, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        }
        let (_, first, _) = b.next_batch().unwrap();
        let ids: Vec<u64> = first.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![4, 5, 0, 1], "interactive first, FIFO within priority");
        let (_, second, _) = b.next_batch().unwrap();
        let ids: Vec<u64> = second.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3], "leftovers keep their order");
    }

    #[test]
    fn interactive_class_preempts_older_batch_class_when_both_due() {
        // Both queues are past their flush bound; the class holding an
        // interactive waiter flushes first even though the
        // batch-priority class has the older request.
        let b = batcher(16, 5, 64);
        let batch_opts =
            RequestOptions { priority: Priority::Batch, ..RequestOptions::default() };
        b.submit(req_opts(1, BatchClass::Softmax, batch_opts)).map_err(|_| ()).unwrap();
        b.submit(req(2, BatchClass::Decode)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(10)); // both now due
        let (class, _, _) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Decode, "interactive class wins among due queues");
        let (class, _, _) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Softmax);
    }

    #[test]
    fn tight_deadline_request_sheds_at_deadline_not_max_wait() {
        // max_wait is 10 s, but the request carries a 10 ms deadline:
        // the flush bound tightens to the deadline, and when the worker
        // wakes there the expired request is shed with a typed
        // `deadline_exceeded` instead of parking for the full max_wait
        // (or burning an executor slot on doomed work, which is what a
        // deadline-bound solo flush used to do).
        let b = Arc::new(batcher(16, 10_000, 64));
        let opts = RequestOptions {
            deadline: Some(Duration::from_millis(10)),
            ..RequestOptions::default()
        };
        let (r, rx) = req_opts_rx(1, BatchClass::Decode, opts);
        b.submit(r).map_err(|_| ()).unwrap();
        let b2 = b.clone();
        let worker = std::thread::spawn(move || b2.next_batch());
        let t0 = Instant::now();
        let reply = rx.recv_timeout(Duration::from_secs(5)).expect("shed reply arrives");
        assert!(
            t0.elapsed() < Duration::from_millis(5_000),
            "deadline-tightened wake, not max_wait: {:?}",
            t0.elapsed()
        );
        let err = reply.expect_err("shed requests get a typed error");
        assert_eq!(err.code, crate::coordinator::ErrorCode::DeadlineExceeded);
        assert_eq!(b.depth(), 0, "shed request freed its admission slot");
        b.shutdown();
        assert!(worker.join().unwrap().is_none(), "nothing left to flush");
    }

    #[test]
    fn tight_deadline_in_lower_priority_class_wakes_the_worker() {
        // Sleep-target regression: the worker's wake-up must follow
        // the earliest flush bound across ALL queues.  Here the
        // higher-priority (interactive) class has a 10 s bound while a
        // batch-priority class carries a 20 ms deadline — the worker
        // must not sleep toward the interactive bound and leave the
        // deadline waiter parked (it now sheds it, typed, at ~20 ms).
        let b = Arc::new(batcher(16, 10_000, 64));
        b.submit(req(1, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let opts = RequestOptions {
            priority: Priority::Batch,
            deadline: Some(Duration::from_millis(20)),
            ..RequestOptions::default()
        };
        let (r, rx) = req_opts_rx(2, BatchClass::Softmax, opts);
        b.submit(r).map_err(|_| ()).unwrap();
        let b2 = b.clone();
        let worker = std::thread::spawn(move || b2.next_batch());
        let t0 = Instant::now();
        let reply = rx.recv_timeout(Duration::from_secs(5)).expect("worker woke for it");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "woke at the ~20 ms bound, not max_wait: {:?}",
            t0.elapsed()
        );
        let err = reply.expect_err("expired waiter shed with a typed error");
        assert_eq!(err.code, crate::coordinator::ErrorCode::DeadlineExceeded);
        // The interactive decode request is untouched by the shed.
        b.shutdown();
        let (class, batch, _) = worker.join().unwrap().expect("decode drains at shutdown");
        assert_eq!(class, BatchClass::Decode);
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn submit_blocked_on_capacity_expires_typed() {
        // Satellite regression: a producer blocked on the `freed`
        // condvar used to enqueue its request even after the deadline
        // expired while it waited.  Now the wait is bounded by the
        // deadline and the wake returns a typed `Expired`.
        let b = Arc::new(batcher(2, 10_000, 2));
        b.submit(req(0, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            let opts = RequestOptions {
                deadline: Some(Duration::from_millis(30)),
                ..RequestOptions::default()
            };
            let t0 = Instant::now();
            let out = b2.submit(req_opts(9, BatchClass::Softmax, opts));
            (out, t0.elapsed())
        });
        // Nobody drains the queue: the blocked submit must give up at
        // its deadline instead of waiting forever / enqueueing.
        let (out, waited) = t.join().unwrap();
        assert!(matches!(out, Err(AdmitError::Expired(_))), "typed deadline rejection");
        assert!(waited >= Duration::from_millis(25), "waited to the deadline: {waited:?}");
        assert!(waited < Duration::from_secs(5), "did not block past it: {waited:?}");
        assert_eq!(b.depth(), 2, "expired request was never enqueued");
    }

    #[test]
    fn lane_cap_rejects_typed_without_blocking() {
        // Per-lane quotas: the batch lane fills its 2 slots and the
        // third batch submit is rejected *immediately* (no blocking),
        // while interactive admission is untouched — a batch backlog
        // can no longer stall interactive work behind `freed`.
        let b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            queue_capacity: 64,
            interactive_cap: 0,
            batch_cap: 2,
        });
        let batch_opts =
            RequestOptions { priority: Priority::Batch, ..RequestOptions::default() };
        b.submit(req_opts(0, BatchClass::Softmax, batch_opts.clone())).map_err(|_| ()).unwrap();
        b.submit(req_opts(1, BatchClass::Softmax, batch_opts.clone())).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        match b.submit(req_opts(2, BatchClass::Softmax, batch_opts.clone())) {
            Err(AdmitError::Overloaded { lane, .. }) => assert_eq!(lane, Priority::Batch),
            _ => panic!("expected a typed Overloaded rejection"),
        }
        assert!(t0.elapsed() < Duration::from_secs(1), "lane quota never blocks");
        // Interactive admission still open, on both submit paths.
        b.submit(req(3, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.try_submit(req(4, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        assert_eq!(b.depth(), 4);
        // try_submit applies the same lane quota.
        assert!(matches!(
            b.try_submit(req_opts(5, BatchClass::Softmax, batch_opts)),
            Err(AdmitError::Overloaded { lane: Priority::Batch, .. })
        ));
    }

    #[test]
    fn starvation_guard_promotes_skipped_batch_request() {
        // A continuous interactive trickle used to hold a
        // batch-priority request back forever: every flush re-pushed
        // it behind the newest interactive arrival.  The skip counter
        // promotes it after PROMOTE_AFTER_SKIPS passes.
        let b = batcher(1, 10_000, 64);
        let batch_opts =
            RequestOptions { priority: Priority::Batch, ..RequestOptions::default() };
        b.submit(req_opts(100, BatchClass::Softmax, batch_opts)).map_err(|_| ()).unwrap();
        let mut flushed = Vec::new();
        for i in 0..(PROMOTE_AFTER_SKIPS as u64 + 2) {
            b.submit(req(i, BatchClass::Softmax)).map_err(|_| ()).unwrap();
            let (_, batch, reason) = b.next_batch().unwrap();
            assert_eq!(reason, FlushReason::Full, "two waiters > max_batch 1");
            flushed.extend(batch.iter().map(|r| r.id));
            if flushed.contains(&100) {
                break;
            }
        }
        assert!(
            flushed.contains(&100),
            "batch-priority request starved through {} flushes: {flushed:?}",
            PROMOTE_AFTER_SKIPS + 2
        );
        let skips_to_board = flushed.iter().position(|&id| id == 100).unwrap();
        assert_eq!(
            skips_to_board as u32, PROMOTE_AFTER_SKIPS,
            "promoted exactly at the bound: {flushed:?}"
        );
    }

    #[test]
    fn oversubscribed_take_prefers_earliest_within_priority() {
        // Satellite 3 pin: the restructured take (pop the boarding
        // prefix instead of draining the whole queue) keeps the
        // documented (priority, FIFO) batch composition when the
        // boarding set interleaves with leftovers.
        let b = batcher(2, 10_000, 64);
        let batch_opts =
            RequestOptions { priority: Priority::Batch, ..RequestOptions::default() };
        b.submit(req_opts(0, BatchClass::Softmax, batch_opts.clone())).map_err(|_| ()).unwrap();
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req_opts(2, BatchClass::Softmax, batch_opts)).map_err(|_| ()).unwrap();
        b.submit(req(3, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        let (_, first, _) = b.next_batch().unwrap();
        let ids: Vec<u64> = first.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3], "interactive waiters board first, FIFO");
        let (_, second, _) = b.next_batch().unwrap();
        let ids: Vec<u64> = second.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2], "leftovers keep FIFO order");
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn class_depths_reports_per_class() {
        let b = batcher(16, 10_000, 64);
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(2, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(3, BatchClass::LmStep)).map_err(|_| ()).unwrap();
        let depths = b.class_depths();
        assert_eq!(depths.len(), BatchClass::ALL.len());
        let get = |c: BatchClass| depths.iter().find(|(d, _)| *d == c).unwrap().1;
        assert_eq!(get(BatchClass::Softmax), 2);
        assert_eq!(get(BatchClass::Decode), 0);
        assert_eq!(get(BatchClass::LmStep), 1);
    }
}
