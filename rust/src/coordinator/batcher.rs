//! Continuous dynamic batcher.
//!
//! Requests are admitted into a bounded queue (backpressure beyond
//! capacity) and coalesced into batches by a vLLM-style policy:
//!
//! * a batch closes as soon as `max_batch` same-class requests are
//!   waiting, or
//! * when the oldest waiting request has aged past `max_wait`
//!   (latency bound), whichever comes first;
//! * requests of different [`BatchClass`]es never mix (they execute
//!   different artifacts);
//! * batches are padded up to the artifact bucket sizes by the executor
//!   (see [`super::executor`]), so the batcher only bounds, never pads.
//!
//! On the host backend a formed batch becomes the **rows dimension** of
//! the executor's batch×shard grid dispatch: `max_batch` therefore
//! bounds rows-per-grid (further capped by `grid_rows`), and a larger
//! `max_wait` trades first-request latency for wider grids and better
//! pool occupancy.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::{BatchClass, Request};

/// Batch-formation policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: Duration::from_millis(2), queue_capacity: 1024 }
    }
}

/// Why a batch was closed (metrics / tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    Full,
    Deadline,
    Shutdown,
}

struct State {
    queues: HashMap<BatchClass, VecDeque<Request>>,
    total: usize,
    shutdown: bool,
}

/// The shared batching queue.
pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<State>,
    /// Wakes batch-forming workers when requests arrive / shutdown.
    arrived: Condvar,
    /// Wakes producers when capacity frees up.
    freed: Condvar,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        assert!(policy.max_batch > 0 && policy.queue_capacity >= policy.max_batch);
        Batcher {
            policy,
            state: Mutex::new(State {
                queues: HashMap::new(),
                total: 0,
                shutdown: false,
            }),
            arrived: Condvar::new(),
            freed: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Admit a request, blocking while the queue is at capacity
    /// (backpressure).  Returns `Err(request)` after shutdown.
    pub fn submit(&self, request: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return Err(request);
            }
            if st.total < self.policy.queue_capacity {
                st.queues.entry(request.class()).or_default().push_back(request);
                st.total += 1;
                drop(st);
                self.arrived.notify_one();
                return Ok(());
            }
            st = self.freed.wait(st).unwrap();
        }
    }

    /// Non-blocking admission (the server's overload path → 503-style
    /// rejection instead of unbounded latency).
    pub fn try_submit(&self, request: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown || st.total >= self.policy.queue_capacity {
            return Err(request);
        }
        st.queues.entry(request.class()).or_default().push_back(request);
        st.total += 1;
        drop(st);
        self.arrived.notify_one();
        Ok(())
    }

    /// Pull the next batch, blocking until one is ready per the policy.
    /// Returns `None` only at shutdown with empty queues.
    pub fn next_batch(&self) -> Option<(BatchClass, Vec<Request>, FlushReason)> {
        let mut st = self.state.lock().unwrap();
        loop {
            // A full batch in any class flushes immediately.
            if let Some((&class, _)) = st
                .queues
                .iter()
                .find(|(_, q)| q.len() >= self.policy.max_batch)
            {
                return Some((class, self.take(&mut st, class), FlushReason::Full));
            }
            // Otherwise, find the class with the oldest waiter.
            let oldest: Option<(BatchClass, Instant)> = st
                .queues
                .iter()
                .filter_map(|(&c, q)| q.front().map(|r| (c, r.enqueued)))
                .min_by_key(|&(_, t)| t);
            match oldest {
                Some((class, t0)) => {
                    let age = t0.elapsed();
                    if age >= self.policy.max_wait {
                        return Some((class, self.take(&mut st, class), FlushReason::Deadline));
                    }
                    if st.shutdown {
                        return Some((class, self.take(&mut st, class), FlushReason::Shutdown));
                    }
                    let (guard, _) =
                        self.arrived.wait_timeout(st, self.policy.max_wait - age).unwrap();
                    st = guard;
                }
                None => {
                    if st.shutdown {
                        return None;
                    }
                    st = self.arrived.wait(st).unwrap();
                }
            }
        }
    }

    fn take(&self, st: &mut State, class: BatchClass) -> Vec<Request> {
        let q = st.queues.get_mut(&class).expect("class must exist");
        let n = q.len().min(self.policy.max_batch);
        let batch: Vec<Request> = q.drain(..n).collect();
        st.total -= batch.len();
        self.freed.notify_all();
        batch
    }

    /// Current queued request count (metrics).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().total
    }

    /// Begin shutdown: queued requests still drain via [`next_batch`].
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.arrived.notify_all();
        self.freed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Payload;
    use crate::exec::channel::oneshot;
    use std::sync::Arc;

    fn req(id: u64, class: BatchClass) -> Request {
        let (tx, _rx) = oneshot();
        let payload = match class {
            BatchClass::Softmax => Payload::Softmax { logits: vec![id as f32] },
            BatchClass::Decode => Payload::DecodeTopK { hidden: vec![id as f32], k: None },
            BatchClass::LmStep => Payload::LmStep { session: id, token: 0, k: None },
        };
        Request::new(id, payload, tx)
    }

    fn batcher(max_batch: usize, max_wait_ms: u64, cap: usize) -> Batcher {
        Batcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_capacity: cap,
        })
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = batcher(4, 10_000, 64);
        for i in 0..4 {
            b.submit(req(i, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        }
        let t0 = Instant::now();
        let (class, batch, reason) = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_millis(100), "must not wait for deadline");
        assert_eq!(class, BatchClass::Softmax);
        assert_eq!(batch.len(), 4);
        assert_eq!(reason, FlushReason::Full);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "FIFO order");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = batcher(16, 20, 64);
        b.submit(req(1, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let (class, batch, reason) = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(class, BatchClass::Decode);
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Deadline);
        assert!(waited >= Duration::from_millis(15), "honored max_wait: {waited:?}");
    }

    #[test]
    fn classes_never_mix() {
        let b = batcher(8, 5, 64);
        for i in 0..3 {
            b.submit(req(i, BatchClass::Softmax)).map_err(|_| ()).unwrap();
            b.submit(req(100 + i, BatchClass::Decode)).map_err(|_| ()).unwrap();
        }
        let (c1, b1, _) = b.next_batch().unwrap();
        let (c2, b2, _) = b.next_batch().unwrap();
        assert_ne!(c1, c2);
        assert!(b1.iter().all(|r| r.class() == c1));
        assert!(b2.iter().all(|r| r.class() == c2));
    }

    #[test]
    fn try_submit_rejects_when_full() {
        let b = batcher(2, 10_000, 2);
        assert!(b.try_submit(req(0, BatchClass::Softmax)).is_ok());
        assert!(b.try_submit(req(1, BatchClass::Softmax)).is_ok());
        assert!(b.try_submit(req(2, BatchClass::Softmax)).is_err(), "over capacity");
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn backpressure_unblocks_after_drain() {
        let b = Arc::new(batcher(2, 10_000, 2));
        b.submit(req(0, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.submit(req(2, BatchClass::Softmax)).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        let (_, batch, _) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t.join().unwrap(), "blocked submit completed after drain");
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = batcher(16, 10_000, 64);
        b.submit(req(7, BatchClass::LmStep)).map_err(|_| ()).unwrap();
        b.shutdown();
        let (_, batch, reason) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushReason::Shutdown);
        assert!(b.next_batch().is_none());
        assert!(b.submit(req(8, BatchClass::LmStep)).is_err(), "no admission after shutdown");
    }

    #[test]
    fn oldest_class_flushes_first_on_deadline() {
        let b = batcher(16, 30, 64);
        b.submit(req(1, BatchClass::Softmax)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        b.submit(req(2, BatchClass::Decode)).map_err(|_| ()).unwrap();
        let (class, _, _) = b.next_batch().unwrap();
        assert_eq!(class, BatchClass::Softmax, "older waiter wins");
    }
}
