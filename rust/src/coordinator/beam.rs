//! Beam-search decoding driver — the workload §4 of the paper motivates
//! ("inference with the beam search for auto-regressive models has TopK
//! following Softmax").
//!
//! Each hypothesis owns a server-side LM session; every step submits
//! one `LmStep` request per live hypothesis (the coordinator batches
//! them into a single artifact execution), expands with the returned
//! top-k, and keeps the `width` best by cumulative log-probability.

use std::time::Duration;

use anyhow::{anyhow, Result};

use super::request::{Payload, Reply, RequestOptions, ServeError};
use super::Coordinator;

/// One beam hypothesis.
#[derive(Clone, Debug)]
pub struct Hypothesis {
    pub tokens: Vec<i32>,
    pub logprob: f64,
    session: u64,
}

/// Beam-search configuration.
#[derive(Clone, Copy, Debug)]
pub struct BeamConfig {
    pub width: usize,
    pub steps: usize,
    /// Branching factor per hypothesis (k of the fused softmax+topk).
    pub k: usize,
    pub timeout: Duration,
}

impl Default for BeamConfig {
    fn default() -> Self {
        Self { width: 4, steps: 8, k: 5, timeout: Duration::from_secs(30) }
    }
}

/// Run beam search from `start_token`; returns hypotheses sorted by
/// descending log-probability.
pub fn beam_search(
    coord: &Coordinator,
    cfg: BeamConfig,
    start_token: i32,
) -> Result<Vec<Hypothesis>> {
    assert!(cfg.width > 0 && cfg.k > 0 && cfg.steps > 0);
    let root = coord.open_session();
    let mut beam =
        vec![Hypothesis { tokens: vec![start_token], logprob: 0.0, session: root }];

    for _step in 0..cfg.steps {
        // Fan out: one LmStep per live hypothesis, submitted together so
        // the batcher can fuse them into a single artifact execution.
        let receivers: Vec<_> = beam
            .iter()
            .map(|h| {
                coord.submit_opts(
                    Payload::LmStep {
                        session: h.session,
                        // panic-ok: hypotheses always carry ≥1 token.
                        token: *h.tokens.last().expect("nonempty"),
                    },
                    RequestOptions::with_k(cfg.k),
                )
            })
            .collect::<Result<Vec<_>, ServeError>>()
            .map_err(|e| anyhow!(e))?;

        // Collect expansions.
        let mut candidates: Vec<(usize, f64, i32)> = Vec::new(); // (parent, score, token)
        for (parent, rx) in receivers.into_iter().enumerate() {
            let reply = rx
                .recv_timeout(cfg.timeout)
                .map_err(|e| anyhow!("beam step failed: {e:?}"))?
                .map_err(|e| anyhow!(e))?;
            match reply {
                Reply::TopK { vals, idx } => {
                    for (v, i) in vals.iter().zip(&idx) {
                        let lp = beam[parent].logprob + (*v as f64).max(1e-30).ln();
                        candidates.push((parent, lp, *i as i32));
                    }
                }
                other => return Err(anyhow!("unexpected reply {other:?}")),
            }
        }

        // Prune to the best `width` (stable tiebreak for determinism).
        candidates.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap() // panic-ok: logprobs are finite (ln of clamped probs)
                .then(a.0.cmp(&b.0))
                .then(a.2.cmp(&b.2))
        });
        candidates.truncate(cfg.width);

        // Build the next beam: fork parent sessions for the survivors.
        let mut next = Vec::with_capacity(candidates.len());
        for &(parent, lp, token) in &candidates {
            let session = coord.open_session();
            coord.executor().fork_session(beam[parent].session, session)?;
            let mut tokens = beam[parent].tokens.clone();
            tokens.push(token);
            next.push(Hypothesis { tokens, logprob: lp, session });
        }
        // Retire the previous generation's sessions.
        for h in &beam {
            coord.close_session(h.session);
        }
        // NOTE: sessions forked *pre-step* states; advance them by
        // replaying the parent's last token so each survivor's state
        // reflects its own token path.  The fork copied the parent's
        // post-step state already (LmStep mutated it), so survivors of
        // the same parent share the parent state and differ only in the
        // *chosen* token, which feeds the next step — correct for this
        // state-update model where the token enters at the next step.
        beam = next;
    }

    // Final ordering; keep sessions open so callers may continue.
    // panic-ok: logprobs are finite (ln of clamped probabilities).
    beam.sort_by(|a, b| b.logprob.partial_cmp(&a.logprob).unwrap());
    Ok(beam)
}

/// Close all sessions held by a finished beam.
pub fn release(coord: &Coordinator, beam: &[Hypothesis]) {
    for h in beam {
        coord.close_session(h.session);
    }
}
