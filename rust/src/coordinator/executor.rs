//! Batch executor: turns a formed batch into artifact executions and
//! fans results back to each request's reply channel.
//!
//! This is where the paper's §3.1 becomes a *system* feature: in
//! sharded mode every vocabulary shard produces a partial
//! `(m, d, topk)` on its own engine thread, and the coordinator merges
//! them in rust with the ⊕ operator (eq. 4) — the parallel online
//! normalizer calculation applied across the serving topology rather
//! than across SIMD lanes.
//!
//! Batching detail: requests are padded up to the artifact batch
//! buckets compiled by `aot.py` (1/4/16 by default); pad rows are zeros
//! and their outputs are discarded.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::model::SyntheticLm;
use super::request::{BatchClass, Payload, Reply, ReplyResult, Request};
use crate::config::{ServeConfig, ServingMode};
use crate::runtime::{EnginePool, Input, Tensor};
use crate::softmax::fused;
use crate::softmax::monoid::MD;
use crate::topk::TopKBuffer;

/// Executes batches against the engine pool.
pub struct Executor {
    pool: EnginePool,
    model: SyntheticLm,
    mode: ServingMode,
    shards: usize,
    default_k: usize,
    vocab: usize,
    hidden: usize,
    artifact_k: usize,
    /// LM session states, (hidden,) per session.
    sessions: Mutex<HashMap<u64, Vec<f32>>>,
}

impl Executor {
    /// Build from config: starts engine threads, generates the model,
    /// registers weights as device-resident params, warms up the
    /// executables the mode needs.
    pub fn new(cfg: &ServeConfig) -> Result<Executor> {
        let n_engines = if cfg.shards > 1 { cfg.shards } else { cfg.workers.max(1) };
        let pool = EnginePool::start(&cfg.artifacts_dir, n_engines)?;
        let manifest = pool.manifest();

        // Shapes come from the manifest, not the config: the artifacts
        // define what the runtime can execute.
        let decode = manifest
            .variant("decode_topk_safe")
            .first()
            .copied()
            .ok_or_else(|| anyhow!("artifacts missing decode_topk_safe variant"))?
            .clone();
        let vocab = decode.vocab;
        let hidden = decode.hidden.ok_or_else(|| anyhow!("decode artifact missing hidden"))?;
        let artifact_k = decode.k.ok_or_else(|| anyhow!("decode artifact missing k"))?;
        if cfg.default_k > artifact_k {
            bail!(
                "default_k {} exceeds the AOT-compiled k {} (regenerate artifacts with --k)",
                cfg.default_k,
                artifact_k
            );
        }
        if cfg.shards > 1 {
            let part = manifest
                .variant("decode_partial")
                .first()
                .copied()
                .ok_or_else(|| anyhow!("artifacts missing decode_partial variant"))?;
            let expected = part.shard_count.unwrap_or(0);
            if expected != cfg.shards {
                bail!(
                    "artifacts were compiled for {} shards, config wants {} \
                     (regenerate with --shards)",
                    expected,
                    cfg.shards
                );
            }
        }

        let model = SyntheticLm::generate(vocab, hidden, cfg.seed);
        let executor = Executor {
            model,
            mode: cfg.mode,
            shards: cfg.shards,
            default_k: cfg.default_k,
            vocab,
            hidden,
            artifact_k,
            sessions: Mutex::new(HashMap::new()),
            pool,
        };
        executor.register_params()?;
        Ok(executor)
    }

    fn register_params(&self) -> Result<()> {
        if self.shards > 1 {
            for s in 0..self.shards {
                self.pool
                    .engine(s)
                    .register_param("W_shard", self.model.w_shard_tensor(s, self.shards))?;
            }
        }
        // Full-vocab weights + LM weights live on every engine so any
        // worker can run any class.
        for i in 0..self.pool.len() {
            let e = self.pool.engine(i);
            e.register_param("W", self.model.w_tensor())?;
            e.register_param("emb", self.model.emb_tensor())?;
            e.register_param("w1", self.model.w1_tensor())?;
            e.register_param("w2", self.model.w2_tensor())?;
        }
        Ok(())
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    pub fn model(&self) -> &SyntheticLm {
        &self.model
    }

    /// Create (or reset) an LM session with a zero state.
    pub fn open_session(&self, id: u64) {
        self.sessions.lock().unwrap().insert(id, vec![0.0; self.hidden]);
    }

    pub fn close_session(&self, id: u64) {
        self.sessions.lock().unwrap().remove(&id);
    }

    /// Copy `src`'s state into session `dst` (beam-search expansion).
    pub fn fork_session(&self, src: u64, dst: u64) -> Result<()> {
        let mut sessions = self.sessions.lock().unwrap();
        let state =
            sessions.get(&src).ok_or_else(|| anyhow!("unknown session {src}"))?.clone();
        sessions.insert(dst, state);
        Ok(())
    }

    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// Execute one formed batch; every request's reply channel receives
    /// its result (success or per-request error).
    pub fn execute_batch(&self, class: BatchClass, batch: Vec<Request>, worker: usize) {
        let outcome = match class {
            BatchClass::Softmax => self.run_softmax(&batch, worker),
            BatchClass::Decode => self.run_decode(&batch, worker),
            BatchClass::LmStep => self.run_lm_step(&batch, worker),
        };
        match outcome {
            Ok(replies) => {
                debug_assert_eq!(replies.len(), batch.len());
                for (req, reply) in batch.into_iter().zip(replies) {
                    let _ = req.reply.send(reply);
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                crate::error!("coordinator.executor", "{msg}");
                for req in batch {
                    let _ = req.reply.send(Err(msg.clone()));
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Softmax serving (Figures 1–2 workload)
    // ------------------------------------------------------------------

    fn run_softmax(&self, batch: &[Request], worker: usize) -> Result<Vec<ReplyResult>> {
        // Per-request validation: reject wrong-length rows up front.
        let mut rows: Vec<Option<&[f32]>> = Vec::with_capacity(batch.len());
        let mut errors: Vec<Option<String>> = vec![None; batch.len()];
        for (i, req) in batch.iter().enumerate() {
            match &req.payload {
                Payload::Softmax { logits } if logits.len() == self.vocab => {
                    rows.push(Some(logits))
                }
                Payload::Softmax { logits } => {
                    errors[i] = Some(format!(
                        "logits length {} != served vocab {}",
                        logits.len(),
                        self.vocab
                    ));
                    rows.push(None);
                }
                _ => unreachable!("router guarantees class purity"),
            }
        }
        let live: Vec<&[f32]> = rows.iter().flatten().copied().collect();
        let probs: Vec<Vec<f32>> = if live.is_empty() {
            Vec::new()
        } else if self.shards > 1 {
            self.softmax_sharded(&live)?
        } else {
            self.softmax_unsharded(&live, worker)?
        };
        let mut out = Vec::with_capacity(batch.len());
        let mut it = probs.into_iter();
        for (row, err) in rows.iter().zip(errors) {
            out.push(match (row, err) {
                (Some(_), _) => Ok(Reply::Softmax { probs: it.next().expect("row count") }),
                (None, Some(e)) => Err(e),
                (None, None) => unreachable!(),
            });
        }
        Ok(out)
    }

    fn softmax_unsharded(&self, rows: &[&[f32]], worker: usize) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .pool
            .manifest()
            .bucket_for("softmax_safe", rows.len())
            .ok_or_else(|| anyhow!("no softmax_safe artifact"))?
            .clone();
        let b = entry.batch;
        let mut flat = vec![0.0f32; b * self.vocab];
        for (i, r) in rows.iter().enumerate() {
            flat[i * self.vocab..(i + 1) * self.vocab].copy_from_slice(r);
        }
        let out = self
            .pool
            .engine(worker)
            .execute(&entry.name, vec![Tensor::f32(vec![b, self.vocab], flat)?])?;
        let y = out.into_iter().next().unwrap().into_f32()?;
        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| y[i * self.vocab..(i + 1) * self.vocab].to_vec())
            .collect())
    }

    /// Sharded softmax: per-shard single-pass partial (m, d) on each
    /// engine, rust-side ⊕ merge, then per-shard scale pass — the
    /// distributed rendition of Algorithm 3's two passes.
    fn softmax_sharded(&self, rows: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let vs = self.vocab / self.shards;
        let part_entry = self
            .pool
            .manifest()
            .bucket_for("softmax_partial", rows.len())
            .ok_or_else(|| anyhow!("no softmax_partial artifact"))?
            .clone();
        let scale_entry = self
            .pool
            .manifest()
            .bucket_for("softmax_scale", rows.len())
            .ok_or_else(|| anyhow!("no softmax_scale artifact"))?
            .clone();
        let b = part_entry.batch;
        if part_entry.vocab != vs || scale_entry.vocab != vs {
            bail!("shard artifacts sized for vocab {} but need {vs}", part_entry.vocab);
        }

        // Column slices per shard, padded to bucket rows.
        let shard_input = |s: usize| -> Result<Tensor> {
            let mut flat = vec![0.0f32; b * vs];
            for (i, r) in rows.iter().enumerate() {
                flat[i * vs..(i + 1) * vs].copy_from_slice(&r[s * vs..(s + 1) * vs]);
            }
            Tensor::f32(vec![b, vs], flat)
        };

        // Pass 1 (parallel over shard engines): partial (m, d).
        let partials: Vec<Result<(Vec<f32>, Vec<f32>)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..self.shards)
                    .map(|s| {
                        let entry_name = part_entry.name.clone();
                        let input = shard_input(s);
                        let engine = self.pool.engine(s).clone();
                        scope.spawn(move || -> Result<(Vec<f32>, Vec<f32>)> {
                            let out = engine.execute(&entry_name, vec![input?])?;
                            let mut it = out.into_iter();
                            let m = it.next().unwrap().into_f32()?;
                            let d = it.next().unwrap().into_f32()?;
                            Ok((m, d))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
            });

        // ⊕ merge in rust (eq. 4) per row.
        let mut merged = vec![MD::IDENTITY; b];
        for part in partials {
            let (m, d) = part?;
            for (row, acc) in merged.iter_mut().enumerate() {
                *acc = acc.combine(MD { m: m[row], d: d[row] });
            }
        }
        let m_final: Vec<f32> = merged.iter().map(|md| md.m).collect();
        let d_final: Vec<f32> = merged.iter().map(|md| md.d).collect();

        // Pass 2 (parallel): scale each shard with the global (m, d).
        let scaled: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.shards)
                .map(|s| {
                    let entry_name = scale_entry.name.clone();
                    let input = shard_input(s);
                    let m = Tensor::f32(vec![b], m_final.clone());
                    let d = Tensor::f32(vec![b], d_final.clone());
                    let engine = self.pool.engine(s).clone();
                    scope.spawn(move || -> Result<Vec<f32>> {
                        let out = engine.execute(&entry_name, vec![input?, m?, d?])?;
                        out.into_iter().next().unwrap().into_f32()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
        });

        // Reassemble rows from shard columns.
        let mut pieces = Vec::with_capacity(self.shards);
        for piece in scaled {
            pieces.push(piece?);
        }
        Ok((0..rows.len())
            .map(|i| {
                let mut row = Vec::with_capacity(self.vocab);
                for piece in &pieces {
                    row.extend_from_slice(&piece[i * vs..(i + 1) * vs]);
                }
                row
            })
            .collect())
    }

    // ------------------------------------------------------------------
    // Decode serving (Figures 3–4 workload)
    // ------------------------------------------------------------------

    fn run_decode(&self, batch: &[Request], worker: usize) -> Result<Vec<ReplyResult>> {
        let mut rows: Vec<Option<(&[f32], usize)>> = Vec::with_capacity(batch.len());
        let mut errors: Vec<Option<String>> = vec![None; batch.len()];
        for (i, req) in batch.iter().enumerate() {
            match &req.payload {
                Payload::DecodeTopK { hidden, k } => {
                    let k = k.unwrap_or(self.default_k);
                    if hidden.len() != self.hidden {
                        errors[i] = Some(format!(
                            "hidden length {} != served hidden {}",
                            hidden.len(),
                            self.hidden
                        ));
                        rows.push(None);
                    } else if k == 0 || k > self.artifact_k {
                        errors[i] =
                            Some(format!("k={k} outside supported range 1..={}", self.artifact_k));
                        rows.push(None);
                    } else {
                        rows.push(Some((hidden.as_slice(), k)));
                    }
                }
                _ => unreachable!("router guarantees class purity"),
            }
        }
        let live: Vec<(&[f32], usize)> = rows.iter().flatten().copied().collect();
        let results: Vec<(Vec<f32>, Vec<i64>)> = if live.is_empty() {
            Vec::new()
        } else {
            let states: Vec<&[f32]> = live.iter().map(|(h, _)| *h).collect();
            let full = self.decode_states(&states, worker)?;
            full.into_iter()
                .zip(live.iter())
                .map(|((vals, idx), (_, k))| (vals[..*k].to_vec(), idx[..*k].to_vec()))
                .collect()
        };
        let mut out = Vec::with_capacity(batch.len());
        let mut it = results.into_iter();
        for (row, err) in rows.iter().zip(errors) {
            out.push(match (row, err) {
                (Some(_), _) => {
                    let (vals, idx) = it.next().expect("row count");
                    Ok(Reply::TopK { vals, idx })
                }
                (None, Some(e)) => Err(e),
                (None, None) => unreachable!(),
            });
        }
        Ok(out)
    }

    /// Decode a batch of hidden states to top-`artifact_k` results.
    pub fn decode_states(
        &self,
        states: &[&[f32]],
        worker: usize,
    ) -> Result<Vec<(Vec<f32>, Vec<i64>)>> {
        if self.shards > 1 {
            self.decode_sharded(states)
        } else {
            self.decode_unsharded(states, worker)
        }
    }

    fn decode_unsharded(
        &self,
        states: &[&[f32]],
        worker: usize,
    ) -> Result<Vec<(Vec<f32>, Vec<i64>)>> {
        let variant = match self.mode {
            ServingMode::Safe => "decode_topk_safe",
            ServingMode::Online => "decode_topk_online",
        };
        let entry = self
            .pool
            .manifest()
            .bucket_for(variant, states.len())
            .ok_or_else(|| anyhow!("no {variant} artifact"))?
            .clone();
        let b = entry.batch;
        let k = self.artifact_k;
        let mut flat = vec![0.0f32; b * self.hidden];
        for (i, s) in states.iter().enumerate() {
            flat[i * self.hidden..(i + 1) * self.hidden].copy_from_slice(s);
        }
        let out = self.pool.engine(worker).execute_mixed(
            &entry.name,
            vec![
                Input::Inline(Tensor::f32(vec![b, self.hidden], flat)?),
                Input::Param("W".into()),
            ],
        )?;
        let vals = out[0].as_f32()?;
        let idx = out[1].as_i32()?;
        Ok((0..states.len())
            .map(|i| {
                (
                    vals[i * k..(i + 1) * k].to_vec(),
                    idx[i * k..(i + 1) * k].iter().map(|&x| x as i64).collect(),
                )
            })
            .collect())
    }

    /// Sharded decode: each shard engine computes `(m, d, u, p_local)`
    /// on its vocabulary slice via the single-pass partial artifact; the
    /// coordinator ⊕-merges normalizers and candidate buffers and
    /// finalizes `e^{u−m}/d` — Algorithm 4 distributed across engines.
    fn decode_sharded(&self, states: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<i64>)>> {
        let entry = self
            .pool
            .manifest()
            .bucket_for("decode_partial", states.len())
            .ok_or_else(|| anyhow!("no decode_partial artifact"))?
            .clone();
        let b = entry.batch;
        let k = self.artifact_k;
        let vs = self.vocab / self.shards;
        let mut flat = vec![0.0f32; b * self.hidden];
        for (i, s) in states.iter().enumerate() {
            flat[i * self.hidden..(i + 1) * self.hidden].copy_from_slice(s);
        }

        type Partial = (Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>);
        let partials: Vec<Result<Partial>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.shards)
                .map(|s| {
                    let name = entry.name.clone();
                    let h = Tensor::f32(vec![b, self.hidden], flat.clone());
                    let engine = self.pool.engine(s).clone();
                    scope.spawn(move || -> Result<Partial> {
                        let out = engine.execute_mixed(
                            &name,
                            vec![Input::Inline(h?), Input::Param("W_shard".into())],
                        )?;
                        let mut it = out.into_iter();
                        Ok((
                            it.next().unwrap().into_f32()?,
                            it.next().unwrap().into_f32()?,
                            it.next().unwrap().into_f32()?,
                            it.next().unwrap().into_i32()?,
                        ))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
        });

        // Rust-side merge per row: ⊕ on (m, d), buffer-merge on top-k.
        let mut acc: Vec<(MD, TopKBuffer)> =
            (0..states.len()).map(|_| (MD::IDENTITY, TopKBuffer::new(k))).collect();
        for (s, part) in partials.into_iter().enumerate() {
            let (m, d, u, p) = part?;
            let base = (s * vs) as i64;
            for (row, (md, buf)) in acc.iter_mut().enumerate() {
                *md = md.combine(MD { m: m[row], d: d[row] });
                for i in 0..k {
                    let idx = p[row * k + i];
                    if idx >= 0 {
                        buf.push(u[row * k + i], base + idx as i64);
                    }
                }
            }
        }
        Ok(acc.iter().map(|(md, buf)| fused::finalize(buf, *md)).collect())
    }

    // ------------------------------------------------------------------
    // LM sessions (end-to-end example workload)
    // ------------------------------------------------------------------

    fn run_lm_step(&self, batch: &[Request], worker: usize) -> Result<Vec<ReplyResult>> {
        let mut jobs: Vec<Option<(u64, i32, usize)>> = Vec::with_capacity(batch.len());
        let mut errors: Vec<Option<String>> = vec![None; batch.len()];
        {
            let sessions = self.sessions.lock().unwrap();
            for (i, req) in batch.iter().enumerate() {
                match &req.payload {
                    Payload::LmStep { session, token, k } => {
                        let k = k.unwrap_or(self.default_k);
                        if !sessions.contains_key(session) {
                            errors[i] = Some(format!("unknown session {session}"));
                            jobs.push(None);
                        } else if *token < 0 || *token as usize >= self.vocab {
                            errors[i] = Some(format!("token {token} outside vocab"));
                            jobs.push(None);
                        } else if k == 0 || k > self.artifact_k {
                            errors[i] = Some(format!(
                                "k={k} outside supported range 1..={}",
                                self.artifact_k
                            ));
                            jobs.push(None);
                        } else {
                            jobs.push(Some((*session, *token, k)));
                        }
                    }
                    _ => unreachable!("router guarantees class purity"),
                }
            }
        }
        let live: Vec<(u64, i32, usize)> = jobs.iter().flatten().copied().collect();
        let mut results: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
        if !live.is_empty() {
            // 1. advance recurrent states via the lm_step artifact
            let entry = self
                .pool
                .manifest()
                .bucket_for("lm_step", live.len())
                .ok_or_else(|| anyhow!("no lm_step artifact"))?
                .clone();
            let b = entry.batch;
            let mut state_flat = vec![0.0f32; b * self.hidden];
            let mut tokens = vec![0i32; b];
            {
                let sessions = self.sessions.lock().unwrap();
                for (i, (sid, tok, _)) in live.iter().enumerate() {
                    state_flat[i * self.hidden..(i + 1) * self.hidden]
                        .copy_from_slice(&sessions[sid]);
                    tokens[i] = *tok;
                }
            }
            let out = self.pool.engine(worker).execute_mixed(
                &entry.name,
                vec![
                    Input::Param("emb".into()),
                    Input::Param("w1".into()),
                    Input::Param("w2".into()),
                    Input::Inline(Tensor::f32(vec![b, self.hidden], state_flat)?),
                    Input::Inline(Tensor::i32(vec![b], tokens)?),
                ],
            )?;
            let new_states = out.into_iter().next().unwrap().into_f32()?;

            // 2. persist new states
            {
                let mut sessions = self.sessions.lock().unwrap();
                for (i, (sid, _, _)) in live.iter().enumerate() {
                    sessions.insert(
                        *sid,
                        new_states[i * self.hidden..(i + 1) * self.hidden].to_vec(),
                    );
                }
            }

            // 3. decode the new states
            let state_rows: Vec<&[f32]> = live
                .iter()
                .enumerate()
                .map(|(i, _)| &new_states[i * self.hidden..(i + 1) * self.hidden])
                .collect();
            let decoded = self.decode_states(&state_rows, worker)?;
            results = decoded
                .into_iter()
                .zip(live.iter())
                .map(|((vals, idx), (_, _, k))| (vals[..*k].to_vec(), idx[..*k].to_vec()))
                .collect();
        }
        let mut out = Vec::with_capacity(batch.len());
        let mut it = results.into_iter();
        for (job, err) in jobs.iter().zip(errors) {
            out.push(match (job, err) {
                (Some(_), _) => {
                    let (vals, idx) = it.next().expect("row count");
                    Ok(Reply::TopK { vals, idx })
                }
                (None, Some(e)) => Err(e),
                (None, None) => unreachable!(),
            });
        }
        Ok(out)
    }

    pub fn shutdown(&self) {
        self.pool.shutdown();
    }
}
