//! Batch executor: turns a formed batch into kernel executions and fans
//! results back to each request's reply channel.
//!
//! This is where the paper's §3.1 becomes a *system* feature.  Two
//! backends implement the same request classes:
//!
//! * **Artifacts** — AOT-compiled PJRT executables (one engine thread
//!   per vocabulary shard); in sharded mode every shard produces a
//!   partial `(m, d, topk)` on its own engine and the coordinator
//!   merges them in rust with the ⊕ operator (eq. 4).
//! * **Host** — the in-process [`shard`](crate::shard) engine: batches
//!   whose vocabulary is at or above `shard_threshold` tile onto the
//!   shard pool as a **batch×shard grid** (rows × vocabulary shards,
//!   all tiles in one scoped dispatch, per-row ⊕ tree reductions
//!   running concurrently — the cross-shard Algorithm 4 at batch
//!   scale); smaller requests fall back to the single-thread
//!   [`softmax::compute`]/[`fused`] kernels.  `grid_rows` caps the
//!   rows per dispatch (0 = whole batch; 1 = the degenerate per-row
//!   grid, bitwise-identical by construction).  The per-tile scan
//!   implementation is pluggable (`shard_backend` config /
//!   `--shard-backend`: `auto`, `scalar`, `vectorized`, or
//!   `artifacts-stub`, with a per-tile fallback to the host scalar
//!   scan — see `docs/BACKENDS.md`).  No artifacts, no python, no
//!   PJRT — this is the default serving path on a bare build.
//!
//! Batching detail: requests are padded up to the artifact batch
//! buckets compiled by `aot.py` (1/4/16 by default); pad rows are zeros
//! and their outputs are discarded.  The host backend needs no padding.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::model::SyntheticLm;
use super::request::{
    BatchClass, Payload, Reply, ReplyResult, Request, RequestOptions, ServeError, ShardScan,
    ShardScanKind, ShardScanReply,
};
use crate::config::{BackendKind, ServeConfig, ServingMode};
use crate::router::{Router, RouterConfig};
use crate::runtime::{EnginePool, Input, Tensor};
use crate::sample::{self, SampleSpec};
use crate::shard::{self, ShardEngine, ShardEngineConfig};
use crate::softmax::monoid::MD;
use crate::softmax::{self, fused, Algorithm};
use crate::topk::TopKBuffer;

/// Top-k ceiling for the host backend (artifact backends take theirs
/// from the AOT-compiled `k`).
const HOST_MAX_K: usize = 64;

/// The execution substrate behind the request classes.
enum Backend {
    /// PJRT engine pool over AOT artifacts.
    Artifacts(EnginePool),
    /// In-process host kernels (shard engine + single-thread fallback).
    Host,
    /// Router tier: vocabulary shards fan out over worker *processes*
    /// as `shard_scan` frames and ⊕-merge back here (see
    /// [`crate::router`]).
    Router(Router),
}

/// Executes batches against the selected backend.
pub struct Executor {
    backend: Backend,
    /// Present only on the host backend (the artifacts backend shards
    /// across PJRT engines instead; an idle pool would waste threads).
    shard_engine: Option<ShardEngine>,
    model: SyntheticLm,
    mode: ServingMode,
    shards: usize,
    default_k: usize,
    vocab: usize,
    hidden: usize,
    artifact_k: usize,
    shard_threshold: usize,
    /// Rows per batch×shard grid dispatch (0 = whole batch).
    grid_rows: usize,
    /// LM session states, (hidden,) per session.
    sessions: Mutex<HashMap<u64, Vec<f32>>>,
}

impl Executor {
    /// Build from config: selects the backend, generates the model,
    /// and (for artifacts) starts engine threads, registers weights as
    /// device-resident params, and warms up the executables.
    pub fn new(cfg: &ServeConfig) -> Result<Executor> {
        let use_artifacts = match cfg.backend {
            BackendKind::Artifacts => true,
            BackendKind::Host => false,
            BackendKind::Router => return Self::new_router(cfg),
            BackendKind::Auto => cfg.artifacts_dir.join("manifest.json").exists(),
        };
        if use_artifacts {
            Self::new_artifacts(cfg)
        } else {
            Self::new_host(cfg)
        }
    }

    fn shard_engine_from(cfg: &ServeConfig) -> ShardEngine {
        ShardEngine::new(ShardEngineConfig {
            workers: cfg.host_shards,
            threshold: cfg.shard_threshold,
            // A row that just clears the threshold must actually split:
            // size shards so the threshold row yields two, larger rows
            // fan out toward the worker count.
            min_shard: (cfg.shard_threshold / 2).max(1),
            sched: cfg.pool_sched,
            backend: cfg.shard_backend,
            ..ShardEngineConfig::default()
        })
    }

    /// Host backend: serve straight from the in-process kernels sized
    /// by the config.  Large-vocab requests route onto the shard
    /// engine; the rest run the single-thread kernels inline.
    fn new_host(cfg: &ServeConfig) -> Result<Executor> {
        let vocab = cfg.vocab;
        let hidden = cfg.hidden;
        let artifact_k = HOST_MAX_K.max(cfg.default_k).min(vocab);
        if cfg.default_k > artifact_k {
            bail!("default_k {} exceeds vocab {}", cfg.default_k, vocab);
        }
        let shard_engine = Self::shard_engine_from(cfg);
        crate::info!(
            "coordinator.executor",
            "host backend: vocab {vocab}, hidden {hidden}, {} shard workers ({} pool, \
             {} shard backend), threshold {}, grid rows {}",
            shard_engine.workers(),
            shard_engine.sched().as_str(),
            shard_engine.backend_name(),
            shard_engine.threshold(),
            if cfg.grid_rows == 0 { "auto".to_string() } else { cfg.grid_rows.to_string() }
        );
        if let Some((start, end)) = cfg.worker_slice {
            // Advisory role marker for a router-tier worker: published
            // for operators, but *not* enforced against `shard_scan`
            // ranges — partial-failure requeue and hedging deliberately
            // send an excluded worker's slice to a healthy peer, and
            // every worker holds the full (seed-deterministic) weights.
            if end > vocab {
                bail!("worker slice {start}:{end} exceeds served vocab {vocab}");
            }
            let reg = crate::metrics::global();
            reg.gauge("worker.slice.start").set(start as i64);
            reg.gauge("worker.slice.end").set(end as i64);
            crate::info!(
                "coordinator.executor",
                "worker role: assigned vocabulary slice {start}:{end} of {vocab}"
            );
        }
        Ok(Executor {
            backend: Backend::Host,
            shard_engine: Some(shard_engine),
            model: SyntheticLm::generate(vocab, hidden, cfg.seed),
            mode: cfg.mode,
            shards: 1,
            default_k: cfg.default_k,
            vocab,
            hidden,
            artifact_k,
            shard_threshold: cfg.shard_threshold,
            grid_rows: cfg.grid_rows,
            sessions: Mutex::new(HashMap::new()),
        })
    }

    /// Router backend: this process owns no kernels — every shard scan
    /// ships to a worker process and only the ⊕ merge runs here.  The
    /// request surface (classes, validation, k ceiling) matches the
    /// host backend exactly, which is what makes the bitwise-identity
    /// property testable: same plan, same kernels, different processes.
    fn new_router(cfg: &ServeConfig) -> Result<Executor> {
        if cfg.router_workers.is_empty() {
            bail!("router backend requires --router-workers (comma-separated host:port list)");
        }
        if cfg.mode != ServingMode::Online {
            bail!(
                "router backend distributes the online ⊕ path; `--mode safe` is the \
                 single-process baseline and cannot be sharded across workers"
            );
        }
        let vocab = cfg.vocab;
        let hidden = cfg.hidden;
        let artifact_k = HOST_MAX_K.max(cfg.default_k).min(vocab);
        if cfg.default_k > artifact_k {
            bail!("default_k {} exceeds vocab {}", cfg.default_k, vocab);
        }
        let router = Router::new(RouterConfig {
            workers: cfg.router_workers.clone(),
            vocab,
            probe_interval: Duration::from_millis(cfg.router_probe_ms),
            shard_timeout: Duration::from_millis(cfg.router_shard_timeout_ms),
            hedge_quantile: cfg.router_hedge_quantile,
        })?;
        Ok(Executor {
            backend: Backend::Router(router),
            shard_engine: None,
            model: SyntheticLm::generate(vocab, hidden, cfg.seed),
            mode: cfg.mode,
            shards: 1,
            default_k: cfg.default_k,
            vocab,
            hidden,
            artifact_k,
            shard_threshold: cfg.shard_threshold,
            grid_rows: cfg.grid_rows,
            sessions: Mutex::new(HashMap::new()),
        })
    }

    /// Artifact backend: engine threads over `artifacts_dir`.
    fn new_artifacts(cfg: &ServeConfig) -> Result<Executor> {
        let n_engines = if cfg.shards > 1 { cfg.shards } else { cfg.workers.max(1) };
        let pool = EnginePool::start(&cfg.artifacts_dir, n_engines)?;
        let manifest = pool.manifest();

        // Shapes come from the manifest, not the config: the artifacts
        // define what the runtime can execute.
        let decode = manifest
            .variant("decode_topk_safe")
            .first()
            .copied()
            .ok_or_else(|| anyhow!("artifacts missing decode_topk_safe variant"))?
            .clone();
        let vocab = decode.vocab;
        let hidden = decode.hidden.ok_or_else(|| anyhow!("decode artifact missing hidden"))?;
        let artifact_k = decode.k.ok_or_else(|| anyhow!("decode artifact missing k"))?;
        if cfg.default_k > artifact_k {
            bail!(
                "default_k {} exceeds the AOT-compiled k {} (regenerate artifacts with --k)",
                cfg.default_k,
                artifact_k
            );
        }
        if cfg.shards > 1 {
            let part = manifest
                .variant("decode_partial")
                .first()
                .copied()
                .ok_or_else(|| anyhow!("artifacts missing decode_partial variant"))?;
            let expected = part.shard_count.unwrap_or(0);
            if expected != cfg.shards {
                bail!(
                    "artifacts were compiled for {} shards, config wants {} \
                     (regenerate with --shards)",
                    expected,
                    cfg.shards
                );
            }
        }

        let model = SyntheticLm::generate(vocab, hidden, cfg.seed);
        let executor = Executor {
            backend: Backend::Artifacts(pool),
            shard_engine: None,
            model,
            mode: cfg.mode,
            shards: cfg.shards,
            default_k: cfg.default_k,
            vocab,
            hidden,
            artifact_k,
            shard_threshold: cfg.shard_threshold,
            grid_rows: cfg.grid_rows,
            sessions: Mutex::new(HashMap::new()),
        };
        executor.register_params()?;
        Ok(executor)
    }

    fn register_params(&self) -> Result<()> {
        let Backend::Artifacts(pool) = &self.backend else {
            return Ok(());
        };
        if self.shards > 1 {
            for s in 0..self.shards {
                pool.engine(s)
                    .register_param("W_shard", self.model.w_shard_tensor(s, self.shards))?;
            }
        }
        // Full-vocab weights + LM weights live on every engine so any
        // worker can run any class.
        for i in 0..pool.len() {
            let e = pool.engine(i);
            e.register_param("W", self.model.w_tensor())?;
            e.register_param("emb", self.model.emb_tensor())?;
            e.register_param("w1", self.model.w1_tensor())?;
            e.register_param("w2", self.model.w2_tensor())?;
        }
        Ok(())
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    pub fn model(&self) -> &SyntheticLm {
        &self.model
    }

    /// True when serving from the in-process host kernels.
    pub fn is_host_backend(&self) -> bool {
        matches!(self.backend, Backend::Host)
    }

    /// The shard engine backing host-mode requests (host backend only).
    fn host_shard_engine(&self) -> &ShardEngine {
        // panic-ok: constructed unconditionally for Backend::Host; callers
        // are host-path only.
        self.shard_engine.as_ref().expect("shard engine exists on the host backend")
    }

    /// Rows per grid dispatch for a batch of `batch` live rows:
    /// `grid_rows` caps the fan-out, 0 means the whole batch at once.
    fn grid_chunk(&self, batch: usize) -> usize {
        if self.grid_rows == 0 {
            batch.max(1)
        } else {
            self.grid_rows
        }
    }

    /// Create (or reset) an LM session with a zero state.
    pub fn open_session(&self, id: u64) {
        self.sessions.lock().unwrap().insert(id, vec![0.0; self.hidden]);
    }

    pub fn close_session(&self, id: u64) {
        self.sessions.lock().unwrap().remove(&id);
    }

    /// Copy `src`'s state into session `dst` (beam-search expansion).
    pub fn fork_session(&self, src: u64, dst: u64) -> Result<()> {
        let mut sessions = self.sessions.lock().unwrap();
        let state =
            sessions.get(&src).ok_or_else(|| anyhow!("unknown session {src}"))?.clone();
        sessions.insert(dst, state);
        Ok(())
    }

    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// Whether `id` names a live LM session.
    pub fn has_session(&self, id: u64) -> bool {
        self.sessions.lock().unwrap().contains_key(&id)
    }

    /// Validate the sampling-related options for one request, or `None`
    /// when they are acceptable for `class` on this backend.
    ///
    /// The rules, in order: temperature must be a finite value > 0;
    /// sampling options are meaningless on the softmax class (it
    /// returns a full distribution, not a selection); a non-neutral
    /// temperature without a seed is ambiguous (greedy top-k is
    /// temperature-invariant, so honoring it silently would be a lie);
    /// and sampled decode is served by the host backend only (the AOT
    /// artifact graphs predate the fused Gumbel-top-k scan).
    fn sampling_error(&self, class: BatchClass, options: &RequestOptions) -> Option<ServeError> {
        let t = options.temperature;
        if !(t.is_finite() && t > 0.0) {
            return Some(ServeError::invalid(format!(
                "temperature {t} must be a finite value > 0"
            )));
        }
        if options.seed.is_none() && t == 1.0 {
            return None; // greedy decode, nothing sampled
        }
        if class == BatchClass::Softmax {
            return Some(ServeError::invalid(
                "sampling options (temperature/seed) apply to decode requests, not softmax",
            ));
        }
        if options.seed.is_none() {
            return Some(ServeError::invalid(format!(
                "temperature {t} requires a seed (sampled decode); greedy decode serves \
                 temperature 1.0 only"
            )));
        }
        if matches!(self.backend, Backend::Artifacts(_)) {
            // The router tier forwards sample specs to its (host
            // backend) workers inside `shard_scan`, so it admits seeds
            // just like direct host serving does.
            return Some(ServeError::invalid(
                "sampled decode (seed) is served by the host backend only",
            ));
        }
        None
    }

    /// The per-row sampling spec a validated request's options imply.
    fn sample_spec(options: &RequestOptions) -> Option<SampleSpec> {
        options.seed.map(|seed| SampleSpec { seed, temperature: options.temperature })
    }

    /// Execute one formed batch; every request's reply channel receives
    /// its result (success or per-request error).
    pub fn execute_batch(&self, class: BatchClass, batch: Vec<Request>, worker: usize) {
        // Class-independent admission checks first: a request whose
        // deadline expired while queued is answered without executing,
        // and unsupported option values fail typed instead of reaching
        // the kernels.
        let now = Instant::now();
        let mut live = Vec::with_capacity(batch.len());
        for req in batch {
            if req.expired(now) {
                crate::metrics::global().counter("coordinator.deadline_expired").inc();
                let _ = req.reply.send(Err(ServeError::deadline(
                    "deadline expired before execution",
                )));
            } else if let Some(err) = self.sampling_error(class, &req.options) {
                let _ = req.reply.send(Err(err));
            } else {
                live.push(req);
            }
        }
        if live.is_empty() {
            return;
        }
        let batch = live;
        let outcome = match class {
            BatchClass::Softmax => self.run_softmax(&batch, worker),
            BatchClass::Decode => self.run_decode(&batch, worker),
            BatchClass::LmStep => self.run_lm_step(&batch, worker),
        };
        match outcome {
            Ok(replies) => {
                debug_assert_eq!(replies.len(), batch.len());
                for (req, reply) in batch.into_iter().zip(replies) {
                    let _ = req.reply.send(reply);
                }
            }
            Err(e) => {
                // A typed failure (e.g. the router tier exhausting its
                // requeue budget, or a worker's own rejection) keeps its
                // code; anything else is an internal fault.
                let err = match e.downcast::<ServeError>() {
                    Ok(e) => e,
                    Err(e) => ServeError::internal(format!("batch execution failed: {e:#}")),
                };
                crate::error!("coordinator.executor", "{err}");
                for req in batch {
                    let _ = req.reply.send(Err(err.clone()));
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Softmax serving (Figures 1–2 workload)
    // ------------------------------------------------------------------

    fn run_softmax(&self, batch: &[Request], worker: usize) -> Result<Vec<ReplyResult>> {
        // Per-request validation: reject wrong-length rows up front.
        let mut rows: Vec<Option<&[f32]>> = Vec::with_capacity(batch.len());
        let mut errors: Vec<Option<ServeError>> = vec![None; batch.len()];
        for (i, req) in batch.iter().enumerate() {
            match &req.payload {
                Payload::Softmax { logits } if logits.len() == self.vocab => {
                    rows.push(Some(logits))
                }
                Payload::Softmax { logits } => {
                    errors[i] = Some(ServeError::invalid(format!(
                        "logits length {} != served vocab {}",
                        logits.len(),
                        self.vocab
                    )));
                    rows.push(None);
                }
                _ => unreachable!("router guarantees class purity"),
            }
        }
        let live: Vec<&[f32]> = rows.iter().flatten().copied().collect();
        let probs: Vec<Vec<f32>> = if live.is_empty() {
            Vec::new()
        } else {
            match &self.backend {
                Backend::Artifacts(pool) if self.shards > 1 => {
                    self.softmax_sharded(pool, &live)?
                }
                Backend::Artifacts(pool) => self.softmax_unsharded(pool, &live, worker)?,
                Backend::Host => self.softmax_host(&live),
                Backend::Router(router) => router.softmax(&live).map_err(anyhow::Error::new)?,
            }
        };
        let mut out = Vec::with_capacity(batch.len());
        let mut it = probs.into_iter();
        for (row, err) in rows.iter().zip(errors) {
            out.push(match (row, err) {
                // panic-ok: one result row exists per Some(row) input.
                (Some(_), _) => Ok(Reply::Softmax { probs: it.next().expect("row count") }),
                (None, Some(e)) => Err(e),
                (None, None) => unreachable!(),
            });
        }
        Ok(out)
    }

    /// Host softmax.  In `online` mode with the served vocabulary at or
    /// above the shard threshold, the whole batch tiles onto the shard
    /// pool as a batch×shard grid (chunked by `grid_rows`): per-tile
    /// `(m, d)` partials, concurrent per-row ⊕ tree reductions, one
    /// scoped join per pass — instead of one fan-out/join per row.
    /// Below the threshold rows run the single-thread online kernel.
    /// `safe` mode is the paper's baseline and therefore *always* runs
    /// the single-thread 3-pass safe kernel — sharding and grid
    /// batching are exactly the capabilities the online normalizer's ⊕
    /// monoid buys, so the baseline must not get them.
    fn softmax_host(&self, rows: &[&[f32]]) -> Vec<Vec<f32>> {
        // Defensive short-circuit for batches where every request
        // failed validation: `chunks(n)` panics on n == 0, and while
        // `grid_chunk` clamps to ≥ 1 today, keeping the empty case out
        // of the chunk/grid machinery makes the invariant local
        // instead of resting on that clamp (and skips a pointless
        // zero-row dispatch).
        if rows.is_empty() {
            return Vec::new();
        }
        match self.mode {
            ServingMode::Safe => {
                rows.iter().map(|r| softmax::compute(r, Algorithm::Safe)).collect()
            }
            // Live rows are validated to exactly `vocab` elements, so
            // the threshold check is uniform across the batch.
            ServingMode::Online if self.vocab >= self.shard_threshold => {
                let engine = self.host_shard_engine();
                let mut out = Vec::with_capacity(rows.len());
                for chunk in rows.chunks(self.grid_chunk(rows.len())) {
                    out.extend(engine.softmax_batch(chunk));
                }
                out
            }
            ServingMode::Online => {
                rows.iter().map(|r| softmax::compute(r, Algorithm::Online)).collect()
            }
        }
    }

    fn softmax_unsharded(
        &self,
        pool: &EnginePool,
        rows: &[&[f32]],
        worker: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let entry = pool
            .manifest()
            .bucket_for("softmax_safe", rows.len())
            .ok_or_else(|| anyhow!("no softmax_safe artifact"))?
            .clone();
        let b = entry.batch;
        let mut flat = vec![0.0f32; b * self.vocab];
        for (i, r) in rows.iter().enumerate() {
            flat[i * self.vocab..(i + 1) * self.vocab].copy_from_slice(r);
        }
        let out = pool
            .engine(worker)
            .execute(&entry.name, vec![Tensor::f32(vec![b, self.vocab], flat)?])?;
        // panic-ok: the softmax artifact declares exactly one output.
        let y = out.into_iter().next().unwrap().into_f32()?;
        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| y[i * self.vocab..(i + 1) * self.vocab].to_vec())
            .collect())
    }

    /// Sharded softmax: per-shard single-pass partial (m, d) on each
    /// engine, rust-side ⊕ merge, then per-shard scale pass — the
    /// distributed rendition of Algorithm 3's two passes.
    fn softmax_sharded(&self, pool: &EnginePool, rows: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let vs = self.vocab / self.shards;
        let part_entry = pool
            .manifest()
            .bucket_for("softmax_partial", rows.len())
            .ok_or_else(|| anyhow!("no softmax_partial artifact"))?
            .clone();
        let scale_entry = pool
            .manifest()
            .bucket_for("softmax_scale", rows.len())
            .ok_or_else(|| anyhow!("no softmax_scale artifact"))?
            .clone();
        let b = part_entry.batch;
        if part_entry.vocab != vs || scale_entry.vocab != vs {
            bail!("shard artifacts sized for vocab {} but need {vs}", part_entry.vocab);
        }

        // Column slices per shard, padded to bucket rows.
        let shard_input = |s: usize| -> Result<Tensor> {
            let mut flat = vec![0.0f32; b * vs];
            for (i, r) in rows.iter().enumerate() {
                flat[i * vs..(i + 1) * vs].copy_from_slice(&r[s * vs..(s + 1) * vs]);
            }
            Tensor::f32(vec![b, vs], flat)
        };

        // Pass 1 (parallel over shard engines): partial (m, d).
        let partials: Vec<Result<(Vec<f32>, Vec<f32>)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..self.shards)
                    .map(|s| {
                        let entry_name = part_entry.name.clone();
                        let input = shard_input(s);
                        let engine = pool.engine(s).clone();
                        scope.spawn(move || -> Result<(Vec<f32>, Vec<f32>)> {
                            let out = engine.execute(&entry_name, vec![input?])?;
                            let mut it = out.into_iter();
                            let m = it.next().unwrap().into_f32()?; // panic-ok: 2 outputs
                            let d = it.next().unwrap().into_f32()?; // panic-ok: 2 outputs
                            Ok((m, d))
                        })
                    })
                    .collect();
                // panic-ok: join() errs only on a panicked shard thread —
                // propagate the panic.
                handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
            });

        // ⊕ merge in rust (eq. 4) per row.
        let mut merged = vec![MD::IDENTITY; b];
        for part in partials {
            let (m, d) = part?;
            for (row, acc) in merged.iter_mut().enumerate() {
                *acc = acc.combine(MD { m: m[row], d: d[row] });
            }
        }
        let m_final: Vec<f32> = merged.iter().map(|md| md.m).collect();
        let d_final: Vec<f32> = merged.iter().map(|md| md.d).collect();

        // Pass 2 (parallel): scale each shard with the global (m, d).
        let scaled: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.shards)
                .map(|s| {
                    let entry_name = scale_entry.name.clone();
                    let input = shard_input(s);
                    let m = Tensor::f32(vec![b], m_final.clone());
                    let d = Tensor::f32(vec![b], d_final.clone());
                    let engine = pool.engine(s).clone();
                    scope.spawn(move || -> Result<Vec<f32>> {
                        let out = engine.execute(&entry_name, vec![input?, m?, d?])?;
                        out.into_iter().next().unwrap().into_f32() // panic-ok: 1 output
                    })
                })
                .collect();
            // panic-ok: join() errs only on a panicked shard thread.
            handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
        });

        // Reassemble rows from shard columns.
        let mut pieces = Vec::with_capacity(self.shards);
        for piece in scaled {
            pieces.push(piece?);
        }
        Ok((0..rows.len())
            .map(|i| {
                let mut row = Vec::with_capacity(self.vocab);
                for piece in &pieces {
                    row.extend_from_slice(&piece[i * vs..(i + 1) * vs]);
                }
                row
            })
            .collect())
    }

    // ------------------------------------------------------------------
    // Decode serving (Figures 3–4 workload)
    // ------------------------------------------------------------------

    fn run_decode(&self, batch: &[Request], worker: usize) -> Result<Vec<ReplyResult>> {
        let mut rows: Vec<Option<(&[f32], usize)>> = Vec::with_capacity(batch.len());
        let mut errors: Vec<Option<ServeError>> = vec![None; batch.len()];
        // Per-*live*-row sampling specs (greedy rows carry `None`),
        // parallel to the `live` vector below.
        let mut specs: Vec<Option<SampleSpec>> = Vec::new();
        for (i, req) in batch.iter().enumerate() {
            match &req.payload {
                Payload::DecodeTopK { hidden } => {
                    let k = req.options.k.unwrap_or(self.default_k);
                    if hidden.len() != self.hidden {
                        errors[i] = Some(ServeError::invalid(format!(
                            "hidden length {} != served hidden {}",
                            hidden.len(),
                            self.hidden
                        )));
                        rows.push(None);
                    } else if k == 0 || k > self.artifact_k {
                        errors[i] = Some(ServeError::invalid(format!(
                            "k={k} outside supported range 1..={}",
                            self.artifact_k
                        )));
                        rows.push(None);
                    } else {
                        rows.push(Some((hidden.as_slice(), k)));
                        specs.push(Self::sample_spec(&req.options));
                    }
                }
                _ => unreachable!("router guarantees class purity"),
            }
        }
        let live: Vec<(&[f32], usize)> = rows.iter().flatten().copied().collect();
        let results: Vec<(Vec<f32>, Vec<i64>)> = if live.is_empty() {
            Vec::new()
        } else {
            let states: Vec<&[f32]> = live.iter().map(|(h, _)| *h).collect();
            let full = self.decode_states_sampled(&states, &specs, worker)?;
            full.into_iter()
                .zip(live.iter())
                .map(|((vals, idx), (_, k))| {
                    let n = (*k).min(vals.len());
                    (vals[..n].to_vec(), idx[..n].to_vec())
                })
                .collect()
        };
        let mut out = Vec::with_capacity(batch.len());
        let mut it = results.into_iter();
        for (row, err) in rows.iter().zip(errors) {
            out.push(match (row, err) {
                (Some(_), _) => {
                    let (vals, idx) = it.next().expect("row count"); // panic-ok: per-row
                    Ok(Reply::TopK { vals, idx })
                }
                (None, Some(e)) => Err(e),
                (None, None) => unreachable!(),
            });
        }
        Ok(out)
    }

    /// Decode a batch of hidden states to top-`artifact_k` results
    /// (greedy — every row unsampled).
    pub fn decode_states(
        &self,
        states: &[&[f32]],
        worker: usize,
    ) -> Result<Vec<(Vec<f32>, Vec<i64>)>> {
        self.decode_states_sampled(states, &vec![None; states.len()], worker)
    }

    /// [`Self::decode_states`] with a per-row sampling spec: rows with
    /// `Some(spec)` return seeded Gumbel-top-k selections instead of
    /// the greedy top-k (host backend only — admission validation
    /// rejects seeds elsewhere, so the artifact arms see all-`None`).
    pub fn decode_states_sampled(
        &self,
        states: &[&[f32]],
        specs: &[Option<SampleSpec>],
        worker: usize,
    ) -> Result<Vec<(Vec<f32>, Vec<i64>)>> {
        debug_assert_eq!(states.len(), specs.len());
        match &self.backend {
            Backend::Artifacts(pool) if self.shards > 1 => {
                debug_assert!(specs.iter().all(Option::is_none));
                self.decode_sharded(pool, states)
            }
            Backend::Artifacts(pool) => {
                debug_assert!(specs.iter().all(Option::is_none));
                self.decode_unsharded(pool, states, worker)
            }
            Backend::Host => Ok(self.decode_host(states, specs)),
            Backend::Router(router) => {
                router.decode(states, self.artifact_k, specs).map_err(anyhow::Error::new)
            }
        }
    }

    /// Host decode.  In `online` mode with the vocabulary at/above the
    /// threshold the whole batch executes as a batch×shard grid
    /// (chunked by `grid_rows`): each (row, shard) tile materializes
    /// only its own slice of the logits (sharded projection) and scans
    /// it with Algorithm 4, and per-row partials ⊕-merge in concurrent
    /// tree reductions under a single scoped join.  Smaller
    /// vocabularies use the single-thread fused kernel per row.  `safe`
    /// mode always runs the framework-baseline path (full projection,
    /// materialized safe softmax, separate top-k) — the baseline the
    /// paper compares against, deliberately unsharded (see
    /// [`Self::softmax_host`]).
    fn decode_host(
        &self,
        states: &[&[f32]],
        specs: &[Option<SampleSpec>],
    ) -> Vec<(Vec<f32>, Vec<i64>)> {
        // Same defensive empty-batch short-circuit as `softmax_host`:
        // decode and lm_step batches where every request was rejected
        // up front never reach the chunked grid dispatch.
        if states.is_empty() {
            return Vec::new();
        }
        let k = self.artifact_k;
        match self.mode {
            ServingMode::Safe => states
                .iter()
                .zip(specs)
                .map(|(h, spec)| {
                    let logits = self.model.project_row(h);
                    match spec {
                        // Sampled rows use the fused single-sweep scan
                        // even in safe mode: the selection must be
                        // bitwise-identical across serving modes, and
                        // the reported probabilities match the safe
                        // normalizer to fp tolerance.
                        Some(spec) => sample::sampled_topk(&logits, k, *spec),
                        None => {
                            let mut scratch = Vec::new();
                            fused::safe_unfused_topk(&logits, k, &mut scratch)
                        }
                    }
                })
                .collect(),
            ServingMode::Online if self.vocab >= self.shard_threshold => {
                let engine = self.host_shard_engine();
                let model = &self.model;
                let mut out = Vec::with_capacity(states.len());
                let mut base = 0usize;
                for chunk in states.chunks(self.grid_chunk(states.len())) {
                    let chunk_specs = &specs[base..base + chunk.len()];
                    base += chunk.len();
                    let grid = engine.grid_plan(chunk.len(), self.vocab);
                    out.extend(engine.grid_map(
                        &grid,
                        |tile| {
                            // Sharded projection: only this tile's slice
                            // of the logits is ever materialized, then
                            // the engine's backend (host scalar/
                            // vectorized, with per-tile fallback) scans
                            // it into the (m, d, topk) partial — plus
                            // the Gumbel-top-k candidate state when the
                            // row is sampled.
                            let logits = model.project_range(
                                chunk[tile.row],
                                tile.range.start,
                                tile.range.end,
                            );
                            engine.scan_tile(
                                &logits,
                                tile.range.start..tile.range.end,
                                k,
                                chunk_specs[tile.row],
                            )
                        },
                        |row, parts| {
                            let merged = shard::tree_reduce(parts);
                            if chunk_specs[row].is_some() {
                                merged.finalize_sampled()
                            } else {
                                merged.finalize()
                            }
                        },
                    ));
                }
                out
            }
            ServingMode::Online => states
                .iter()
                .zip(specs)
                .map(|(h, spec)| {
                    let logits = self.model.project_row(h);
                    match spec {
                        Some(spec) => sample::sampled_topk(&logits, k, *spec),
                        None => fused::online_topk(&logits, k),
                    }
                })
                .collect(),
        }
    }

    fn decode_unsharded(
        &self,
        pool: &EnginePool,
        states: &[&[f32]],
        worker: usize,
    ) -> Result<Vec<(Vec<f32>, Vec<i64>)>> {
        let variant = match self.mode {
            ServingMode::Safe => "decode_topk_safe",
            ServingMode::Online => "decode_topk_online",
        };
        let entry = pool
            .manifest()
            .bucket_for(variant, states.len())
            .ok_or_else(|| anyhow!("no {variant} artifact"))?
            .clone();
        let b = entry.batch;
        let k = self.artifact_k;
        let mut flat = vec![0.0f32; b * self.hidden];
        for (i, s) in states.iter().enumerate() {
            flat[i * self.hidden..(i + 1) * self.hidden].copy_from_slice(s);
        }
        let out = pool.engine(worker).execute_mixed(
            &entry.name,
            vec![
                Input::Inline(Tensor::f32(vec![b, self.hidden], flat)?),
                Input::Param("W".into()),
            ],
        )?;
        let vals = out[0].as_f32()?;
        let idx = out[1].as_i32()?;
        Ok((0..states.len())
            .map(|i| {
                (
                    vals[i * k..(i + 1) * k].to_vec(),
                    idx[i * k..(i + 1) * k].iter().map(|&x| x as i64).collect(),
                )
            })
            .collect())
    }

    /// Sharded decode: each shard engine computes `(m, d, u, p_local)`
    /// on its vocabulary slice via the single-pass partial artifact; the
    /// coordinator ⊕-merges normalizers and candidate buffers and
    /// finalizes `e^{u−m}/d` — Algorithm 4 distributed across engines.
    fn decode_sharded(
        &self,
        pool: &EnginePool,
        states: &[&[f32]],
    ) -> Result<Vec<(Vec<f32>, Vec<i64>)>> {
        let entry = pool
            .manifest()
            .bucket_for("decode_partial", states.len())
            .ok_or_else(|| anyhow!("no decode_partial artifact"))?
            .clone();
        let b = entry.batch;
        let k = self.artifact_k;
        let vs = self.vocab / self.shards;
        let mut flat = vec![0.0f32; b * self.hidden];
        for (i, s) in states.iter().enumerate() {
            flat[i * self.hidden..(i + 1) * self.hidden].copy_from_slice(s);
        }

        type Partial = (Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>);
        let partials: Vec<Result<Partial>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.shards)
                .map(|s| {
                    let name = entry.name.clone();
                    let h = Tensor::f32(vec![b, self.hidden], flat.clone());
                    let engine = pool.engine(s).clone();
                    scope.spawn(move || -> Result<Partial> {
                        let out = engine.execute_mixed(
                            &name,
                            vec![Input::Inline(h?), Input::Param("W_shard".into())],
                        )?;
                        let mut it = out.into_iter();
                        Ok((
                            it.next().unwrap().into_f32()?, // panic-ok: 4 outputs
                            it.next().unwrap().into_f32()?, // panic-ok: 4 outputs
                            it.next().unwrap().into_f32()?, // panic-ok: 4 outputs
                            it.next().unwrap().into_i32()?, // panic-ok: 4 outputs
                        ))
                    })
                })
                .collect();
            // panic-ok: join() errs only on a panicked shard thread.
            handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
        });

        // Rust-side merge per row: ⊕ on (m, d), buffer-merge on top-k.
        let mut acc: Vec<(MD, TopKBuffer)> =
            (0..states.len()).map(|_| (MD::IDENTITY, TopKBuffer::new(k))).collect();
        for (s, part) in partials.into_iter().enumerate() {
            let (m, d, u, p) = part?;
            let base = (s * vs) as i64;
            for (row, (md, buf)) in acc.iter_mut().enumerate() {
                *md = md.combine(MD { m: m[row], d: d[row] });
                for i in 0..k {
                    let idx = p[row * k + i];
                    if idx >= 0 {
                        buf.push(u[row * k + i], base + idx as i64);
                    }
                }
            }
        }
        Ok(acc.iter().map(|(md, buf)| fused::finalize(buf, *md)).collect())
    }

    // ------------------------------------------------------------------
    // LM sessions (end-to-end example workload)
    // ------------------------------------------------------------------

    fn run_lm_step(&self, batch: &[Request], worker: usize) -> Result<Vec<ReplyResult>> {
        let mut jobs: Vec<Option<(u64, i32, usize)>> = Vec::with_capacity(batch.len());
        let mut errors: Vec<Option<ServeError>> = vec![None; batch.len()];
        // Per-live-job sampling specs, parallel to `live` below.
        let mut specs: Vec<Option<SampleSpec>> = Vec::new();
        {
            let sessions = self.sessions.lock().unwrap();
            for (i, req) in batch.iter().enumerate() {
                match &req.payload {
                    Payload::LmStep { session, token } => {
                        let k = req.options.k.unwrap_or(self.default_k);
                        if !sessions.contains_key(session) {
                            errors[i] =
                                Some(ServeError::not_found(format!("unknown session {session}")));
                            jobs.push(None);
                        } else if *token < 0 || *token as usize >= self.vocab {
                            errors[i] =
                                Some(ServeError::invalid(format!("token {token} outside vocab")));
                            jobs.push(None);
                        } else if k == 0 || k > self.artifact_k {
                            errors[i] = Some(ServeError::invalid(format!(
                                "k={k} outside supported range 1..={}",
                                self.artifact_k
                            )));
                            jobs.push(None);
                        } else {
                            jobs.push(Some((*session, *token, k)));
                            specs.push(Self::sample_spec(&req.options));
                        }
                    }
                    // `Generate` shares this batch class but is a
                    // streaming operation the coordinator decomposes;
                    // reaching the executor whole is a caller bug we
                    // answer typed rather than panicking a worker.
                    Payload::Generate { .. } => {
                        errors[i] = Some(ServeError::invalid(
                            "generate is a streaming operation; use Coordinator::generate",
                        ));
                        jobs.push(None);
                    }
                    _ => unreachable!("router guarantees class purity"),
                }
            }
        }
        let live: Vec<(u64, i32, usize)> = jobs.iter().flatten().copied().collect();
        let mut results: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
        if !live.is_empty() {
            // 1. advance recurrent states (artifact graph or host math)
            let new_states = self.advance_states(&live, worker)?;

            // 2. persist new states
            {
                let mut sessions = self.sessions.lock().unwrap();
                for (i, (sid, _, _)) in live.iter().enumerate() {
                    sessions.insert(
                        *sid,
                        new_states[i * self.hidden..(i + 1) * self.hidden].to_vec(),
                    );
                }
            }

            // 3. decode the new states
            let state_rows: Vec<&[f32]> = live
                .iter()
                .enumerate()
                .map(|(i, _)| &new_states[i * self.hidden..(i + 1) * self.hidden])
                .collect();
            let decoded = self.decode_states_sampled(&state_rows, &specs, worker)?;
            results = decoded
                .into_iter()
                .zip(live.iter())
                .map(|((vals, idx), (_, _, k))| {
                    let n = (*k).min(vals.len());
                    (vals[..n].to_vec(), idx[..n].to_vec())
                })
                .collect();
        }
        let mut out = Vec::with_capacity(batch.len());
        let mut it = results.into_iter();
        for (job, err) in jobs.iter().zip(errors) {
            out.push(match (job, err) {
                (Some(_), _) => {
                    let (vals, idx) = it.next().expect("row count"); // panic-ok: per-row
                    Ok(Reply::TopK { vals, idx })
                }
                (None, Some(e)) => Err(e),
                (None, None) => unreachable!(),
            });
        }
        Ok(out)
    }

    /// Advance each live session's recurrent state by one token.
    /// Returns row-major `(live.len() + padding, hidden)` states; rows
    /// beyond `live.len()` (artifact batch padding) are ignored.
    fn advance_states(&self, live: &[(u64, i32, usize)], worker: usize) -> Result<Vec<f32>> {
        match &self.backend {
            // The router advances states locally too: the recurrent
            // step is O(hidden²) with no vocabulary axis to shard, and
            // the synthetic weights are seed-deterministic, so local
            // advancement is bitwise-identical to any worker's.  Only
            // the decode that follows fans out.
            Backend::Host | Backend::Router(_) => {
                // Copy the states out under the lock, compute after
                // releasing it (matching the artifact arm) — lm_step_row
                // is O(hidden²) per row and must not serialize sessions.
                let states: Vec<Vec<f32>> = {
                    let sessions = self.sessions.lock().unwrap();
                    live.iter().map(|(sid, _, _)| sessions[sid].clone()).collect()
                };
                let mut flat = Vec::with_capacity(live.len() * self.hidden);
                for (state, (_, tok, _)) in states.iter().zip(live) {
                    flat.extend(self.model.lm_step_row(state, *tok));
                }
                Ok(flat)
            }
            Backend::Artifacts(pool) => {
                let entry = pool
                    .manifest()
                    .bucket_for("lm_step", live.len())
                    .ok_or_else(|| anyhow!("no lm_step artifact"))?
                    .clone();
                let b = entry.batch;
                let mut state_flat = vec![0.0f32; b * self.hidden];
                let mut tokens = vec![0i32; b];
                {
                    let sessions = self.sessions.lock().unwrap();
                    for (i, (sid, tok, _)) in live.iter().enumerate() {
                        state_flat[i * self.hidden..(i + 1) * self.hidden]
                            .copy_from_slice(&sessions[sid]);
                        tokens[i] = *tok;
                    }
                }
                let out = pool.engine(worker).execute_mixed(
                    &entry.name,
                    vec![
                        Input::Param("emb".into()),
                        Input::Param("w1".into()),
                        Input::Param("w2".into()),
                        Input::Inline(Tensor::f32(vec![b, self.hidden], state_flat)?),
                        Input::Inline(Tensor::i32(vec![b], tokens)?),
                    ],
                )?;
                out.into_iter().next().unwrap().into_f32() // panic-ok: 1 output
            }
        }
    }

    // ------------------------------------------------------------------
    // Router-tier worker surface (`shard_scan` frames)
    // ------------------------------------------------------------------

    /// Serve one `shard_scan` frame: compute this request's vocabulary
    /// slice with exactly the per-tile kernels the in-process grid path
    /// dispatches, so the router's ⊕ merge of the returned partials is
    /// bitwise-identical to single-process serving.
    ///
    /// Ranges are validated against the served vocab but *not* against
    /// this worker's `--worker-slice` assignment — the router requeues
    /// an excluded worker's slice onto any healthy peer, and every
    /// worker holds the full seed-deterministic weights.
    pub fn shard_scan(&self, scan: &ShardScan) -> Result<ShardScanReply, ServeError> {
        let Some(engine) = (match &self.backend {
            Backend::Host => self.shard_engine.as_ref(),
            _ => None,
        }) else {
            return Err(ServeError::invalid(
                "shard_scan is served by host-backend workers only",
            ));
        };
        let (start, end) = (scan.start, scan.end);
        if start >= end || end > self.vocab {
            return Err(ServeError::invalid(format!(
                "shard range {start}:{end} outside served vocab {}",
                self.vocab
            )));
        }
        let width = end - start;
        match scan.kind {
            ShardScanKind::Decode => {
                if scan.k == 0 || scan.k > self.vocab {
                    return Err(ServeError::invalid(format!(
                        "k={} outside supported range 1..={}",
                        scan.k, self.vocab
                    )));
                }
                if scan.samples.len() != scan.rows.len() {
                    return Err(ServeError::invalid("samples must align with rows"));
                }
                let mut partials = Vec::with_capacity(scan.rows.len());
                for (row, spec) in scan.rows.iter().zip(&scan.samples) {
                    if row.len() != self.hidden {
                        return Err(ServeError::invalid(format!(
                            "hidden length {} != served hidden {}",
                            row.len(),
                            self.hidden
                        )));
                    }
                    // Sharded projection + Algorithm 4 scan: the same
                    // two calls the grid path's per-tile closure makes.
                    let logits = self.model.project_range(row, start, end);
                    partials.push(engine.scan_tile(&logits, start..end, scan.k, *spec));
                }
                Ok(ShardScanReply::Partials(partials))
            }
            ShardScanKind::Softmax => {
                let mut norms = Vec::with_capacity(scan.rows.len());
                for row in &scan.rows {
                    if row.len() != width {
                        return Err(ServeError::invalid(format!(
                            "softmax row length {} != shard width {width}",
                            row.len()
                        )));
                    }
                    norms.push(engine.normalizer_tile(row, start..end));
                }
                Ok(ShardScanReply::Norms(norms))
            }
            ShardScanKind::Scale => {
                if scan.norms.len() != scan.rows.len() {
                    return Err(ServeError::invalid("norms must align with rows"));
                }
                let mut slices = Vec::with_capacity(scan.rows.len());
                for (row, md) in scan.rows.iter().zip(&scan.norms) {
                    if row.len() != width {
                        return Err(ServeError::invalid(format!(
                            "scale row length {} != shard width {width}",
                            row.len()
                        )));
                    }
                    if !(md.d.is_finite() && md.d > 0.0 && md.m.is_finite()) {
                        return Err(ServeError::invalid(
                            "scale norms must be finite non-identity (m, d) values",
                        ));
                    }
                    // Same arithmetic as the in-process scale grid: the
                    // reciprocal is taken once per (row, shard) tile in
                    // f32, then the backend's scale kernel runs.
                    let inv = 1.0 / md.d;
                    let mut out = vec![0.0f32; width];
                    engine.scale_slice(row, &mut out, md.m, inv);
                    slices.push(out);
                }
                Ok(ShardScanReply::Slices(slices))
            }
        }
    }

    pub fn shutdown(&self) {
        match &self.backend {
            Backend::Artifacts(pool) => pool.shutdown(),
            Backend::Router(router) => router.shutdown(),
            Backend::Host => {}
        }
    }
}
