//! L3 coordinator — the serving system around the paper's kernels.
//!
//! ```text
//!  clients ──► Coordinator::submit ──► Batcher (bounded, classed)
//!                                         │ next_batch()
//!                              worker threads (config.workers)
//!                                         │
//!                                  Executor::execute_batch
//!                   ┌──────────────┬──────┴────────┬──────────────┐
//!               softmax        decode topk      lm step        (classes)
//!                   │              │               │
//!         host backend: batch×shard GridPlan → shard pool tiles →
//!         concurrent per-row ⊕ tree reductions (one scoped join)
//!                   │
//!             EnginePool (PJRT CPU clients, AOT artifacts)
//!                   │
//!          sharded mode: per-shard (m, d, topk) partials,
//!          ⊕-merged in rust (§3.1 of the paper) and finalized
//! ```
//!
//! Submodules: [`request`] (types), [`batcher`] (continuous dynamic
//! batching with deadline flush + backpressure), [`executor`] (artifact
//! execution + shard merge), [`model`] (deterministic synthetic
//! weights), [`beam`] (beam-search driver used by the examples).

pub mod batcher;
pub mod beam;
pub mod executor;
pub mod model;
pub mod request;

pub use batcher::{BatchPolicy, Batcher, FlushReason};
pub use executor::Executor;
pub use model::SyntheticLm;
pub use request::{BatchClass, Payload, Reply, ReplyResult, Request, RequestId};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::exec::channel::OnceReceiver;
use crate::exec::oneshot;
use crate::metrics;

/// The assembled serving system.
pub struct Coordinator {
    batcher: Arc<Batcher>,
    executor: Arc<Executor>,
    next_id: AtomicU64,
    next_session: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Build and start: engines, weights, batcher, worker threads.
    pub fn start(cfg: &ServeConfig) -> Result<Coordinator> {
        let executor = Arc::new(Executor::new(cfg)?);
        let batcher = Arc::new(Batcher::new(BatchPolicy {
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            queue_capacity: cfg.queue_capacity,
        }));
        let reg = metrics::global();
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let executor = executor.clone();
            let batch_hist = reg.histogram("coordinator.batch_exec_us");
            let batch_size = reg.counter("coordinator.batched_requests");
            let batches = reg.counter("coordinator.batches");
            workers.push(
                std::thread::Builder::new()
                    .name(format!("coord-worker-{w}"))
                    .spawn(move || {
                        while let Some((class, batch, _reason)) = batcher.next_batch() {
                            batches.inc();
                            batch_size.add(batch.len() as u64);
                            let t0 = std::time::Instant::now();
                            executor.execute_batch(class, batch, w);
                            batch_hist.record(t0.elapsed());
                        }
                    })
                    .expect("spawn coordinator worker"),
            );
        }
        Ok(Coordinator {
            batcher,
            executor,
            next_id: AtomicU64::new(1),
            next_session: AtomicU64::new(1),
            workers,
        })
    }

    /// Submit a request; returns the response channel immediately.
    pub fn submit(&self, payload: Payload) -> Result<OnceReceiver<ReplyResult>, String> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = oneshot();
        let req = Request::new(id, payload, tx);
        metrics::global().counter("coordinator.submitted").inc();
        metrics::global()
            .gauge("coordinator.queue_depth")
            .set(self.batcher.depth() as i64);
        self.batcher
            .submit(req)
            .map_err(|_| "coordinator shutting down".to_string())?;
        Ok(rx)
    }

    /// Submit without blocking on a full queue (server overload path).
    pub fn try_submit(&self, payload: Payload) -> Result<OnceReceiver<ReplyResult>, String> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = oneshot();
        let req = Request::new(id, payload, tx);
        self.batcher.try_submit(req).map_err(|_| "queue full (backpressure)".to_string())?;
        Ok(rx)
    }

    /// Submit and wait with a timeout — the blocking convenience path.
    pub fn call(&self, payload: Payload, timeout: Duration) -> ReplyResult {
        let t0 = std::time::Instant::now();
        let rx = self.submit(payload)?;
        let result = rx
            .recv_timeout(timeout)
            .map_err(|e| format!("request timed out/failed: {e:?}"))?;
        metrics::global()
            .histogram("coordinator.request_us")
            .record(t0.elapsed());
        result
    }

    /// Open a new LM session, returning its id.
    pub fn open_session(&self) -> u64 {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        self.executor.open_session(id);
        id
    }

    pub fn close_session(&self, id: u64) {
        self.executor.close_session(id);
    }

    /// Fork an existing session's state into a fresh session id
    /// (beam-search expansion without replay).
    pub fn fork_session(&self, src: u64) -> Result<u64> {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        self.executor.fork_session(src, id)?;
        Ok(id)
    }

    pub fn executor(&self) -> &Executor {
        &self.executor
    }

    /// Queue depth snapshot (metrics / tests).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Drain and stop: in-flight batches finish, workers join.
    pub fn shutdown(mut self) {
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.executor.shutdown();
    }
}
