//! L3 coordinator — the serving system around the paper's kernels.
//!
//! ```text
//!  clients ──► Coordinator::submit ──► Front (cache / coalesce)
//!                                         │ admit()
//!                                      Batcher (bounded, classed,
//!                                       per-lane admission quotas,
//!                                       deadline shedding)
//!                                         │ next_batch()
//!                              worker threads (config.workers)
//!                                         │
//!                                  Executor::execute_batch
//!                   ┌──────────────┬──────┴────────┬──────────────┐
//!               softmax        decode topk      lm step        (classes)
//!                   │              │               │
//!         host backend: batch×shard GridPlan → shard pool tiles →
//!         concurrent per-row ⊕ tree reductions (one scoped join)
//!                   │
//!             EnginePool (PJRT CPU clients, AOT artifacts)
//!                   │
//!          sharded mode: per-shard (m, d, topk) partials,
//!          ⊕-merged in rust (§3.1 of the paper) and finalized
//! ```
//!
//! Submodules: [`request`] (typed v2 request surface: payloads,
//! options, structured errors), [`front`] (request coalescing + LRU
//! result cache ahead of admission), [`batcher`] (continuous dynamic
//! batching with priority/deadline-aware flush, per-lane admission
//! quotas, and backpressure),
//! [`executor`] (artifact execution + shard merge), [`generate`]
//! (server-side streaming generation loop), [`model`] (deterministic
//! synthetic weights), [`beam`] (beam-search driver used by the
//! examples).

// xtask:atomics-allowlist: Relaxed
// Relaxed: `next_id` / `next_session` only need uniqueness (fetch_add
// is atomic at any ordering) and `active_streams` is telemetry; no
// other memory is published through these atomics — request handoff
// ordering comes from the batcher's mutex.

pub mod batcher;
pub mod beam;
pub mod executor;
pub mod front;
pub mod generate;
pub mod model;
pub mod request;

pub use batcher::{AdmitError, BatchPolicy, Batcher, FlushReason};
pub use front::{Admission, Front, FrontPolicy, FrontStats};
pub use executor::Executor;
pub use generate::TokenFrame;
pub use model::SyntheticLm;
pub use request::{
    BatchClass, ErrorCode, Payload, Priority, Reply, ReplyResult, Request, RequestId,
    RequestOptions, ServeError, ShardScan, ShardScanKind, ShardScanReply,
};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::exec::channel::{OnceReceiver, RecvError};
use crate::exec::oneshot;
use crate::metrics;

/// The assembled serving system.
pub struct Coordinator {
    batcher: Arc<Batcher>,
    front: Arc<Front>,
    executor: Arc<Executor>,
    next_id: AtomicU64,
    next_session: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Live server-side generation streams (see [`generate`]).
    /// Deliberately separate from the `coordinator.active_streams`
    /// metrics gauge: the gauge is process-global (shared by every
    /// coordinator in a test binary), while this field scopes the
    /// `stats` RPC's count to *this* instance.
    active_streams: AtomicU64,
    /// Default per-request handling budget (config `request_timeout`);
    /// per-request deadlines tighten it, never extend it.
    request_timeout: Duration,
}

impl Coordinator {
    /// Build and start: engines, weights, batcher, worker threads.
    pub fn start(cfg: &ServeConfig) -> Result<Coordinator> {
        let executor = Arc::new(Executor::new(cfg)?);
        let batcher = Arc::new(Batcher::new(BatchPolicy {
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            queue_capacity: cfg.queue_capacity,
            interactive_cap: cfg.admission_interactive_cap,
            batch_cap: cfg.admission_batch_cap,
        }));
        let front = Arc::new(Front::new(FrontPolicy {
            cache_capacity: cfg.cache_capacity,
            coalesce: cfg.cache_coalesce,
            default_k: cfg.default_k,
        }));
        let reg = metrics::global();
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let executor = executor.clone();
            let batch_hist = reg.histogram("coordinator.batch_exec_us");
            let batch_size = reg.counter("coordinator.batched_requests");
            let batches = reg.counter("coordinator.batches");
            // Per-class batch accounting: depth counters feed the
            // `stats` RPC, and the peak gauge is the cross-stream
            // batching witness (a server-side generation e2e asserts
            // `coordinator.batch.lm_step.peak > 1` under concurrent
            // streams).
            let class_batches: Vec<_> = BatchClass::ALL
                .iter()
                .map(|c| reg.counter(&format!("coordinator.batch.{}.batches", c.name())))
                .collect();
            let class_requests: Vec<_> = BatchClass::ALL
                .iter()
                .map(|c| reg.counter(&format!("coordinator.batch.{}.requests", c.name())))
                .collect();
            let class_peak: Vec<_> = BatchClass::ALL
                .iter()
                .map(|c| reg.gauge(&format!("coordinator.batch.{}.peak", c.name())))
                .collect();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("coord-worker-{w}"))
                    .spawn(move || {
                        while let Some((class, batch, _reason)) = batcher.next_batch() {
                            batches.inc();
                            batch_size.add(batch.len() as u64);
                            let ci = BatchClass::ALL
                                .iter()
                                .position(|c| *c == class)
                                .expect("class in ALL"); // panic-ok: ALL is exhaustive
                            class_batches[ci].inc();
                            class_requests[ci].add(batch.len() as u64);
                            class_peak[ci].set_max(batch.len() as i64);
                            let t0 = std::time::Instant::now();
                            executor.execute_batch(class, batch, w);
                            batch_hist.record(t0.elapsed());
                        }
                    })
                    .expect("spawn coordinator worker"), // panic-ok: fatal at startup
            );
        }
        Ok(Coordinator {
            batcher,
            front,
            executor,
            next_id: AtomicU64::new(1),
            next_session: AtomicU64::new(1),
            workers,
            active_streams: AtomicU64::new(0),
            request_timeout: cfg.request_timeout,
        })
    }

    /// Submit a request with default options; returns the response
    /// channel immediately.
    pub fn submit(&self, payload: Payload) -> Result<OnceReceiver<ReplyResult>, ServeError> {
        self.submit_opts(payload, RequestOptions::default())
    }

    /// Submit a request carrying explicit per-request options.  The
    /// request first passes the [`Front`]: a cache hit or a coalesced
    /// join resolves without touching the batcher; otherwise the
    /// batcher's admission control decides (lane quota → immediate
    /// typed `overloaded`, global capacity → blocking backpressure).
    pub fn submit_opts(
        &self,
        payload: Payload,
        options: RequestOptions,
    ) -> Result<OnceReceiver<ReplyResult>, ServeError> {
        if matches!(payload, Payload::Generate { .. }) {
            return Err(ServeError::invalid(
                "generate is a streaming operation; use Coordinator::generate",
            ));
        }
        metrics::global().counter("coordinator.submitted").inc();
        match self.front.admit(&payload, &options) {
            Admission::Resolved(rx) => Ok(rx),
            Admission::Execute(sink, rx) => {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let req = Request::with_options(id, payload, options, sink);
                metrics::global()
                    .gauge("coordinator.queue_depth")
                    .set(self.batcher.depth() as i64);
                self.batcher.submit(req).map_err(reject)?;
                Ok(rx)
            }
        }
    }

    /// Submit without blocking on a full queue (server overload path).
    pub fn try_submit(&self, payload: Payload) -> Result<OnceReceiver<ReplyResult>, ServeError> {
        if matches!(payload, Payload::Generate { .. }) {
            return Err(ServeError::invalid(
                "generate is a streaming operation; use Coordinator::generate",
            ));
        }
        match self.front.admit(&payload, &RequestOptions::default()) {
            Admission::Resolved(rx) => Ok(rx),
            Admission::Execute(sink, rx) => {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let req = Request::new(id, payload, sink);
                self.batcher.try_submit(req).map_err(reject)?;
                Ok(rx)
            }
        }
    }

    /// Submit and wait with a timeout — the blocking convenience path
    /// (default options).
    pub fn call(&self, payload: Payload, timeout: Duration) -> ReplyResult {
        self.call_opts(payload, RequestOptions::default(), timeout)
    }

    /// Submit with explicit options and wait with a timeout.
    pub fn call_opts(
        &self,
        payload: Payload,
        options: RequestOptions,
        timeout: Duration,
    ) -> ReplyResult {
        let t0 = std::time::Instant::now();
        let rx = self.submit_opts(payload, options)?;
        let result = rx.recv_timeout(timeout).map_err(|e| match e {
            RecvError::Timeout => {
                ServeError::deadline(format!("request timed out after {timeout:?}"))
            }
            RecvError::Disconnected => {
                ServeError::internal("coordinator dropped the request reply")
            }
        })?;
        metrics::global()
            .histogram("coordinator.request_us")
            .record(t0.elapsed());
        result
    }

    /// Open a new LM session, returning its id.
    pub fn open_session(&self) -> u64 {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        self.executor.open_session(id);
        id
    }

    pub fn close_session(&self, id: u64) {
        self.executor.close_session(id);
    }

    /// Fork an existing session's state into a fresh session id
    /// (beam-search expansion without replay).
    pub fn fork_session(&self, src: u64) -> Result<u64> {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        self.executor.fork_session(src, id)?;
        Ok(id)
    }

    pub fn executor(&self) -> &Executor {
        &self.executor
    }

    /// Queue depth snapshot (metrics / tests).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Per-class queue depths (the `stats` RPC's `queue_depths`).
    pub fn class_depths(&self) -> Vec<(BatchClass, usize)> {
        self.batcher.class_depths()
    }

    /// This instance's coalescing/cache counters (the `stats` RPC's
    /// `cache` object — per-instance, unlike the process-global
    /// `coordinator.cache.*` metrics).
    pub fn cache_stats(&self) -> FrontStats {
        self.front.stats()
    }

    /// Live server-side generation streams.
    pub fn active_streams(&self) -> u64 {
        self.active_streams.load(Ordering::Relaxed)
    }

    /// The configured default request-handling budget.
    pub fn request_timeout(&self) -> Duration {
        self.request_timeout
    }

    /// Drain and stop: in-flight batches finish, workers join.
    pub fn shutdown(mut self) {
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.executor.shutdown();
    }
}

/// Map a batcher admission rejection to its typed [`ServeError`] and
/// deliver it through the rejected request's own reply sink — so a
/// coalescing leader's rejection fans out to its followers too — then
/// hand the error back for the submitting caller.
fn reject(err: AdmitError) -> ServeError {
    let e = match &err {
        AdmitError::Overloaded { lane, .. } => {
            metrics::global()
                .counter(&format!("coordinator.admission.rejected.{}", lane.as_str()))
                .inc();
            ServeError::overloaded(format!(
                "{} admission quota exhausted; retry with backoff",
                lane.as_str()
            ))
        }
        AdmitError::ShuttingDown(_) => ServeError::shutting_down("coordinator shutting down"),
        AdmitError::Expired(_) => {
            ServeError::deadline("deadline expired before the request was admitted")
        }
    };
    let req = err.into_request();
    let _ = req.reply.send(Err(e.clone()));
    e
}
