//! Property-based testing substrate (no `proptest`/`quickcheck` offline).
//!
//! A compact generate-and-shrink harness:
//!
//! * [`Gen`] — composable random-value generators built on the crate
//!   PRNG ([`crate::rng`]),
//! * [`forall`] — runs a property over N generated cases; on failure it
//!   greedily shrinks the input via the generator's [`Gen::shrink`]
//!   candidates and reports the minimal counterexample,
//! * stock generators for the shapes this crate cares about: logits
//!   vectors (with adversarial magnitude mixes), batch/vocab sizes, and
//!   (m, d) monoid elements.
//!
//! Used by the coordinator-invariant tests (routing, batching, merge)
//! and the numeric-kernel tests.

use crate::rng::Xoshiro256pp;

/// A reproducible generator of `T` with shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    /// Produce one value from the RNG.
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value;

    /// Candidate smaller values (tried in order during shrinking).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Outcome of a [`forall`] run.
#[derive(Debug)]
pub enum PropResult<T> {
    Pass { cases: usize },
    Fail { original: T, minimal: T, shrinks: usize, message: String },
}

impl<T: std::fmt::Debug> PropResult<T> {
    /// Panic with a readable report on failure (for use in #[test]s).
    pub fn unwrap(self) {
        match self {
            PropResult::Pass { .. } => {}
            PropResult::Fail { original, minimal, shrinks, message } => panic!(
                "property failed: {message}\n  original: {original:?}\n  minimal (after {shrinks} shrinks): {minimal:?}"
            ),
        }
    }
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrinks: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 200, seed: 0x05F7_A113, max_shrinks: 500 }
    }
}

/// Check `prop` over `config.cases` generated inputs, shrinking on failure.
///
/// `prop` returns `Ok(())` or a failure message.
pub fn forall_with<G: Gen>(
    config: Config,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) -> PropResult<G::Value> {
    let mut rng = Xoshiro256pp::seed_from_u64(config.seed);
    for _ in 0..config.cases {
        let value = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&value) {
            // Greedy shrink loop.
            let original = value.clone();
            let mut current = value;
            let mut message = first_msg;
            let mut shrinks = 0;
            'outer: while shrinks < config.max_shrinks {
                for cand in gen.shrink(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        message = m;
                        shrinks += 1;
                        continue 'outer;
                    }
                }
                break;
            }
            return PropResult::Fail { original, minimal: current, shrinks, message };
        }
    }
    PropResult::Pass { cases: config.cases }
}

/// [`forall_with`] under the default config.
pub fn forall<G: Gen>(
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) -> PropResult<G::Value> {
    forall_with(Config::default(), gen, prop)
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi]; shrinks toward lo.
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Xoshiro256pp) -> usize {
        self.0 + rng.below((self.1 - self.0 + 1) as u64) as usize
    }

    fn shrink(&self, &v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Logits vector generator covering the numeric regimes the paper's
/// safety analysis cares about: moderate gaussians, large offsets
/// (±80…±200, where naive softmax dies), constants (ties), and mixed
/// per-element magnitudes.  Shrinks by halving length and zeroing tails.
pub struct LogitsVec {
    pub min_len: usize,
    pub max_len: usize,
}

impl Gen for LogitsVec {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Xoshiro256pp) -> Vec<f32> {
        let len = self.min_len + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        match rng.below(5) {
            0 => rng.logits(len, 1.0),
            1 => rng.logits(len, 12.0),
            2 => {
                let off = rng.range_f32(-150.0, 150.0);
                let mut v = rng.logits(len, 2.0);
                v.iter_mut().for_each(|x| *x += off);
                v
            }
            3 => vec![rng.range_f32(-50.0, 50.0); len],
            _ => (0..len)
                .map(|_| {
                    let scale = [0.01f32, 1.0, 40.0][rng.below(3) as usize];
                    rng.next_normal() * scale
                })
                .collect(),
        }
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            let mut z = v.clone();
            let n = z.len();
            z[n / 2..].iter_mut().for_each(|x| *x = 0.0);
            out.push(z);
        }
        out
    }
}

/// Pair generator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Vector of values from an inner generator.
pub struct VecOf<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        let len = self.min_len + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() - 1].to_vec());
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
        }
        // shrink one element
        if let Some(first) = v.first() {
            for cand in self.inner.shrink(first) {
                let mut w = v.clone();
                w[0] = cand;
                out.push(w);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = UsizeRange(1, 100);
        match forall(&gen, |&n| if n >= 1 { Ok(()) } else { Err("n < 1".into()) }) {
            PropResult::Pass { cases } => assert_eq!(cases, 200),
            f => panic!("{f:?}"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let gen = UsizeRange(0, 1000);
        let result = forall(&gen, |&n| if n < 50 { Ok(()) } else { Err(format!("{n} >= 50")) });
        match result {
            PropResult::Fail { minimal, .. } => {
                // greedy shrink should land on a small counterexample
                assert!(minimal >= 50 && minimal <= 75, "minimal={minimal}");
            }
            PropResult::Pass { .. } => panic!("property should fail"),
        }
    }

    #[test]
    fn logits_generator_hits_extreme_regime() {
        let gen = LogitsVec { min_len: 4, max_len: 64 };
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let mut saw_extreme = false;
        for _ in 0..100 {
            let v = gen.generate(&mut rng);
            assert!(v.len() >= 4 && v.len() <= 64);
            if v.iter().any(|&x| x.abs() > 80.0) {
                saw_extreme = true;
            }
        }
        assert!(saw_extreme, "extreme-magnitude regime must be generated");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let gen = VecOf { inner: UsizeRange(0, 9), min_len: 1, max_len: 8 };
        let shrunk = gen.shrink(&vec![1, 2, 3, 4]);
        assert!(shrunk.iter().any(|v| v.len() < 4));
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = LogitsVec { min_len: 1, max_len: 16 };
        let run = |seed| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            gen.generate(&mut rng)
        };
        assert_eq!(run(9), run(9));
    }
}
