//! CLI argument parsing substrate (no `clap` in the offline registry).
//!
//! Subcommand-oriented parser:
//!
//! ```text
//! onlinesoftmax <command> [--flag] [--opt value] [--opt=value] [positional...]
//! ```
//!
//! [`Args`] collects flags/options/positionals with typed accessors and
//! strict unknown-argument rejection, so typos fail loudly instead of
//! silently running a default bench.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positionals: Vec<String>,
    /// Names consumed by typed accessors — used by `finish()` to reject
    /// unknown arguments.
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse raw argv (without the program/subcommand names).
    ///
    /// `value_options` lists option names that consume a following
    /// value (`--name value`); everything else starting with `--` is a
    /// boolean flag.  `--name=value` works for any option.
    pub fn parse(raw: &[String], value_options: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: the rest is positional
                    for rest in it.by_ref() {
                        args.positionals.push(rest.clone());
                    }
                    break;
                }
                if let Some((name, value)) = body.split_once('=') {
                    args.options.entry(name.to_string()).or_default().push(value.to_string());
                } else if value_options.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} requires a value"))?;
                    args.options.entry(body.to_string()).or_default().push(v.clone());
                } else {
                    args.flags.push(body.to_string());
                }
            } else if a.starts_with('-') && a.len() > 1 && !a[1..2].chars().next().unwrap().is_ascii_digit() {
                bail!("short options are not supported: `{a}` (use --long form)");
            } else {
                args.positionals.push(a.clone());
            }
        }
        Ok(args)
    }

    fn mark(&self, name: &str) {
        self.known.borrow_mut().push(name.to_string());
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.mark(name);
        self.flags.iter().any(|f| f == name)
    }

    /// Last occurrence of a string option.
    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences (repeatable options).
    pub fn opt_all(&self, name: &str) -> Vec<&str> {
        self.mark(name);
        self.options.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("invalid value for --{name}: `{s}` ({e})")),
        }
    }

    /// Required typed option.
    pub fn opt_require<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let s = self
            .opt_str(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))?;
        s.parse().map_err(|e| anyhow!("invalid value for --{name}: `{s}` ({e})"))
    }

    /// Comma- or repeat-separated list of typed values.
    pub fn opt_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        let occurrences = self.opt_all(name);
        if occurrences.is_empty() {
            return Ok(default.to_vec());
        }
        occurrences
            .iter()
            .flat_map(|s| s.split(','))
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse()
                    .map_err(|e| anyhow!("invalid element for --{name}: `{s}` ({e})"))
            })
            .collect()
    }

    /// Positional arguments.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Reject any option/flag that no accessor consumed.
    pub fn finish(&self) -> Result<()> {
        let known = self.known.borrow();
        for f in &self.flags {
            if !known.iter().any(|k| k == f) {
                bail!("unknown flag --{f}");
            }
        }
        for name in self.options.keys() {
            if !known.iter().any(|k| k == name) {
                bail!("unknown option --{name}");
            }
        }
        Ok(())
    }
}

/// The top-level `help` text, rendered with the crate `version`.
///
/// Lives in the library (rather than `main.rs`) so the knob inventory
/// is testable: `docs/CONFIG.md` documents every flag in its tables,
/// and the `help_names_every_documented_knob` test below asserts each
/// one appears here — the help text and CONFIG.md cannot silently
/// drift apart.
pub fn help_text(version: &str) -> String {
    format!(
        "onlinesoftmax {version} — Online Normalizer Calculation for Softmax (reproduction)\n\n\
         USAGE:\n  onlinesoftmax <command> [options]\n\n\
         COMMANDS:\n\
           serve      start the vocabulary-softmax serving system\n\
           bench      run the paper's benchmark figures on this CPU\n\
           model      analytic V100/CPU predictions for every figure\n\
           accesses   print the paper's memory-access table\n\
           loadgen    drive a running server with synthetic load\n\
           help       this message\n\n\
         SERVE OPTIONS:\n\
           --config FILE        JSON config (defaults + CLI overrides)\n\
           --addr HOST:PORT     bind address        [127.0.0.1:7070]\n\
           --artifacts DIR      AOT artifacts dir   [artifacts]\n\
           --backend B          auto|artifacts|host|router [auto]\n\
           --mode safe|online   softmax strategy    [online]\n\
           --shards N           vocabulary shards (artifact backend) [1]\n\
           --vocab N            served vocab (host backend)   [8192]\n\
           --hidden N           hidden width (host backend)   [128]\n\
           --host-shards N      shard-engine workers (0=auto) [0]\n\
           --shard-threshold N  sharded-path vocab cutoff     [32768]\n\
           --shard-backend B    per-tile shard scan backend:\n\
                                auto|scalar|vectorized|twopass|artifacts-stub\n\
                                (env default: OSMAX_SHARD_BACKEND) [auto]\n\
           --grid-rows N        rows per batch×shard grid dispatch\n\
                                (0=whole batch, 1=per-row)    [0]\n\
           --pool-sched P       shard-pool scheduler: steal|fifo\n\
                                (env default: OSMAX_POOL_SCHED) [steal]\n\
           --max-batch N        dynamic batch bound [16]\n\
           --max-wait-us N      batch deadline      [2000]\n\
           --queue-capacity N   global admission queue bound  [1024]\n\
           --admission-interactive-cap N  interactive-lane admission\n\
                                quota; excess rejected typed `overloaded`\n\
                                (0 = no lane quota)           [0]\n\
           --admission-batch-cap N  batch-lane admission quota\n\
                                (0 = no lane quota)           [0]\n\
           --cache-capacity N   result-cache entries in the coalescing\n\
                                front (0 = no caching)        [256]\n\
           --cache-coalesce B   dedupe identical in-flight requests\n\
                                into one execution: true|false [true]\n\
           --workers N          executor workers    [2]\n\
           --k N                default decode top-k          [5]\n\
           --request-timeout MS per-request handling budget; per-request\n\
                                deadline_ms tightens it\n\
                                (env default: OSMAX_REQUEST_TIMEOUT) [60000]\n\
           --seed N             synthetic-model RNG seed      [0xC0FFEE]\n\
           --worker-slice S:E   router-tier worker role: assigned vocab\n\
                                slice (advisory; published as gauges)\n\
           --router-workers L   router backend: comma-separated worker\n\
                                host:port list, one vocab slice each\n\
           --router-probe-ms MS router worker health-probe period [500]\n\
           --router-shard-timeout-ms MS  per-shard call budget; a late\n\
                                shard is excluded + requeued    [2000]\n\
           --router-hedge-quantile Q  duplicate a shard still running\n\
                                past this latency quantile onto a\n\
                                second worker (0 = off)         [0]\n\n\
         BENCH OPTIONS:\n\
           --fig 1|2|3|4|k|ablation|grid|steal|backend|sample|cache|all  figure/study  [all]\n\
           --sizes a,b,c        vector sizes V override\n\
           --batch N            batch size override\n\
           --threads N          worker threads for parallel/sharded variants\n\
                                (0 = one per core)                           [1]\n\
           --smoke              minimal sizes/iterations (CI rot check)\n\
           --out FILE           also append results as JSON lines\n\
           --json FILE          write a single machine-readable report\n\
                                document (backend and sample figures)\n\n\
         LOADGEN OPTIONS:\n\
           --addr HOST:PORT     target server       [127.0.0.1:7070]\n\
           --requests N         total requests      [200]\n\
           --concurrency N      worker connections  [4]\n\
           --op O               decode|softmax|generate [decode]\n\
           --tokens N           tokens per generate stream [8]\n\
           --priority P         interactive|batch|mixed (workers\n\
                                alternate per request)  [interactive]\n\
           --deadline-ms MS     per-request deadline (omit for none);\n\
                                typed rejections are tallied, not fatal\n\
           --distinct N         payload variety: cycle N distinct\n\
                                payloads (0 = all unique)     [0]\n\
           --temperature T      sampling temperature sent with every\n\
                                request (values != 1 need --seed)\n\
           --seed N             Gumbel-top-k sampling seed; switches\n\
                                decode/generate ops to seeded sampling\n\
           --target T           single|router|both: which topologies to\n\
                                drive; `both` runs the same load against\n\
                                --addr and --router-addr and reports\n\
                                per-class percentiles for each [single]\n\
           --router-addr H:P    router-tier address for --target\n\
                                router|both       [127.0.0.1:7080]\n"
    )
}

/// Split argv into `(subcommand, rest)`.
pub fn subcommand(argv: &[String]) -> Result<(&str, &[String])> {
    let cmd = argv
        .first()
        .context("missing subcommand (try `onlinesoftmax help`)")?;
    Ok((cmd.as_str(), &argv[1..]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(&sv(&["--v", "4096", "--algo=online", "--verbose", "pos1"]), &["v"])
            .unwrap();
        assert_eq!(a.opt_parse("v", 0usize).unwrap(), 4096);
        assert_eq!(a.opt_str("algo"), Some("online"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
        a.finish().unwrap();
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--v"]), &["v"]).is_err());
    }

    #[test]
    fn unknown_option_rejected_by_finish() {
        let a = Args::parse(&sv(&["--typo=1"]), &[]).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_option_comma_and_repeat() {
        let a = Args::parse(&sv(&["--sizes=1,2", "--sizes", "3"]), &["sizes"]).unwrap();
        assert_eq!(a.opt_list::<usize>("sizes", &[]).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn list_option_default() {
        let a = Args::parse(&sv(&[]), &[]).unwrap();
        assert_eq!(a.opt_list::<usize>("sizes", &[7, 8]).unwrap(), vec![7, 8]);
    }

    #[test]
    fn negative_numbers_are_positional() {
        let a = Args::parse(&sv(&["-5"]), &[]).unwrap();
        assert_eq!(a.positionals(), &["-5".to_string()]);
    }

    #[test]
    fn double_dash_terminates() {
        let a = Args::parse(&sv(&["--x", "--", "--not-a-flag"]), &[]).unwrap();
        assert!(a.flag("x"));
        assert_eq!(a.positionals(), &["--not-a-flag".to_string()]);
        a.finish().unwrap();
    }

    #[test]
    fn required_option() {
        let a = Args::parse(&sv(&[]), &[]).unwrap();
        assert!(a.opt_require::<usize>("n").is_err());
    }

    #[test]
    fn help_names_every_documented_knob() {
        // Every flag documented in docs/CONFIG.md's tables must appear
        // in `--help` — the test that stops CONFIG.md from silently
        // rotting.  Table rows start `| `--flag ...`` by convention.
        let md = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/CONFIG.md"));
        let help = help_text("0.0.0-test");
        let mut checked = 0usize;
        for line in md.lines() {
            let Some(rest) = line.strip_prefix("| `--") else { continue };
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '-')
                .collect();
            assert!(!name.is_empty(), "malformed CONFIG.md table row: {line}");
            let flag = format!("--{name}");
            assert!(
                help.contains(&flag),
                "docs/CONFIG.md documents `{flag}` but `--help` does not mention it"
            );
            checked += 1;
        }
        assert!(
            checked >= 20,
            "expected ≥ 20 documented flags in docs/CONFIG.md tables, found {checked} — \
             did the table format change?"
        );
    }

    #[test]
    fn subcommand_split() {
        let argv = sv(&["bench", "--fig", "1"]);
        let (cmd, rest) = subcommand(&argv).unwrap();
        assert_eq!(cmd, "bench");
        assert_eq!(rest.len(), 2);
        assert!(subcommand(&[]).is_err());
    }
}
