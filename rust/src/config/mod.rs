//! Configuration system: typed config structs loadable from a JSON file
//! with CLI overrides layered on top (file < flags), plus validation.
//!
//! ```text
//! onlinesoftmax serve --config serve.json --port 7070
//! ```

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::exec::SchedPolicy;
use crate::json::{self, Value};
use crate::shard::ShardBackendKind;

/// Which softmax strategy the serving path uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServingMode {
    /// Safe softmax (Algorithm 2) — the framework-default baseline.
    Safe,
    /// Online softmax (Algorithm 3) / fused online top-k (Algorithm 4).
    Online,
}

impl ServingMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "safe" => Ok(ServingMode::Safe),
            "online" => Ok(ServingMode::Online),
            _ => bail!("invalid mode `{s}` (expected `safe` or `online`)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ServingMode::Safe => "safe",
            ServingMode::Online => "online",
        }
    }
}

/// Which execution backend serves requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Artifacts when `artifacts_dir/manifest.json` exists, host
    /// otherwise (the default).
    Auto,
    /// AOT-compiled PJRT artifacts (requires `make artifacts` and the
    /// real xla bindings).
    Artifacts,
    /// In-process host kernels: the shard-reduction engine for large
    /// vocabularies, single-thread kernels below the threshold.
    Host,
    /// Router tier: fan vocabulary shards out over worker processes
    /// (`--router-workers`) as `shard_scan` frames and ⊕-merge the
    /// partials locally (see `docs/ARCHITECTURE.md` §router tier).
    Router,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "artifacts" => Ok(BackendKind::Artifacts),
            "host" => Ok(BackendKind::Host),
            "router" => Ok(BackendKind::Router),
            _ => bail!(
                "invalid backend `{s}` (expected `auto`, `artifacts`, `host`, or `router`)"
            ),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Artifacts => "artifacts",
            BackendKind::Host => "host",
            BackendKind::Router => "router",
        }
    }
}

/// Parse a `START:END` vocabulary slice (half-open, `START < END`).
fn parse_slice(s: &str) -> Result<(usize, usize)> {
    let Some((a, b)) = s.split_once(':') else {
        bail!("invalid slice `{s}` (expected START:END)");
    };
    let start: usize = a.trim().parse().with_context(|| format!("slice start in `{s}`"))?;
    let end: usize = b.trim().parse().with_context(|| format!("slice end in `{s}`"))?;
    if start >= end {
        bail!("invalid slice `{s}`: start must be < end");
    }
    Ok((start, end))
}

/// Full serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP bind address.
    pub addr: String,
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: PathBuf,
    /// Softmax strategy for decode requests.
    pub mode: ServingMode,
    /// Number of vocabulary shards to serve with (1 = unsharded).
    pub shards: usize,
    /// Maximum requests coalesced into one executed batch.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before flushing.
    pub max_wait: Duration,
    /// Admission queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
    /// Per-priority admission quota for `interactive` requests: at
    /// most this many may be queued at once; excess is rejected with
    /// a typed `overloaded` error instead of blocking.  `0` disables
    /// the lane quota (only the global `queue_capacity` applies).
    pub admission_interactive_cap: usize,
    /// Per-priority admission quota for `batch` requests (`0` = off).
    /// A finite batch cap keeps throughput backlog from consuming the
    /// whole queue and blocking interactive admission.
    pub admission_batch_cap: usize,
    /// Result-cache entries in the coalescing front (`0` disables
    /// caching).  Only stateless softmax/decode results are cached.
    pub cache_capacity: usize,
    /// Dedupe identical in-flight requests into one execution with
    /// fan-out replies (the coalescing half of the front).
    pub cache_coalesce: bool,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Default top-k for decode requests that do not specify one.
    pub default_k: usize,
    /// RNG seed for the built-in synthetic model weights.
    pub seed: u64,
    /// Execution backend (auto = artifacts when built, host otherwise).
    pub backend: BackendKind,
    /// Served vocabulary size for the host backend (artifact backends
    /// take theirs from the manifest).
    pub vocab: usize,
    /// Hidden-state width for the host backend.
    pub hidden: usize,
    /// Shard-engine worker threads for the host backend (0 = one per
    /// available core).
    pub host_shards: usize,
    /// Vocabulary length at which host requests route onto the sharded
    /// path; below it the single-thread kernels run inline.
    pub shard_threshold: usize,
    /// Maximum batch rows tiled into one batch×shard grid dispatch on
    /// the host backend (0 = the whole batch; 1 = per-row dispatch, the
    /// degenerate grid).  Results are bitwise-identical for every
    /// setting — this only shapes scheduling.
    pub grid_rows: usize,
    /// Shard-pool scheduling policy: `steal` (per-worker work-stealing
    /// deques, the default) or `fifo` (single shared injector queue).
    /// Results are bitwise-identical under either — only occupancy
    /// under skewed tile costs changes.
    pub pool_sched: SchedPolicy,
    /// Per-tile scan backend for the host shard engine: `auto` (pick
    /// the vectorized lane-split scan whenever the tile geometry
    /// allows, scalar otherwise), `scalar` (the fused host scan —
    /// reference numerics), `vectorized` (lane-split streaming scan),
    /// or `artifacts-stub` (PJRT contract adapter that declines every
    /// tile at runtime, exercising the per-tile host fallback).
    /// Selected indices are identical across backends; see
    /// docs/BACKENDS.md for the per-backend identity guarantees.
    pub shard_backend: ShardBackendKind,
    /// Default per-request handling budget (connection threads give up
    /// on a reply after this long; per-request `deadline_ms` tightens
    /// it, never extends it).  JSON `request_timeout_ms`, CLI
    /// `--request-timeout` (ms), env default `OSMAX_REQUEST_TIMEOUT`.
    pub request_timeout: Duration,
    /// Worker-role marker for a router-tier deployment: the vocabulary
    /// slice this server is assigned, as half-open `(start, end)`.
    /// Advisory (published as `worker.slice.*` gauges) — `shard_scan`
    /// ranges are not restricted to it, so the router can requeue an
    /// excluded worker's slice onto any peer.  JSON/CLI `START:END`.
    pub worker_slice: Option<(usize, usize)>,
    /// Worker addresses for the router backend, one vocabulary slice
    /// per worker (`ShardPlan::with_shards(vocab, workers.len())`).
    /// JSON `router_workers` (string array), CLI `--router-workers`
    /// (comma-separated `host:port` list).
    pub router_workers: Vec<String>,
    /// Router health-probe period in milliseconds.
    pub router_probe_ms: u64,
    /// Router per-shard call budget (connect + roundtrip) in
    /// milliseconds; a shard exceeding it is excluded and requeued.
    pub router_shard_timeout_ms: u64,
    /// Straggler-hedging latency quantile in `[0, 1)`: a shard still
    /// outstanding past this quantile of recent shard latencies is
    /// duplicated onto a second healthy worker (first reply wins).
    /// `0` disables hedging (the default).
    pub router_hedge_quantile: f64,
}

/// `OSMAX_REQUEST_TIMEOUT` (integer milliseconds) overrides the
/// built-in default request timeout; file and CLI layers still
/// override the env.  An invalid value fails fast at startup, same
/// convention as `OSMAX_POOL_SCHED` / `OSMAX_SHARD_BACKEND`.
fn request_timeout_from_env_or(default: Duration) -> Duration {
    match std::env::var("OSMAX_REQUEST_TIMEOUT") {
        Ok(s) => Duration::from_millis(
            s.parse::<u64>().expect("OSMAX_REQUEST_TIMEOUT must be integer milliseconds"),
        ),
        Err(_) => default,
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7070".to_string(),
            artifacts_dir: PathBuf::from("artifacts"),
            mode: ServingMode::Online,
            shards: 1,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_capacity: 1024,
            admission_interactive_cap: 0,
            admission_batch_cap: 0,
            cache_capacity: 256,
            cache_coalesce: true,
            workers: 2,
            default_k: 5,
            seed: 0xC0FFEE,
            backend: BackendKind::Auto,
            vocab: 8192,
            hidden: 128,
            host_shards: 0,
            shard_threshold: 32_768,
            grid_rows: 0,
            // OSMAX_POOL_SCHED (CI's scheduler matrix) overrides the
            // built-in default, exactly like the other env knobs; file
            // and CLI layers still override the env.
            pool_sched: SchedPolicy::from_env_or(SchedPolicy::Steal),
            // OSMAX_SHARD_BACKEND (CI's backend matrix) works the same
            // way: env overrides the built-in `auto`, file and CLI
            // layers override the env.
            shard_backend: ShardBackendKind::from_env_or(ShardBackendKind::Auto),
            request_timeout: request_timeout_from_env_or(Duration::from_secs(60)),
            worker_slice: None,
            router_workers: Vec::new(),
            router_probe_ms: 500,
            router_shard_timeout_ms: 2_000,
            router_hedge_quantile: 0.0,
        }
    }
}

impl ServeConfig {
    /// Load from a JSON file (all fields optional).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing config {}", path.display()))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut cfg = ServeConfig::default();
        if let Some(s) = v.get("addr").and_then(Value::as_str) {
            cfg.addr = s.to_string();
        }
        if let Some(s) = v.get("artifacts_dir").and_then(Value::as_str) {
            cfg.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = v.get("mode").and_then(Value::as_str) {
            cfg.mode = ServingMode::parse(s)?;
        }
        if let Some(n) = v.get("shards").and_then(Value::as_usize) {
            cfg.shards = n;
        }
        if let Some(n) = v.get("max_batch").and_then(Value::as_usize) {
            cfg.max_batch = n;
        }
        if let Some(n) = v.get("max_wait_us").and_then(Value::as_usize) {
            cfg.max_wait = Duration::from_micros(n as u64);
        }
        if let Some(n) = v.get("queue_capacity").and_then(Value::as_usize) {
            cfg.queue_capacity = n;
        }
        if let Some(n) = v.get("admission_interactive_cap").and_then(Value::as_usize) {
            cfg.admission_interactive_cap = n;
        }
        if let Some(n) = v.get("admission_batch_cap").and_then(Value::as_usize) {
            cfg.admission_batch_cap = n;
        }
        if let Some(n) = v.get("cache_capacity").and_then(Value::as_usize) {
            cfg.cache_capacity = n;
        }
        if let Some(b) = v.get("cache_coalesce").and_then(Value::as_bool) {
            cfg.cache_coalesce = b;
        }
        if let Some(n) = v.get("workers").and_then(Value::as_usize) {
            cfg.workers = n;
        }
        if let Some(n) = v.get("default_k").and_then(Value::as_usize) {
            cfg.default_k = n;
        }
        if let Some(n) = v.get("seed").and_then(Value::as_i64) {
            cfg.seed = n as u64;
        }
        if let Some(s) = v.get("backend").and_then(Value::as_str) {
            cfg.backend = BackendKind::parse(s)?;
        }
        if let Some(n) = v.get("vocab").and_then(Value::as_usize) {
            cfg.vocab = n;
        }
        if let Some(n) = v.get("hidden").and_then(Value::as_usize) {
            cfg.hidden = n;
        }
        if let Some(n) = v.get("host_shards").and_then(Value::as_usize) {
            cfg.host_shards = n;
        }
        if let Some(n) = v.get("shard_threshold").and_then(Value::as_usize) {
            cfg.shard_threshold = n;
        }
        if let Some(n) = v.get("grid_rows").and_then(Value::as_usize) {
            cfg.grid_rows = n;
        }
        if let Some(s) = v.get("pool_sched").and_then(Value::as_str) {
            cfg.pool_sched = SchedPolicy::parse(s)?;
        }
        if let Some(s) = v.get("shard_backend").and_then(Value::as_str) {
            cfg.shard_backend = ShardBackendKind::parse(s)?;
        }
        if let Some(n) = v.get("request_timeout_ms").and_then(Value::as_usize) {
            cfg.request_timeout = Duration::from_millis(n as u64);
        }
        if let Some(s) = v.get("worker_slice").and_then(Value::as_str) {
            cfg.worker_slice = Some(parse_slice(s)?);
        }
        if let Some(arr) = v.get("router_workers").and_then(Value::as_array) {
            cfg.router_workers = arr
                .iter()
                .map(|w| {
                    w.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow::anyhow!("router_workers must be strings"))
                })
                .collect::<Result<Vec<String>>>()?;
        }
        if let Some(n) = v.get("router_probe_ms").and_then(Value::as_usize) {
            cfg.router_probe_ms = n as u64;
        }
        if let Some(n) = v.get("router_shard_timeout_ms").and_then(Value::as_usize) {
            cfg.router_shard_timeout_ms = n as u64;
        }
        if let Some(q) = v.get("router_hedge_quantile").and_then(Value::as_f64) {
            cfg.router_hedge_quantile = q;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Layer CLI flags over the current values.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(a) = args.opt_str("addr") {
            self.addr = a.to_string();
        }
        if let Some(d) = args.opt_str("artifacts") {
            self.artifacts_dir = PathBuf::from(d);
        }
        if let Some(m) = args.opt_str("mode") {
            self.mode = ServingMode::parse(m)?;
        }
        self.shards = args.opt_parse("shards", self.shards)?;
        self.max_batch = args.opt_parse("max-batch", self.max_batch)?;
        self.max_wait =
            Duration::from_micros(args.opt_parse("max-wait-us", self.max_wait.as_micros() as u64)?);
        self.queue_capacity = args.opt_parse("queue-capacity", self.queue_capacity)?;
        self.admission_interactive_cap =
            args.opt_parse("admission-interactive-cap", self.admission_interactive_cap)?;
        self.admission_batch_cap =
            args.opt_parse("admission-batch-cap", self.admission_batch_cap)?;
        self.cache_capacity = args.opt_parse("cache-capacity", self.cache_capacity)?;
        self.cache_coalesce = args.opt_parse("cache-coalesce", self.cache_coalesce)?;
        self.workers = args.opt_parse("workers", self.workers)?;
        self.default_k = args.opt_parse("k", self.default_k)?;
        self.seed = args.opt_parse("seed", self.seed)?;
        if let Some(b) = args.opt_str("backend") {
            self.backend = BackendKind::parse(b)?;
        }
        self.vocab = args.opt_parse("vocab", self.vocab)?;
        self.hidden = args.opt_parse("hidden", self.hidden)?;
        self.host_shards = args.opt_parse("host-shards", self.host_shards)?;
        self.shard_threshold = args.opt_parse("shard-threshold", self.shard_threshold)?;
        self.grid_rows = args.opt_parse("grid-rows", self.grid_rows)?;
        if let Some(s) = args.opt_str("pool-sched") {
            self.pool_sched = SchedPolicy::parse(s)?;
        }
        if let Some(s) = args.opt_str("shard-backend") {
            self.shard_backend = ShardBackendKind::parse(s)?;
        }
        self.request_timeout = Duration::from_millis(
            args.opt_parse("request-timeout", self.request_timeout.as_millis() as u64)?,
        );
        if let Some(s) = args.opt_str("worker-slice") {
            self.worker_slice = Some(parse_slice(s)?);
        }
        if let Some(s) = args.opt_str("router-workers") {
            self.router_workers =
                s.split(',').map(str::trim).filter(|w| !w.is_empty()).map(str::to_string).collect();
        }
        self.router_probe_ms = args.opt_parse("router-probe-ms", self.router_probe_ms)?;
        self.router_shard_timeout_ms =
            args.opt_parse("router-shard-timeout-ms", self.router_shard_timeout_ms)?;
        self.router_hedge_quantile =
            args.opt_parse("router-hedge-quantile", self.router_hedge_quantile)?;
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            bail!("shards must be >= 1");
        }
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.queue_capacity < self.max_batch {
            bail!(
                "queue_capacity ({}) must be >= max_batch ({})",
                self.queue_capacity,
                self.max_batch
            );
        }
        for (name, cap) in [
            ("admission_interactive_cap", self.admission_interactive_cap),
            ("admission_batch_cap", self.admission_batch_cap),
        ] {
            if cap > self.queue_capacity {
                bail!(
                    "{name} ({cap}) must be <= queue_capacity ({}); \
                     use 0 to disable the lane quota",
                    self.queue_capacity
                );
            }
        }
        if self.default_k == 0 {
            bail!("default_k must be >= 1");
        }
        if self.vocab == 0 {
            bail!("vocab must be >= 1");
        }
        if self.hidden == 0 {
            bail!("hidden must be >= 1");
        }
        if self.shard_threshold == 0 {
            bail!("shard_threshold must be >= 1");
        }
        if self.request_timeout.is_zero() {
            bail!("request_timeout must be > 0");
        }
        if let Some((start, end)) = self.worker_slice {
            // start < end is parse-enforced for CLI/JSON, but keep the
            // invariant here too for programmatic construction.
            if start >= end {
                bail!("worker_slice start ({start}) must be < end ({end})");
            }
            if end > self.vocab {
                bail!("worker_slice end ({end}) exceeds vocab ({})", self.vocab);
            }
        }
        if !(0.0..1.0).contains(&self.router_hedge_quantile) {
            bail!(
                "router_hedge_quantile ({}) must be in [0, 1); 0 disables hedging",
                self.router_hedge_quantile
            );
        }
        if self.backend == BackendKind::Router {
            if self.router_workers.is_empty() {
                bail!("backend `router` requires router_workers (--router-workers)");
            }
            if self.vocab < self.router_workers.len() {
                bail!(
                    "vocab ({}) cannot be sliced over {} router workers",
                    self.vocab,
                    self.router_workers.len()
                );
            }
            if self.router_probe_ms == 0 {
                bail!("router_probe_ms must be > 0");
            }
            if self.router_shard_timeout_ms == 0 {
                bail!("router_shard_timeout_ms must be > 0");
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set("addr", Value::String(self.addr.clone()))
            .set("artifacts_dir", Value::String(self.artifacts_dir.display().to_string()))
            .set("mode", Value::String(self.mode.as_str().to_string()))
            .set("shards", Value::Number(self.shards as f64))
            .set("max_batch", Value::Number(self.max_batch as f64))
            .set("max_wait_us", Value::Number(self.max_wait.as_micros() as f64))
            .set("queue_capacity", Value::Number(self.queue_capacity as f64))
            .set(
                "admission_interactive_cap",
                Value::Number(self.admission_interactive_cap as f64),
            )
            .set("admission_batch_cap", Value::Number(self.admission_batch_cap as f64))
            .set("cache_capacity", Value::Number(self.cache_capacity as f64))
            .set("cache_coalesce", Value::Bool(self.cache_coalesce))
            .set("workers", Value::Number(self.workers as f64))
            .set("default_k", Value::Number(self.default_k as f64))
            .set("seed", Value::Number(self.seed as f64))
            .set("backend", Value::String(self.backend.as_str().to_string()))
            .set("vocab", Value::Number(self.vocab as f64))
            .set("hidden", Value::Number(self.hidden as f64))
            .set("host_shards", Value::Number(self.host_shards as f64))
            .set("shard_threshold", Value::Number(self.shard_threshold as f64))
            .set("grid_rows", Value::Number(self.grid_rows as f64))
            .set("pool_sched", Value::String(self.pool_sched.as_str().to_string()))
            .set("shard_backend", Value::String(self.shard_backend.as_str().to_string()))
            .set(
                "request_timeout_ms",
                Value::Number(self.request_timeout.as_millis() as f64),
            )
            .set(
                "router_workers",
                Value::Array(
                    self.router_workers
                        .iter()
                        .map(|w| Value::String(w.clone()))
                        .collect(),
                ),
            )
            .set("router_probe_ms", Value::Number(self.router_probe_ms as f64))
            .set(
                "router_shard_timeout_ms",
                Value::Number(self.router_shard_timeout_ms as f64),
            )
            .set("router_hedge_quantile", Value::Number(self.router_hedge_quantile));
        if let Some((start, end)) = self.worker_slice {
            v.set("worker_slice", Value::String(format!("{start}:{end}")));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ServeConfig::default();
        cfg.shards = 4;
        cfg.mode = ServingMode::Safe;
        cfg.backend = BackendKind::Host;
        cfg.vocab = 4096;
        cfg.host_shards = 6;
        cfg.shard_threshold = 1024;
        cfg.grid_rows = 8;
        cfg.pool_sched = SchedPolicy::Fifo;
        cfg.shard_backend = ShardBackendKind::Vectorized;
        cfg.request_timeout = Duration::from_millis(2500);
        cfg.admission_interactive_cap = 64;
        cfg.admission_batch_cap = 32;
        cfg.cache_capacity = 9;
        cfg.cache_coalesce = false;
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.shards, 4);
        assert_eq!(back.request_timeout, Duration::from_millis(2500));
        assert_eq!(back.mode, ServingMode::Safe);
        assert_eq!(back.addr, cfg.addr);
        assert_eq!(back.backend, BackendKind::Host);
        assert_eq!(back.vocab, 4096);
        assert_eq!(back.hidden, cfg.hidden);
        assert_eq!(back.host_shards, 6);
        assert_eq!(back.shard_threshold, 1024);
        assert_eq!(back.grid_rows, 8);
        assert_eq!(back.pool_sched, SchedPolicy::Fifo);
        assert_eq!(back.shard_backend, ShardBackendKind::Vectorized);
        assert_eq!(back.admission_interactive_cap, 64);
        assert_eq!(back.admission_batch_cap, 32);
        assert_eq!(back.cache_capacity, 9);
        assert!(!back.cache_coalesce);
    }

    #[test]
    fn admission_and_cache_knobs_from_cli() {
        let mut cfg = ServeConfig::default();
        assert_eq!(cfg.admission_interactive_cap, 0, "lane quotas default off");
        assert_eq!(cfg.admission_batch_cap, 0);
        assert!(cfg.cache_coalesce, "coalescing defaults on");
        let raw: Vec<String> = [
            "--admission-interactive-cap", "128", "--admission-batch-cap", "16",
            "--cache-capacity", "0", "--cache-coalesce", "false",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(
            &raw,
            &["admission-interactive-cap", "admission-batch-cap", "cache-capacity",
              "cache-coalesce"],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.admission_interactive_cap, 128);
        assert_eq!(cfg.admission_batch_cap, 16);
        assert_eq!(cfg.cache_capacity, 0);
        assert!(!cfg.cache_coalesce);
    }

    #[test]
    fn validation_rejects_lane_cap_above_queue_capacity() {
        let mut cfg = ServeConfig::default();
        cfg.queue_capacity = 64;
        cfg.admission_batch_cap = 65;
        assert!(cfg.validate().is_err());
        cfg.admission_batch_cap = 64;
        cfg.validate().unwrap();
        cfg.admission_interactive_cap = 1000;
        assert!(cfg.validate().is_err());
        cfg.admission_interactive_cap = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn cli_overrides_file_values() {
        let mut cfg = ServeConfig::default();
        let raw: Vec<String> = [
            "--mode", "safe", "--shards", "8", "--max-wait-us", "500",
            "--request-timeout", "1500",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args =
            Args::parse(&raw, &["mode", "shards", "max-wait-us", "request-timeout"]).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.mode, ServingMode::Safe);
        assert_eq!(cfg.shards, 8);
        assert_eq!(cfg.max_wait, Duration::from_micros(500));
        assert_eq!(cfg.request_timeout, Duration::from_millis(1500));
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut cfg = ServeConfig::default();
        cfg.shards = 0;
        assert!(cfg.validate().is_err());
        cfg = ServeConfig::default();
        cfg.queue_capacity = 1;
        cfg.max_batch = 16;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn mode_parse() {
        assert!(ServingMode::parse("bogus").is_err());
        assert_eq!(ServingMode::parse("online").unwrap(), ServingMode::Online);
    }

    #[test]
    fn backend_parse_and_cli_override() {
        assert!(BackendKind::parse("gpu").is_err());
        assert_eq!(BackendKind::parse("host").unwrap(), BackendKind::Host);
        assert_eq!(BackendKind::parse("artifacts").unwrap(), BackendKind::Artifacts);

        let mut cfg = ServeConfig::default();
        let raw: Vec<String> = [
            "--backend", "host", "--vocab", "2048", "--shard-threshold", "512",
            "--grid-rows", "4", "--pool-sched", "fifo", "--shard-backend", "scalar",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(
            &raw,
            &["backend", "vocab", "shard-threshold", "grid-rows", "pool-sched",
              "shard-backend"],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.backend, BackendKind::Host);
        assert_eq!(cfg.vocab, 2048);
        assert_eq!(cfg.shard_threshold, 512);
        assert_eq!(cfg.grid_rows, 4);
        assert_eq!(cfg.pool_sched, SchedPolicy::Fifo);
        assert_eq!(cfg.shard_backend, ShardBackendKind::Scalar);
    }

    #[test]
    fn shard_backend_rejects_unknown_values() {
        let v = json::parse(r#"{"shard_backend": "tpu"}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"shard_backend": "artifacts-stub"}"#).unwrap();
        assert_eq!(
            ServeConfig::from_json(&v).unwrap().shard_backend,
            ShardBackendKind::ArtifactsStub
        );
    }

    #[test]
    fn pool_sched_rejects_unknown_values() {
        let v = json::parse(r#"{"pool_sched": "lifo"}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"pool_sched": "steal"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&v).unwrap().pool_sched, SchedPolicy::Steal);
    }

    #[test]
    fn validation_rejects_zero_request_timeout() {
        let mut cfg = ServeConfig::default();
        cfg.request_timeout = Duration::ZERO;
        assert!(cfg.validate().is_err());
        let v = json::parse(r#"{"request_timeout_ms": 250}"#).unwrap();
        assert_eq!(
            ServeConfig::from_json(&v).unwrap().request_timeout,
            Duration::from_millis(250)
        );
    }

    #[test]
    fn router_knobs_roundtrip_and_cli() {
        let mut cfg = ServeConfig::default();
        cfg.backend = BackendKind::Router;
        cfg.router_workers =
            vec!["127.0.0.1:7071".to_string(), "127.0.0.1:7072".to_string()];
        cfg.router_probe_ms = 250;
        cfg.router_shard_timeout_ms = 750;
        cfg.router_hedge_quantile = 0.9;
        cfg.worker_slice = Some((0, 1024));
        cfg.validate().unwrap();
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.backend, BackendKind::Router);
        assert_eq!(back.router_workers, cfg.router_workers);
        assert_eq!(back.router_probe_ms, 250);
        assert_eq!(back.router_shard_timeout_ms, 750);
        assert_eq!(back.router_hedge_quantile, 0.9);
        assert_eq!(back.worker_slice, Some((0, 1024)));

        let mut cfg = ServeConfig::default();
        let raw: Vec<String> = [
            "--backend", "router",
            "--router-workers", "a:1, b:2,c:3",
            "--router-probe-ms", "100",
            "--router-shard-timeout-ms", "300",
            "--router-hedge-quantile", "0.95",
            "--worker-slice", "128:4096",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(
            &raw,
            &["backend", "router-workers", "router-probe-ms", "router-shard-timeout-ms",
              "router-hedge-quantile", "worker-slice"],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.backend, BackendKind::Router);
        assert_eq!(cfg.router_workers, vec!["a:1", "b:2", "c:3"]);
        assert_eq!(cfg.router_probe_ms, 100);
        assert_eq!(cfg.router_shard_timeout_ms, 300);
        assert_eq!(cfg.router_hedge_quantile, 0.95);
        assert_eq!(cfg.worker_slice, Some((128, 4096)));
    }

    #[test]
    fn router_validation_rejects_nonsense() {
        assert_eq!(BackendKind::parse("router").unwrap(), BackendKind::Router);
        assert!(BackendKind::parse("proxy").is_err());

        // router backend without workers
        let mut cfg = ServeConfig::default();
        cfg.backend = BackendKind::Router;
        assert!(cfg.validate().is_err());

        // more workers than vocabulary entries
        cfg.router_workers = (0..4).map(|i| format!("w:{i}")).collect();
        cfg.vocab = 3;
        assert!(cfg.validate().is_err());

        // hedge quantile outside [0, 1)
        let mut cfg = ServeConfig::default();
        cfg.router_hedge_quantile = 1.0;
        assert!(cfg.validate().is_err());
        cfg.router_hedge_quantile = -0.1;
        assert!(cfg.validate().is_err());
        cfg.router_hedge_quantile = 0.99;
        cfg.validate().unwrap();

        // malformed slices
        assert!(parse_slice("10").is_err());
        assert!(parse_slice("5:5").is_err());
        assert!(parse_slice("9:4").is_err());
        assert!(parse_slice("x:4").is_err());
        assert_eq!(parse_slice(" 4 : 9 ").unwrap(), (4, 9));

        // slice beyond the served vocab
        let mut cfg = ServeConfig::default();
        cfg.worker_slice = Some((0, cfg.vocab + 1));
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_host_dims() {
        let mut cfg = ServeConfig::default();
        cfg.vocab = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ServeConfig::default();
        cfg.hidden = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ServeConfig::default();
        cfg.shard_threshold = 0;
        assert!(cfg.validate().is_err());
    }
}
