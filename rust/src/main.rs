//! `onlinesoftmax` — CLI for the Online Softmax serving system.
//!
//! ```text
//! onlinesoftmax serve   [--config f.json] [--addr ..] [--mode safe|online] [--shards N] ...
//! onlinesoftmax bench   [--fig 1|2|3|4|k|all] [--sizes ..] [--threads N] [--json FILE]
//! onlinesoftmax model   [--device v100|cpu]         # analytic predictions
//! onlinesoftmax accesses                            # the paper's access table
//! onlinesoftmax loadgen [--addr ..] [--requests N] [--concurrency C]
//!                       [--op decode|softmax|generate] [--tokens N]
//!                       [--priority interactive|batch|mixed]
//!                       [--deadline-ms MS] [--distinct N]
//!                       [--temperature T] [--seed S]
//! onlinesoftmax help
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use onlinesoftmax::analytic::{DeviceModel, Pipeline};
use onlinesoftmax::benchkit::Table;
use onlinesoftmax::cli::{subcommand, Args};
use onlinesoftmax::config::ServeConfig;
use onlinesoftmax::coordinator::Coordinator;
use onlinesoftmax::server::{client::Client, Server};
use onlinesoftmax::{benches, logging};

const VALUE_OPTS: &[&str] = &[
    "config", "addr", "artifacts", "mode", "shards", "max-batch", "max-wait-us",
    "queue-capacity", "workers", "k", "seed", "fig", "sizes", "batch", "threads",
    "device", "requests", "concurrency", "op", "out", "json", "backend", "vocab", "hidden",
    "host-shards", "shard-threshold", "grid-rows", "pool-sched", "shard-backend",
    "request-timeout", "tokens", "admission-interactive-cap", "admission-batch-cap",
    "cache-capacity", "cache-coalesce", "priority", "deadline-ms", "distinct",
    "temperature", "worker-slice", "router-workers", "router-probe-ms",
    "router-shard-timeout-ms", "router-hedge-quantile", "target", "router-addr",
];

fn main() {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let (cmd, rest) = subcommand(argv)?;
    let args = Args::parse(rest, VALUE_OPTS)?;
    match cmd {
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "model" => cmd_model(&args),
        "accesses" => cmd_accesses(&args),
        "loadgen" => cmd_loadgen(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow!("unknown command `{other}` (try `help`)")),
    }
}

fn print_help() {
    // The text lives in `cli::help_text` so the knob inventory is
    // testable against docs/CONFIG.md.
    println!("{}", onlinesoftmax::cli::help_text(onlinesoftmax::VERSION));
}

// ---------------------------------------------------------------------------

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => ServeConfig::from_file(std::path::Path::new(path))?,
        None => ServeConfig::default(),
    };
    cfg.apply_args(args)?;
    args.finish()?;
    onlinesoftmax::info!("main", "starting coordinator: {}", cfg.to_json().to_json());
    let coordinator = Arc::new(Coordinator::start(&cfg)?);
    let server = Server::bind(&cfg.addr, coordinator, 32)?;
    server.serve()
}

fn cmd_bench(args: &Args) -> Result<()> {
    let fig = args.opt_str("fig").unwrap_or("all").to_string();
    let sizes = args.opt_list::<usize>("sizes", &[])?;
    let batch = args.opt_parse("batch", 0usize)?;
    let threads = args.opt_parse("threads", 1usize)?;
    let smoke = args.flag("smoke");
    let out = args.opt_str("out").map(|s| s.to_string());
    let json_report = args.opt_str("json").map(|s| s.to_string());
    args.finish()?;
    if smoke {
        // Smoke runs exist to prove the bench binaries still build and
        // execute (CI), not to measure — shrink the harness budgets.
        std::env::set_var("OSMAX_BENCH_FAST", "1");
    }
    let opts = benches::BenchOpts {
        sizes: if sizes.is_empty() { None } else { Some(sizes) },
        batch: if batch == 0 { None } else { Some(batch) },
        threads,
        smoke,
        json_out: out,
        json_report,
    };
    match fig.as_str() {
        "1" => benches::fig1(&opts),
        "2" => benches::fig2(&opts),
        "3" => benches::fig3(&opts),
        "4" => benches::fig4(&opts),
        "k" => benches::k_sweep(&opts),
        "ablation" | "shard" => benches::shard_ablation(&opts),
        "grid" => benches::grid_ablation(&opts),
        "steal" => benches::steal_ablation(&opts),
        "backend" => benches::backend_ablation(&opts),
        "sample" => benches::sample_ablation(&opts),
        "cache" => benches::cache_fig(&opts),
        "all" => {
            benches::fig1(&opts)?;
            benches::fig2(&opts)?;
            benches::fig3(&opts)?;
            benches::fig4(&opts)?;
            benches::k_sweep(&opts)?;
            benches::shard_ablation(&opts)?;
            benches::grid_ablation(&opts)?;
            benches::steal_ablation(&opts)?;
            benches::backend_ablation(&opts)?;
            benches::sample_ablation(&opts)?;
            benches::cache_fig(&opts)
        }
        other => Err(anyhow!(
            "unknown figure `{other}` (1|2|3|4|k|ablation|grid|steal|backend|sample|cache|all)"
        )),
    }
}

fn cmd_model(args: &Args) -> Result<()> {
    let device = args.opt_str("device").unwrap_or("v100").to_string();
    args.finish()?;
    let dev = match device.as_str() {
        "v100" => DeviceModel::v100(),
        "cpu" => DeviceModel::measured_cpu(),
        other => return Err(anyhow!("unknown device `{other}` (v100|cpu)")),
    };
    println!("analytic model: {}\n", dev.name);

    println!("— softmax speedup over safe (paper fig 1/2 bars) —");
    let mut t = Table::new(&["V", "batch 4000: online/safe", "batch 10: online/safe"]);
    for v in [10, 100, 1000, 4000, 10_000, 25_000, 50_000, 100_000] {
        t.row(vec![
            v.to_string(),
            format!("{:.2}x", dev.speedup(Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax, v, 4000)),
            format!("{:.2}x", dev.speedup(Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax, v, 10)),
        ]);
    }
    println!("{}", t.render());

    println!("— softmax+topk speedup over safe-unfused (paper fig 3/4 bars) —");
    let mut t = Table::new(&[
        "V",
        "batch 4000: online-fused",
        "batch 4000: safe-fused",
        "batch 10: online-fused",
    ]);
    for v in [100, 1000, 4000, 10_000, 25_000, 50_000] {
        t.row(vec![
            v.to_string(),
            format!(
                "{:.2}x",
                dev.speedup(Pipeline::SafeUnfusedTopK, Pipeline::OnlineFusedTopK, v, 4000)
            ),
            format!(
                "{:.2}x",
                dev.speedup(Pipeline::SafeUnfusedTopK, Pipeline::SafeFusedTopK, v, 4000)
            ),
            format!(
                "{:.2}x",
                dev.speedup(Pipeline::SafeUnfusedTopK, Pipeline::OnlineFusedTopK, v, 10)
            ),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper-reported: softmax ~1.3x @ V≥4000 batch 4000, ~1.15x batch 10;\n\
         fused ~5x @ V=25000 batch 4000, 1.5–2.5x batch 10."
    );
    Ok(())
}

fn cmd_accesses(args: &Args) -> Result<()> {
    args.finish()?;
    println!("memory accesses per input element (paper §2–§4):\n");
    let mut t = Table::new(&["pipeline", "loads", "stores", "total", "passes", "launches"]);
    for p in Pipeline::SOFTMAX.iter().chain(Pipeline::TOPK.iter()) {
        let c = p.accesses();
        t.row(vec![
            p.name().to_string(),
            c.loads.to_string(),
            c.stores.to_string(),
            c.total().to_string(),
            c.passes.to_string(),
            p.launches().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("ratios: safe/online = 4/3 ≈ 1.33x; safe-unfused/online-fused = 5/1 = 5x");
    Ok(())
}

/// Per-priority-class outcome tally for one loadgen run.  Structured
/// rejections (`overloaded`, `deadline_exceeded`) are counted, not
/// fatal — the overload CI smoke asserts on this summary.
#[derive(Default)]
struct ClassTally {
    ok: Vec<Duration>,
    overloaded: usize,
    deadline: usize,
    other: usize,
}

impl ClassTally {
    fn merge(&mut self, mut other: ClassTally) {
        self.ok.append(&mut other.ok);
        self.overloaded += other.overloaded;
        self.deadline += other.deadline;
        self.other += other.other;
    }

    fn attempts(&self) -> usize {
        self.ok.len() + self.overloaded + self.deadline + self.other
    }
}

/// Deterministic payload for a `--distinct` slot: identical bits for
/// the same slot across workers and repeats, so the server's result
/// cache and coalescer can hit.
fn slot_logits(slot: usize, n: usize, scale: f32) -> Vec<f32> {
    let mut rng = onlinesoftmax::rng::Xoshiro256pp::seed_from_u64(0xD15C + slot as u64);
    rng.logits(n, scale)
}

/// One loadgen run's knobs, shared across `--target` topologies so the
/// comparison mode drives identical workloads at both tiers.
struct LoadOpts {
    requests: usize,
    concurrency: usize,
    op: String,
    tokens: usize,
    priority: String,
    deadline_ms: Option<u64>,
    distinct: usize,
    sample_seed: Option<u64>,
    temperature: Option<f32>,
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let addr = args.opt_str("addr").unwrap_or("127.0.0.1:7070").to_string();
    let router_addr = args.opt_str("router-addr").unwrap_or("127.0.0.1:7080").to_string();
    let target = args.opt_str("target").unwrap_or("single").to_string();
    let requests: usize = args.opt_parse("requests", 200)?;
    let concurrency: usize = args.opt_parse("concurrency", 4)?;
    let op = args.opt_str("op").unwrap_or("decode").to_string();
    // Tokens per stream for `--op generate` (each "request" is one
    // whole server-side stream).
    let tokens: usize = args.opt_parse("tokens", 8)?;
    let priority = args.opt_str("priority").unwrap_or("interactive").to_string();
    let deadline_ms: Option<u64> = match args.opt_str("deadline-ms") {
        Some(s) => Some(
            s.parse()
                .map_err(|_| anyhow!("--deadline-ms expects milliseconds, got `{s}`"))?,
        ),
        None => None,
    };
    // Payload variety: workers cycle through `distinct` payload slots
    // (identical bits across workers, so the server's result cache can
    // hit); 0 = every request unique.
    let distinct: usize = args.opt_parse("distinct", 0)?;
    // Sampling knobs: a seed switches decode/lm_step/generate requests
    // to seeded Gumbel-top-k sampling (sent verbatim on every request,
    // so identical payloads still coalesce); temperature != 1 requires
    // a seed, mirroring the server's rule.
    let sample_seed: Option<u64> = match args.opt_str("seed") {
        Some(s) => Some(
            s.parse().map_err(|_| anyhow!("--seed expects a non-negative integer, got `{s}`"))?,
        ),
        None => None,
    };
    let temperature: Option<f32> = match args.opt_str("temperature") {
        Some(s) => Some(
            s.parse().map_err(|_| anyhow!("--temperature expects a number, got `{s}`"))?,
        ),
        None => None,
    };
    args.finish()?;
    if !matches!(priority.as_str(), "interactive" | "batch" | "mixed") {
        return Err(anyhow!(
            "unknown priority `{priority}` (interactive|batch|mixed)"
        ));
    }
    let opts = LoadOpts {
        requests,
        concurrency,
        op,
        tokens,
        priority,
        deadline_ms,
        distinct,
        sample_seed,
        temperature,
    };

    // `--target` selects the topologies: `single` and `router` drive
    // one address; `both` runs the same workload against each tier in
    // turn and reports per-class percentiles side by side.
    let runs: Vec<(&str, &str)> = match target.as_str() {
        "single" => vec![("single", addr.as_str())],
        "router" => vec![("router", router_addr.as_str())],
        "both" => vec![("single", addr.as_str()), ("router", router_addr.as_str())],
        other => return Err(anyhow!("unknown target `{other}` (single|router|both)")),
    };
    let mut any_progress = false;
    for (topology, run_addr) in runs {
        let (wall, tallies) = run_load(run_addr, &opts)?;
        report_load(topology, run_addr, &opts, wall, &tallies);
        let ok_total = tallies[0].ok.len() + tallies[1].ok.len();
        let structured = tallies[0].overloaded
            + tallies[1].overloaded
            + tallies[0].deadline
            + tallies[1].deadline;
        if ok_total > 0 || structured > 0 {
            any_progress = true;
        }
    }
    if !any_progress {
        return Err(anyhow!("no successful requests"));
    }
    Ok(())
}

/// Drive one address with `opts`; returns the wall time and the
/// `[interactive, batch]` tallies merged across workers.
fn run_load(addr: &str, opts: &LoadOpts) -> Result<(Duration, [ClassTally; 2])> {
    use onlinesoftmax::coordinator::ErrorCode;
    use onlinesoftmax::server::wire;

    let LoadOpts {
        requests,
        concurrency,
        tokens,
        deadline_ms,
        distinct,
        sample_seed,
        temperature,
        ..
    } = *opts;

    // Probe connection (fail fast if the server is down).
    let mut probe = Client::connect(addr)?;
    probe.ping()?;

    let per_worker = requests.div_ceil(concurrency);
    let t0 = Instant::now();
    // [interactive, batch] tallies merged across workers.
    let tallies: [ClassTally; 2] = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..concurrency)
            .map(|w| {
                let op = opts.op.as_str();
                let priority = opts.priority.as_str();
                scope.spawn(move || -> Result<[ClassTally; 2]> {
                    let mut client = Client::connect(addr)?;
                    client.set_tag(Some(&format!("loadgen-{w}")));
                    client.set_deadline_ms(deadline_ms);
                    client.set_temperature(temperature);
                    client.set_seed(sample_seed);
                    let mut rng =
                        onlinesoftmax::rng::Xoshiro256pp::seed_from_u64(w as u64 + 1);
                    let mut tally = [ClassTally::default(), ClassTally::default()];
                    for r in 0..per_worker {
                        let class = match priority {
                            "batch" => 1,
                            "mixed" => (w + r) % 2,
                            _ => 0,
                        };
                        client.set_priority(Some(if class == 0 {
                            "interactive"
                        } else {
                            "batch"
                        }));
                        // Slot-seeded payloads are bit-identical across
                        // workers and repeats; slot 0 = unique payloads
                        // from the per-worker stream.
                        let slot = if distinct > 0 { Some(r % distinct) } else { None };
                        let t = Instant::now();
                        let res: Result<()> = (|| {
                            match op {
                                "softmax" => {
                                    let logits = match slot {
                                        Some(s) => slot_logits(s, 8192, 5.0),
                                        None => rng.logits(8192, 5.0),
                                    };
                                    client.softmax(&logits)?;
                                }
                                "generate" => {
                                    // One streamed generation per
                                    // request: a single wire
                                    // round-trip, decoded server-side,
                                    // batched across workers.
                                    let sid = client.open_session()?;
                                    let start = (w * 31 + r) as i32 % 512;
                                    let frames =
                                        client.generate_all(sid, &[start], tokens, Some(5))?;
                                    client.close_session(sid)?;
                                    if frames.len() != tokens {
                                        return Err(anyhow!(
                                            "stream returned {} of {} tokens",
                                            frames.len(),
                                            tokens
                                        ));
                                    }
                                }
                                _ => {
                                    let hidden = match slot {
                                        Some(s) => slot_logits(s, 128, 1.0),
                                        None => rng.logits(128, 1.0),
                                    };
                                    client.decode(&hidden, Some(5))?;
                                }
                            }
                            Ok(())
                        })();
                        match res {
                            Ok(()) => tally[class].ok.push(t.elapsed()),
                            Err(e) => match wire::error_code(&e) {
                                Some(ErrorCode::Overloaded) => tally[class].overloaded += 1,
                                Some(ErrorCode::DeadlineExceeded) => tally[class].deadline += 1,
                                _ => tally[class].other += 1,
                            },
                        }
                    }
                    Ok(tally)
                })
            })
            .collect();
        let mut merged = [ClassTally::default(), ClassTally::default()];
        for h in handles {
            if let Ok([i, b]) = h.join().expect("loadgen worker") {
                merged[0].merge(i);
                merged[1].merge(b);
            }
        }
        merged
    });
    Ok((t0.elapsed(), tallies))
}

/// Print one topology's summary: throughput plus per-class outcome
/// counts and latency percentiles (the `--target both` comparison is
/// these blocks side by side, one per tier).
fn report_load(
    topology: &str,
    addr: &str,
    opts: &LoadOpts,
    wall: Duration,
    tallies: &[ClassTally; 2],
) {
    let attempts = tallies[0].attempts() + tallies[1].attempts();
    let ok_total = tallies[0].ok.len() + tallies[1].ok.len();
    println!(
        "loadgen[{topology} @ {addr}]: {} `{}` requests ({} ok), concurrency {}, \
         wall {:.2}s → {:.0} req/s",
        attempts,
        opts.op,
        ok_total,
        opts.concurrency,
        wall.as_secs_f64(),
        ok_total as f64 / wall.as_secs_f64()
    );
    for (name, tally) in ["interactive", "batch"].iter().zip(tallies.iter()) {
        if tally.attempts() == 0 {
            continue;
        }
        println!(
            "class {name}: ok={} overloaded={} deadline={} other={}",
            tally.ok.len(),
            tally.overloaded,
            tally.deadline,
            tally.other
        );
        if tally.ok.is_empty() {
            continue;
        }
        let mut sorted = tally.ok.clone();
        sorted.sort();
        let total = sorted.len();
        let pick = |q: f64| sorted[((q * (total - 1) as f64) as usize).min(total - 1)];
        println!(
            "  latency p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms  max {:.2}ms",
            pick(0.50).as_secs_f64() * 1e3,
            pick(0.95).as_secs_f64() * 1e3,
            pick(0.99).as_secs_f64() * 1e3,
            sorted[total - 1].as_secs_f64() * 1e3
        );
    }
}
