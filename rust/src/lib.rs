//! # onlinesoftmax — Online Normalizer Calculation for Softmax
//!
//! Production-grade reproduction of Milakov & Gimelshein, *"Online
//! normalizer calculation for softmax"* (NVIDIA, 2018): a single-pass
//! softmax normalizer, its parallel ⊕-merge form, fused Softmax+TopK,
//! and a vocabulary-softmax serving system built around them.
//!
//! ## Layers
//!
//! * **Core algorithms** ([`softmax`], [`topk`]) — Algorithms 1–4 of the
//!   paper in scalar, vectorized, multithreaded, and fused forms.
//! * **Shard layer** ([`shard`]) — the shard-reduction execution engine:
//!   vocabulary rows split into balanced shards, scanned in parallel on
//!   a persistent pool, and merged with the ⊕ tree reduction (the
//!   cross-shard Algorithm 4).  Whole batches tile as a batch×shard
//!   grid ([`shard::GridPlan`]) dispatched in one scheduling pass with
//!   concurrent per-row reductions.  The coordinator routes large-vocab
//!   requests here.
//! * **Runtime** ([`runtime`]) — loads AOT-compiled JAX/Pallas decode
//!   graphs (HLO text in `artifacts/`) into a PJRT CPU client; python is
//!   never on the request path.  (Offline builds link an API-compatible
//!   `xla` stub; artifact execution requires the real bindings.)
//! * **Coordinator** ([`coordinator`], [`server`]) — the typed v2
//!   serving surface (per-request options, structured errors), request
//!   routing, continuous dynamic batching (priority/deadline-aware),
//!   server-side streaming generation that batches across concurrent
//!   streams, beam-search decode scheduling, and vocabulary-sharded
//!   execution whose partial normalizers are merged with the paper's ⊕
//!   operator (§3.1) in rust.  Wire schema: `docs/PROTOCOL.md`.
//! * **Substrates** ([`exec`], [`json`], [`cli`], [`config`], [`rng`],
//!   [`prop`], [`benchkit`], [`metrics`], [`logging`]) — the offline
//!   crate registry ships only `xla` + `anyhow`, so the thread-pool
//!   runtime, JSON codec, CLI parser, PRNG, property-testing harness,
//!   benchmark harness, and metrics registry are first-class modules of
//!   this crate (see DESIGN.md §3).
//! * **Analytics** ([`analytic`]) — the paper's memory-access model and
//!   a device-bandwidth performance model that regenerates the shape of
//!   Figures 1–4 analytically.
//!
//! ## Quickstart
//!
//! ```no_run
//! use onlinesoftmax::softmax::{self, Algorithm};
//!
//! let x: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.37).sin()).collect();
//! let y = softmax::compute(&x, Algorithm::Online);
//! let (vals, idx) = onlinesoftmax::softmax::fused::online_topk(&x, 5);
//! assert_eq!(vals.len(), 5);
//! assert!((y.iter().sum::<f32>() - 1.0).abs() < 1e-5);
//! # let _ = idx;
//! ```

// Every `unsafe` block and impl must carry an immediately-preceding
// `// SAFETY:` comment (CI runs clippy with `-D warnings`, making this
// blocking; `xtask lint` enforces the same rule registry-offline).
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod analytic;
pub mod benches;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod json;
pub mod logging;
pub mod metrics;
pub mod prop;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod sample;
pub mod server;
pub mod shard;
pub mod softmax;
pub mod topk;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Semantic version of the library, kept in sync with `Cargo.toml`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
