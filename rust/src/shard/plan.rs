//! Shard planning: how a vocabulary-length row splits into per-worker
//! slices.
//!
//! A [`ShardPlan`] is pure arithmetic — balanced contiguous ranges with
//! the remainder spread over the leading shards — so the same plan can
//! be replayed deterministically by the engine, the tests, and the
//! benches.  Shard boundaries never affect results (the ⊕ merge is
//! associative); they only affect parallelism and cache behaviour.

/// One contiguous slice of the vocabulary axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRange {
    /// Shard index in `[0, plan.shards())`.
    pub index: usize,
    /// First element (inclusive).
    pub start: usize,
    /// One past the last element.
    pub end: usize,
}

impl ShardRange {
    /// Number of elements the range covers.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Whether the range covers zero elements (only the `v == 0` plan).
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// A balanced split of a length-`v` row into `shards` contiguous ranges.
///
/// Ranges partition `[0, v)` exactly, lengths differ by at most one,
/// and the split is pure arithmetic — replayable anywhere:
///
/// ```
/// use onlinesoftmax::shard::ShardPlan;
///
/// let plan = ShardPlan::with_shards(10, 3);
/// let lens: Vec<usize> = plan.ranges().map(|r| r.len()).collect();
/// assert_eq!(lens, [4, 3, 3]); // remainder spread over leading shards
/// assert_eq!(plan.range(1).start, 4);
/// assert_eq!(plan.range(2).end, 10);
/// assert!(plan.is_sharded());
/// assert!(!ShardPlan::single(10).is_sharded());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    v: usize,
    shards: usize,
}

impl ShardPlan {
    /// Default minimum elements per shard: below this, per-shard
    /// dispatch overhead exceeds the scan cost.
    pub const DEFAULT_MIN_SHARD: usize = 4096;

    /// Exactly `shards` ranges (clamped to `[1, max(v, 1)]` so no shard
    /// is ever empty unless `v == 0`).
    pub fn with_shards(v: usize, shards: usize) -> ShardPlan {
        ShardPlan { v, shards: shards.clamp(1, v.max(1)) }
    }

    /// The degenerate single-shard plan (the serial fallback).
    pub fn single(v: usize) -> ShardPlan {
        ShardPlan { v, shards: 1 }
    }

    /// Pick a shard count automatically: as many shards as `max_shards`
    /// allows while keeping every shard at least `min_shard` elements.
    pub fn auto(v: usize, max_shards: usize, min_shard: usize) -> ShardPlan {
        let by_size = if min_shard == 0 { v } else { v / min_shard };
        ShardPlan::with_shards(v, by_size.clamp(1, max_shards.max(1)))
    }

    /// Total row length covered by the plan.
    pub fn v(&self) -> usize {
        self.v
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Whether the plan actually fans out.
    pub fn is_sharded(&self) -> bool {
        self.shards > 1
    }

    /// The `i`-th range.  Balanced: `v = base·shards + rem`, and the
    /// first `rem` shards take one extra element.
    pub fn range(&self, i: usize) -> ShardRange {
        assert!(i < self.shards, "shard index {i} out of {}", self.shards);
        let base = self.v / self.shards;
        let rem = self.v % self.shards;
        let start = i * base + i.min(rem);
        let len = base + usize::from(i < rem);
        ShardRange { index: i, start, end: start + len }
    }

    /// All ranges in order.
    pub fn ranges(&self) -> impl Iterator<Item = ShardRange> + '_ {
        (0..self.shards).map(|i| self.range(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_partition(plan: &ShardPlan) {
        let mut next = 0;
        for (i, r) in plan.ranges().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.start, next, "ranges must be contiguous");
            assert!(r.end >= r.start);
            next = r.end;
        }
        assert_eq!(next, plan.v(), "ranges must cover the row exactly");
    }

    #[test]
    fn balanced_partition_all_shapes() {
        for v in [0usize, 1, 2, 7, 100, 101, 4096, 100_000] {
            for s in [1usize, 2, 3, 5, 8, 64] {
                let plan = ShardPlan::with_shards(v, s);
                assert_partition(&plan);
                // balanced: lengths differ by at most one
                let lens: Vec<usize> = plan.ranges().map(|r| r.len()).collect();
                let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(hi - lo <= 1, "v={v} s={s}: {lens:?}");
            }
        }
    }

    #[test]
    fn shard_count_clamps_to_row_length() {
        assert_eq!(ShardPlan::with_shards(3, 10).shards(), 3);
        assert_eq!(ShardPlan::with_shards(0, 10).shards(), 1);
        assert_eq!(ShardPlan::with_shards(10, 0).shards(), 1);
    }

    #[test]
    fn auto_respects_min_shard_and_cap() {
        // 100k / 4096 = 24 shards by size, capped at 8 workers.
        assert_eq!(ShardPlan::auto(100_000, 8, 4096).shards(), 8);
        // small rows stay single-shard
        assert_eq!(ShardPlan::auto(1000, 8, 4096).shards(), 1);
        assert_eq!(ShardPlan::auto(8192, 8, 4096).shards(), 2);
        // min_shard = 0 means "no size floor"
        assert_eq!(ShardPlan::auto(16, 4, 0).shards(), 4);
    }

    #[test]
    fn single_is_one_full_range() {
        let plan = ShardPlan::single(77);
        assert!(!plan.is_sharded());
        assert_eq!(plan.range(0), ShardRange { index: 0, start: 0, end: 77 });
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn range_index_bounds_checked() {
        ShardPlan::with_shards(10, 2).range(2);
    }
}
