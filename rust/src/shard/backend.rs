//! Pluggable per-tile scan backends for the shard engine — the layer
//! boundary that makes [`ShardEngine`](super::ShardEngine) execution
//! substrate-agnostic.
//!
//! The paper's Algorithm 4 works because the partial `(m, d, topk)`
//! state merges under an associative ⊕ regardless of *where* each
//! partial was computed.  This module promotes that fact to an
//! interface: a [`ShardBackend`] produces one [`ShardPartial`] per
//! (vocabulary-tile × request) and nothing else — planning, the ⊕ tree
//! reduction, scheduling, and finalization all stay in the engine, so a
//! backend author only writes the scan.
//!
//! Four implementations ship in-tree:
//!
//! * [`HostScalar`] — the engine's original fused single-sweep scan
//!   (cache-blocked normalizer + scalar candidate insertion,
//!   Algorithm 4).  **Total**: it accepts every tile geometry, which is
//!   what makes it the engine's per-tile fallback.
//! * [`HostVectorized`] — the §7 CPU adaptation: the lane-split
//!   streaming online normalizer
//!   ([`vectorized::online_normalizer_streaming`]) plus a separate
//!   candidate scan.  Declines tiles shorter than one
//!   [`LANES`](vectorized::LANES)-element stripe.
//! * [`HostTwoPass`] — the Dukhan & Ablavatski two-pass
//!   stored-partials scan ([`crate::softmax::twopass`]): per-stripe
//!   `(m, d)` partials with software-pipelined SIMD exp/accumulate in
//!   pass 1, an O(stripes) exact rescale in pass 2, and the top-k
//!   candidate scan fused into pass 1 while each stripe is L1-hot.
//!   Declines sub-[`LANES`](vectorized::LANES) tiles like the
//!   vectorized scan.
//! * [`ArtifactsStub`] — an adapter over the vendored `xla` stub that
//!   validates the tensor-interop contract shape a real PJRT shard
//!   executable would use, then reports [`Unsupported`] at runtime.  It
//!   exists so the engine's per-tile fallback path is exercised on
//!   every build, and so the future real-PJRT backend has a pinned
//!   slot-in point (see `docs/BACKENDS.md`).
//!
//! Selection is [`ShardBackendKind`]: config/CLI (`--shard-backend`),
//! the `OSMAX_SHARD_BACKEND` environment variable (CI's backend
//! matrix), with `auto` routing each tile by the measured geometry
//! bands from `bench --fig backend` (see [`AutoBackend::route`] and
//! the committed `BENCH_backend.json`): scalar below one lane stripe,
//! vectorized up to [`TWOPASS_CROSSOVER`], two-pass above it.
//!
//! The full backend-author contract — the ⊕ merge law a partial must
//! satisfy, per-backend bitwise-identity expectations, and the fallback
//! protocol — is documented in `docs/BACKENDS.md`.

use std::fmt;
use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::sample::{self, SampleSpec};
use crate::softmax::monoid::MD;
use crate::softmax::{twopass, vectorized};
use crate::topk::scan_topk;

use super::reduce::ShardPartial;

/// A backend declined a tile at runtime.
///
/// This is the **fallback protocol**'s signal, not a request failure:
/// on receiving it the engine reruns the same tile on [`HostScalar`]
/// (which is total) and increments the backend's
/// `shard.backend.<name>.fallbacks` counter.  Results are therefore
/// always produced; `Unsupported` only moves *where*.
#[derive(Debug, Clone)]
pub struct Unsupported {
    /// Name of the backend that declined the tile.
    pub backend: &'static str,
    /// Human-readable reason (logged/inspected, never parsed).
    pub reason: String,
}

impl Unsupported {
    /// Construct a decline signal for backend `backend`.
    pub fn new(backend: &'static str, reason: impl Into<String>) -> Unsupported {
        Unsupported { backend, reason: reason.into() }
    }
}

impl fmt::Display for Unsupported {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard backend `{}` declined the tile: {}", self.backend, self.reason)
    }
}

impl std::error::Error for Unsupported {}

/// One per-tile scan implementation behind the shard engine.
///
/// ## Contract (normative; see `docs/BACKENDS.md` for the full guide)
///
/// * `logits` holds exactly the tile's elements and `range` is the
///   *global* vocabulary interval they cover, so
///   `logits.len() == range.end - range.start` (asserted by the engine)
///   and the element at `logits[i]` has global index `range.start + i`.
///   Backends that materialize their own logits (sharded projection,
///   device memory) receive only the slice they are responsible for.
/// * The returned [`ShardPartial`] must satisfy the ⊕ merge law: the
///   normalizer pair obeys Algorithm 3's recurrence
///   `d_j = d_{j-1}·e^{m_{j-1}−m_j} + e^{x_j−m_j}` up to fp
///   reassociation (so `m` is exact and `d` is tolerance-equal under
///   any bracketing), and the top-k buffer carries **global** indices
///   with NaN candidates excluded and ties resolved to the earliest
///   global index.
/// * A backend may decline any tile with [`Unsupported`]; it must not
///   panic on geometry it dislikes.  Declining is cheap and safe — the
///   engine reruns the tile on the host scalar scan.
pub trait ShardBackend: Send + Sync {
    /// Stable identifier used in config values, metric names
    /// (`shard.backend.<name>.*`), bench labels, and logs.
    fn name(&self) -> &'static str;

    /// Capability hook: whether this backend expects to accept a tile
    /// of `tile_len` elements at top-`k` (`k == 0` asks about a
    /// normalizer-only scan).  Advisory — `auto` selection consults it
    /// up front, but the runtime truth is still the `Result` of the
    /// scan methods, so a backend may decline at scan time things it
    /// advertised here.
    fn supports(&self, tile_len: usize, k: usize) -> bool;

    /// Scan one tile in a single conceptual sweep: the fused
    /// online-normalizer + top-k partial of Algorithm 4 over
    /// `logits`, with candidate indices globalized by `range.start`.
    ///
    /// When `sample` is present the same sweep must additionally track
    /// the Gumbel-top-k candidate state ([`ShardPartial::sampled`]):
    /// each element's perturbed score is the pure function
    /// [`sample::perturb`] of `(seed, global index)`, so every backend
    /// — and every decomposition — produces bitwise-identical sampled
    /// selections for a fixed spec (pinned by the cross-backend
    /// property harness; see `docs/BACKENDS.md`).
    fn scan_tile(
        &self,
        logits: &[f32],
        range: Range<usize>,
        k: usize,
        sample: Option<SampleSpec>,
    ) -> std::result::Result<ShardPartial, Unsupported>;

    /// Normalizer-only scan of one tile (the first pass of a sharded
    /// softmax, where no candidates are needed).
    fn normalizer_tile(
        &self,
        logits: &[f32],
        range: Range<usize>,
    ) -> std::result::Result<MD, Unsupported>;

    /// Output pass: `out[i] = e^{logits[i] − m} · inv` over one tile.
    /// Always total — it is a pure store pass with no partial state, so
    /// the default host implementation serves every backend until a
    /// device-resident output path exists.
    fn scale_tile(&self, logits: &[f32], out: &mut [f32], m: f32, inv: f32) {
        vectorized::scale_pass(logits, out, m, inv);
    }
}

// ---------------------------------------------------------------------------
// Host scalar: the original fused scan, extracted
// ---------------------------------------------------------------------------

/// The engine's original per-tile scan, extracted behind the trait: the
/// fused cache-blocked sweep of [`ShardPartial::scan`] for fused
/// queries and the blocked [`vectorized::online_normalizer`] for
/// normalizer-only tiles.
///
/// **Total** (accepts every tile geometry) and **bitwise-identical** to
/// the pre-backend engine and to the single-thread kernels on unsharded
/// plans — this is the reference numerics every other backend is
/// compared against, and the target of the engine's per-tile fallback.
#[derive(Debug, Default, Clone, Copy)]
pub struct HostScalar;

impl ShardBackend for HostScalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn supports(&self, _tile_len: usize, _k: usize) -> bool {
        true
    }

    fn scan_tile(
        &self,
        logits: &[f32],
        range: Range<usize>,
        k: usize,
        sample: Option<SampleSpec>,
    ) -> std::result::Result<ShardPartial, Unsupported> {
        Ok(ShardPartial::scan_with(logits, k, range.start as i64, sample))
    }

    fn normalizer_tile(
        &self,
        logits: &[f32],
        _range: Range<usize>,
    ) -> std::result::Result<MD, Unsupported> {
        Ok(vectorized::online_normalizer(logits))
    }
}

// ---------------------------------------------------------------------------
// Host vectorized: the lane-split streaming scan
// ---------------------------------------------------------------------------

/// The §7 CPU adaptation as a backend: every SIMD lane keeps its own
/// `(m, d)` state through one streaming pass
/// ([`vectorized::online_normalizer_streaming`]) and the lanes ⊕-merge
/// once at the end; top-k candidates come from a separate
/// [`scan_topk`] sweep over the same tile.
///
/// Declines tiles shorter than one [`LANES`](vectorized::LANES)-element
/// stripe (`supports` is false and `scan_tile` returns
/// [`Unsupported`]), so sub-stripe tiles exercise the engine's host
/// fallback.  Selected indices are identical to [`HostScalar`]'s; `d`
/// differs within fp reassociation (lane bracketing vs block
/// bracketing) — see `docs/BACKENDS.md` for the per-backend identity
/// table.
#[derive(Debug, Default, Clone, Copy)]
pub struct HostVectorized;

impl ShardBackend for HostVectorized {
    fn name(&self) -> &'static str {
        "vectorized"
    }

    fn supports(&self, tile_len: usize, _k: usize) -> bool {
        tile_len >= vectorized::LANES
    }

    fn scan_tile(
        &self,
        logits: &[f32],
        range: Range<usize>,
        k: usize,
        sample: Option<SampleSpec>,
    ) -> std::result::Result<ShardPartial, Unsupported> {
        if !self.supports(logits.len(), k) {
            return Err(Unsupported::new(
                self.name(),
                format!(
                    "tile of {} elements is below one {}-lane stripe",
                    logits.len(),
                    vectorized::LANES
                ),
            ));
        }
        let base = range.start as i64;
        Ok(ShardPartial {
            md: vectorized::online_normalizer_streaming(logits),
            topk: scan_topk(logits, k, base),
            sampled: sample.map(|spec| sample::scan_sampled(logits, k, base, spec)),
        })
    }

    fn normalizer_tile(
        &self,
        logits: &[f32],
        _range: Range<usize>,
    ) -> std::result::Result<MD, Unsupported> {
        if !self.supports(logits.len(), 0) {
            return Err(Unsupported::new(
                self.name(),
                format!(
                    "tile of {} elements is below one {}-lane stripe",
                    logits.len(),
                    vectorized::LANES
                ),
            ));
        }
        Ok(vectorized::online_normalizer_streaming(logits))
    }
}

// ---------------------------------------------------------------------------
// Host two-pass: stored-partials scan (Dukhan & Ablavatski)
// ---------------------------------------------------------------------------

/// The two-pass stored-partials scan as a backend
/// ([`crate::softmax::twopass`], after Dukhan & Ablavatski
/// arXiv 2001.04438): pass 1 sweeps the tile once in
/// [`STRIPE`](twopass::STRIPE)-element stripes, each producing an
/// independent `(m_s, d_s)` partial with two-bank software-pipelined
/// SIMD max/exp loops — no serial ⊕ chain between stripes — while the
/// top-k candidate scan runs over the same L1-hot stripe; pass 2
/// rescales the stored partials (`d = Σ d_s·e^{m_s − m}`, exact `exp`,
/// O(stripes)).  DRAM sees each element exactly once; there is no
/// third sweep and no full-softmax rematerialization.
///
/// Declines tiles shorter than one [`LANES`](vectorized::LANES)-element
/// stripe, like [`HostVectorized`], so sub-stripe tiles exercise the
/// engine's host fallback.  Selected indices are identical to
/// [`HostScalar`]'s; `m` is bitwise-equal and `d` ULP-bounded (stripe
/// bracketing vs block bracketing) — see `docs/BACKENDS.md`.
#[derive(Debug, Default, Clone, Copy)]
pub struct HostTwoPass;

impl HostTwoPass {
    fn decline(&self, tile_len: usize) -> Unsupported {
        Unsupported::new(
            self.name(),
            format!(
                "tile of {} elements is below one {}-lane stripe",
                tile_len,
                vectorized::LANES
            ),
        )
    }
}

impl ShardBackend for HostTwoPass {
    fn name(&self) -> &'static str {
        "twopass"
    }

    fn supports(&self, tile_len: usize, _k: usize) -> bool {
        tile_len >= vectorized::LANES
    }

    fn scan_tile(
        &self,
        logits: &[f32],
        range: Range<usize>,
        k: usize,
        sample: Option<SampleSpec>,
    ) -> std::result::Result<ShardPartial, Unsupported> {
        if !self.supports(logits.len(), k) {
            return Err(self.decline(logits.len()));
        }
        let base = range.start as i64;
        let (md, topk) = twopass::fused_partial(logits, k, base);
        let sampled = sample.map(|spec| sample::scan_sampled(logits, k, base, spec));
        Ok(ShardPartial { md, topk, sampled })
    }

    fn normalizer_tile(
        &self,
        logits: &[f32],
        _range: Range<usize>,
    ) -> std::result::Result<MD, Unsupported> {
        if !self.supports(logits.len(), 0) {
            return Err(self.decline(logits.len()));
        }
        Ok(twopass::normalizer(logits))
    }
}

// ---------------------------------------------------------------------------
// Artifacts stub: the pinned slot-in point for the real PJRT path
// ---------------------------------------------------------------------------

/// Adapter over the vendored `xla` stub: performs the host-side tensor
/// interop a real PJRT shard executable would need (literal
/// construction + reshape to the `(1, tile_len)` input shape the AOT
/// partial executables take), then attempts to reach a PJRT client and
/// reports [`Unsupported`] when — as in every offline build — none is
/// available.
///
/// Its purpose is twofold: the contract *shape* for the future
/// real-PJRT backend is validated on every build (the interop code
/// path is real even though execution is not), and the engine's
/// per-tile fallback-to-host protocol is exercised end-to-end rather
/// than only in unit tests.  Swapping in the real bindings turns the
/// client probe into a live engine; the partial-executable wiring then
/// lands behind this same `name()` without touching the engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct ArtifactsStub;

impl ArtifactsStub {
    /// Shared decline path for both scan flavours: validate the
    /// host-side tensor shape, then probe for a PJRT client.
    fn decline(&self, logits: &[f32]) -> Unsupported {
        // The interop a real backend performs before dispatch: a dense
        // rank-1 literal reshaped to the (1, tile_len) batch-of-one the
        // AOT partial executables accept.  Fully functional on the
        // stub, so shape bugs surface here rather than on first contact
        // with real bindings.
        let lit = xla::Literal::vec1(logits);
        if let Err(e) = lit.reshape(&[1, logits.len() as i64]) {
            return Unsupported::new(self.name(), format!("literal interop failed: {e}"));
        }
        match xla::PjRtClient::cpu() {
            // Real bindings linked but the shard executables are not
            // wired yet — still a decline, with a reason that names the
            // remaining work.
            Ok(_client) => Unsupported::new(
                self.name(),
                "PJRT client available but shard partial executables are not wired",
            ),
            Err(e) => Unsupported::new(self.name(), e.to_string()),
        }
    }
}

impl ShardBackend for ArtifactsStub {
    fn name(&self) -> &'static str {
        "artifacts-stub"
    }

    /// Claims support so selection never filters it out — the decline
    /// happens at scan time, which is exactly what drives the engine's
    /// runtime fallback path.
    fn supports(&self, _tile_len: usize, _k: usize) -> bool {
        true
    }

    fn scan_tile(
        &self,
        logits: &[f32],
        _range: Range<usize>,
        _k: usize,
        _sample: Option<SampleSpec>,
    ) -> std::result::Result<ShardPartial, Unsupported> {
        Err(self.decline(logits))
    }

    fn normalizer_tile(
        &self,
        logits: &[f32],
        _range: Range<usize>,
    ) -> std::result::Result<MD, Unsupported> {
        Err(self.decline(logits))
    }
}

// ---------------------------------------------------------------------------
// Auto: geometry-driven composite
// ---------------------------------------------------------------------------

/// Tile length (elements) at and above which [`AutoBackend`] routes to
/// [`HostTwoPass`] instead of [`HostVectorized`].
///
/// Measured, not guessed: `bench --fig backend` sweeps vocab sizes over
/// all three host backends and the committed `BENCH_backend.json`
/// records the run this constant was read from (see its `crossover`
/// note and docs/BACKENDS.md §Crossover).  On the reference testbed the
/// two-pass stored-partials scan pulls ahead of the streaming scan once
/// a tile covers a few full [`STRIPE`](twopass::STRIPE)s — below that
/// the stored-partials bookkeeping (partial vector allocation + rescale
/// pass) costs more than the shorter fp dependency chains win back.
/// Re-run the bench and update this constant together with
/// `BENCH_backend.json`; the decision-table test pins the bands.
pub const TWOPASS_CROSSOVER: usize = 2 * twopass::STRIPE;

/// Geometry-driven composite backend: routes each tile by the measured
/// (tile_len, k) bands of [`AutoBackend::route`] — [`HostScalar`] below
/// one lane stripe, [`HostVectorized`] in the middle band, and
/// [`HostTwoPass`] at and above [`TWOPASS_CROSSOVER`].  Total by
/// construction, so it never triggers the engine-level fallback.
#[derive(Debug, Default, Clone, Copy)]
pub struct AutoBackend {
    twopass: HostTwoPass,
    vectorized: HostVectorized,
    scalar: HostScalar,
}

impl AutoBackend {
    /// The routing decision table: which backend kind serves a tile of
    /// `tile_len` elements at top-`k` (`k == 0` = normalizer-only).
    ///
    /// Pure function of the geometry so the serving default is unit-
    /// testable: the decision-table test enumerates the bands and any
    /// routing edit must update it in the same change.  `k` does not
    /// currently shift a band — every host backend fuses or separates
    /// its candidate scan at identical per-element cost — but it is part
    /// of the signature so a future k-sensitive backend (e.g. heap-based
    /// selection for large k) can claim a band without an API break.
    pub fn route(tile_len: usize, _k: usize) -> ShardBackendKind {
        if tile_len < vectorized::LANES {
            ShardBackendKind::Scalar
        } else if tile_len < TWOPASS_CROSSOVER {
            ShardBackendKind::Vectorized
        } else {
            ShardBackendKind::TwoPass
        }
    }
}

impl ShardBackend for AutoBackend {
    fn name(&self) -> &'static str {
        "auto"
    }

    fn supports(&self, _tile_len: usize, _k: usize) -> bool {
        true
    }

    fn scan_tile(
        &self,
        logits: &[f32],
        range: Range<usize>,
        k: usize,
        sample: Option<SampleSpec>,
    ) -> std::result::Result<ShardPartial, Unsupported> {
        match Self::route(logits.len(), k) {
            ShardBackendKind::TwoPass => self.twopass.scan_tile(logits, range, k, sample),
            ShardBackendKind::Vectorized => {
                self.vectorized.scan_tile(logits, range, k, sample)
            }
            _ => self.scalar.scan_tile(logits, range, k, sample),
        }
    }

    fn normalizer_tile(
        &self,
        logits: &[f32],
        range: Range<usize>,
    ) -> std::result::Result<MD, Unsupported> {
        match Self::route(logits.len(), 0) {
            ShardBackendKind::TwoPass => self.twopass.normalizer_tile(logits, range),
            ShardBackendKind::Vectorized => self.vectorized.normalizer_tile(logits, range),
            _ => self.scalar.normalizer_tile(logits, range),
        }
    }
}

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

/// Which [`ShardBackend`] an engine instantiates — the value behind
/// `shard_backend` in the config file, `--shard-backend` on the CLI,
/// and `OSMAX_SHARD_BACKEND` in the environment (CI's backend matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardBackendKind {
    /// Per-tile geometry-driven choice between the vectorized and
    /// scalar host scans ([`AutoBackend`]).
    Auto,
    /// The fused cache-blocked host scan ([`HostScalar`]) — reference
    /// numerics, total, and the fallback target.
    Scalar,
    /// The lane-split streaming host scan ([`HostVectorized`]).
    Vectorized,
    /// The two-pass stored-partials host scan ([`HostTwoPass`]).
    TwoPass,
    /// The PJRT contract-shape stub ([`ArtifactsStub`]) — always falls
    /// back to host at runtime.
    ArtifactsStub,
}

impl ShardBackendKind {
    /// Every selectable kind, in documentation order.  The
    /// backend-iteration test harness runs the shard-layer edge-case
    /// suite over exactly this list, so a newly registered backend is
    /// covered the moment it is added here.
    pub fn all() -> [ShardBackendKind; 5] {
        [
            ShardBackendKind::Scalar,
            ShardBackendKind::Vectorized,
            ShardBackendKind::TwoPass,
            ShardBackendKind::ArtifactsStub,
            ShardBackendKind::Auto,
        ]
    }

    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(ShardBackendKind::Auto),
            "scalar" => Ok(ShardBackendKind::Scalar),
            "vectorized" => Ok(ShardBackendKind::Vectorized),
            "twopass" => Ok(ShardBackendKind::TwoPass),
            "artifacts-stub" => Ok(ShardBackendKind::ArtifactsStub),
            _ => bail!(
                "invalid shard backend `{s}` (expected `auto`, `scalar`, \
                 `vectorized`, `twopass`, or `artifacts-stub`)"
            ),
        }
    }

    /// The canonical config/CLI/metric spelling of this kind.
    pub fn as_str(self) -> &'static str {
        match self {
            ShardBackendKind::Auto => "auto",
            ShardBackendKind::Scalar => "scalar",
            ShardBackendKind::Vectorized => "vectorized",
            ShardBackendKind::TwoPass => "twopass",
            ShardBackendKind::ArtifactsStub => "artifacts-stub",
        }
    }

    /// The kind named by the `OSMAX_SHARD_BACKEND` environment variable
    /// (how CI's backend matrix threads a backend through the e2e
    /// suites), or `default` when unset.  An unparsable value panics —
    /// a matrix job silently testing the wrong backend is worse than a
    /// loud failure (same convention as `OSMAX_POOL_SCHED`).
    pub fn from_env_or(default: ShardBackendKind) -> ShardBackendKind {
        Self::resolve(std::env::var("OSMAX_SHARD_BACKEND").ok().as_deref(), default)
    }

    /// Testable core of [`Self::from_env_or`] — kept free of
    /// environment reads so tests never mutate process-global env vars.
    fn resolve(value: Option<&str>, default: ShardBackendKind) -> ShardBackendKind {
        match value {
            Some(s) => ShardBackendKind::parse(s).expect("OSMAX_SHARD_BACKEND"),
            None => default,
        }
    }

    /// Build the backend object this kind names.
    pub fn instantiate(self) -> Arc<dyn ShardBackend> {
        match self {
            ShardBackendKind::Auto => Arc::new(AutoBackend::default()),
            ShardBackendKind::Scalar => Arc::new(HostScalar),
            ShardBackendKind::Vectorized => Arc::new(HostVectorized),
            ShardBackendKind::TwoPass => Arc::new(HostTwoPass),
            ShardBackendKind::ArtifactsStub => Arc::new(ArtifactsStub),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::softmax::fused;

    fn logits(n: usize, seed: u64) -> Vec<f32> {
        Xoshiro256pp::seed_from_u64(seed).logits(n, 7.0)
    }

    #[test]
    fn kind_parse_and_as_str_roundtrip() {
        for kind in ShardBackendKind::all() {
            assert_eq!(ShardBackendKind::parse(kind.as_str()).unwrap(), kind);
            assert_eq!(kind.instantiate().name(), kind.as_str());
        }
        assert_eq!(ShardBackendKind::parse("auto").unwrap(), ShardBackendKind::Auto);
        assert!(ShardBackendKind::parse("gpu").is_err());
        assert!(ShardBackendKind::parse("").is_err());
    }

    #[test]
    fn env_resolution_mirrors_pool_sched() {
        assert_eq!(
            ShardBackendKind::resolve(None, ShardBackendKind::Auto),
            ShardBackendKind::Auto
        );
        assert_eq!(
            ShardBackendKind::resolve(Some("scalar"), ShardBackendKind::Auto),
            ShardBackendKind::Scalar
        );
        assert_eq!(
            ShardBackendKind::resolve(Some("vectorized"), ShardBackendKind::Scalar),
            ShardBackendKind::Vectorized
        );
    }

    #[test]
    #[should_panic(expected = "OSMAX_SHARD_BACKEND")]
    fn env_resolution_rejects_garbage_loudly() {
        ShardBackendKind::resolve(Some("cuda"), ShardBackendKind::Auto);
    }

    #[test]
    fn scalar_backend_is_the_reference_scan() {
        let x = logits(3000, 1);
        let part = HostScalar.scan_tile(&x, 0..x.len(), 5, None).unwrap();
        let (md, buf) = fused::fused_partial(&x, 5, 0);
        assert_eq!(part.md, md);
        assert_eq!(part.topk.indices(), buf.indices());
        let md2 = HostScalar.normalizer_tile(&x, 0..x.len()).unwrap();
        assert_eq!(md2, vectorized::online_normalizer(&x));
    }

    #[test]
    fn vectorized_backend_selects_identical_indices() {
        for n in [16usize, 100, 513, 4097] {
            let x = logits(n, n as u64);
            let part = HostVectorized.scan_tile(&x, 0..n, 6, None).unwrap();
            let reference = HostScalar.scan_tile(&x, 0..n, 6, None).unwrap();
            assert_eq!(part.topk.indices(), reference.topk.indices(), "n={n}");
            assert_eq!(part.md.m, reference.md.m, "n={n}");
            let (a, b) = (part.md.d, reference.md.d);
            assert!((a - b).abs() <= 1e-4 * b.max(1.0), "n={n}: {a} vs {b}");
        }
    }

    #[test]
    fn vectorized_backend_declines_sub_stripe_tiles() {
        let x = logits(vectorized::LANES - 1, 9);
        assert!(!HostVectorized.supports(x.len(), 3));
        let err = HostVectorized.scan_tile(&x, 0..x.len(), 3, None).unwrap_err();
        assert_eq!(err.backend, "vectorized");
        assert!(HostVectorized.normalizer_tile(&x, 0..x.len()).is_err());
        assert!(HostVectorized.supports(vectorized::LANES, 3));
    }

    #[test]
    fn vectorized_backend_globalizes_indices() {
        let x = logits(64, 4);
        let part = HostVectorized.scan_tile(&x, 1000..1064, 3, None).unwrap();
        assert!(part.topk.indices().iter().all(|&i| (1000..1064).contains(&(i as usize))));
    }

    #[test]
    fn twopass_backend_selects_identical_indices() {
        // Lengths straddle the stripe/pipeline boundaries: one lane
        // stripe, sub-STRIPE, exact STRIPE multiples, and ragged tails.
        for n in [16usize, 100, 513, 1024, 4097] {
            let x = logits(n, n as u64);
            let part = HostTwoPass.scan_tile(&x, 0..n, 6, None).unwrap();
            let reference = HostScalar.scan_tile(&x, 0..n, 6, None).unwrap();
            assert_eq!(part.topk.indices(), reference.topk.indices(), "n={n}");
            assert_eq!(part.md.m, reference.md.m, "n={n}");
            let (a, b) = (part.md.d, reference.md.d);
            assert!((a - b).abs() <= 1e-4 * b.max(1.0), "n={n}: {a} vs {b}");
        }
    }

    #[test]
    fn twopass_backend_declines_sub_stripe_tiles() {
        let x = logits(vectorized::LANES - 1, 9);
        assert!(!HostTwoPass.supports(x.len(), 3));
        let err = HostTwoPass.scan_tile(&x, 0..x.len(), 3, None).unwrap_err();
        assert_eq!(err.backend, "twopass");
        assert!(HostTwoPass.normalizer_tile(&x, 0..x.len()).is_err());
        assert!(HostTwoPass.supports(vectorized::LANES, 3));
    }

    #[test]
    fn twopass_backend_globalizes_indices() {
        // Range start far from zero AND a tile spanning multiple
        // stripes, so per-stripe bases compose with the global offset.
        let n = 2 * twopass::STRIPE + 64;
        let x = logits(n, 4);
        let part = HostTwoPass.scan_tile(&x, 50_000..50_000 + n, 3, None).unwrap();
        let reference = HostScalar.scan_tile(&x, 50_000..50_000 + n, 3, None).unwrap();
        assert_eq!(part.topk.indices(), reference.topk.indices());
        assert!(part
            .topk
            .indices()
            .iter()
            .all(|&i| (50_000..50_000 + n).contains(&(i as usize))));
    }

    #[test]
    fn twopass_backend_normalizer_matches_reference() {
        let x = logits(3 * twopass::STRIPE + 11, 13);
        let got = HostTwoPass.normalizer_tile(&x, 0..x.len()).unwrap();
        let reference = HostScalar.normalizer_tile(&x, 0..x.len()).unwrap();
        assert_eq!(got.m, reference.m);
        assert!((got.d - reference.d).abs() <= 1e-4 * reference.d.max(1.0));
    }

    #[test]
    fn artifacts_stub_always_declines_at_runtime() {
        let x = logits(512, 2);
        assert!(ArtifactsStub.supports(x.len(), 5), "claims support up front");
        let err = ArtifactsStub.scan_tile(&x, 0..512, 5, None).unwrap_err();
        assert_eq!(err.backend, "artifacts-stub");
        assert!(ArtifactsStub.normalizer_tile(&x, 0..512).is_err());
        // Empty tiles exercise the interop path too, without panicking.
        assert!(ArtifactsStub.scan_tile(&[], 0..0, 1, None).is_err());
    }

    #[test]
    fn auto_backend_routes_by_geometry_and_is_total() {
        let auto = AutoBackend::default();
        // Middle-band tile → vectorized numerics (streaming d).
        let x = logits(512, 3);
        let got = auto.scan_tile(&x, 0..512, 4, None).unwrap();
        let vec = HostVectorized.scan_tile(&x, 0..512, 4, None).unwrap();
        assert_eq!(got.md, vec.md);
        assert_eq!(got.topk.indices(), vec.topk.indices());
        // At/above the crossover → two-pass numerics (stripe d).
        let n = TWOPASS_CROSSOVER;
        let big = logits(n, 11);
        let got = auto.scan_tile(&big, 0..n, 4, None).unwrap();
        let tp = HostTwoPass.scan_tile(&big, 0..n, 4, None).unwrap();
        assert_eq!(got.md, tp.md);
        assert_eq!(got.topk.indices(), tp.topk.indices());
        // Sub-stripe tile → scalar numerics, not an error.
        let tiny = logits(5, 6);
        let got = auto.scan_tile(&tiny, 0..5, 2, None).unwrap();
        let scalar = HostScalar.scan_tile(&tiny, 0..5, 2, None).unwrap();
        assert_eq!(got.md, scalar.md);
        assert_eq!(got.topk.indices(), scalar.topk.indices());
        // Normalizer-only path routes through the same bands.
        let got = auto.normalizer_tile(&big, 0..n).unwrap();
        assert_eq!(got, HostTwoPass.normalizer_tile(&big, 0..n).unwrap());
    }

    /// The `auto` decision table, pinned band by band: any routing edit
    /// (including moving [`TWOPASS_CROSSOVER`] after a new bench run)
    /// must update this table in the same change, so the serving
    /// default can't drift silently.
    #[test]
    fn auto_backend_decision_table() {
        use ShardBackendKind::{Scalar, TwoPass, Vectorized};
        let lanes = vectorized::LANES;
        let table = [
            // (tile_len, k) → expected backend
            (0, 0, Scalar),
            (1, 1, Scalar),
            (lanes - 1, 5, Scalar),                // below one lane stripe
            (lanes, 0, Vectorized),                // first vectorizable length
            (lanes, 5, Vectorized),
            (512, 4, Vectorized),                  // one STRIPE, still streaming
            (TWOPASS_CROSSOVER - 1, 5, Vectorized),
            (TWOPASS_CROSSOVER, 0, TwoPass),       // measured crossover
            (TWOPASS_CROSSOVER, 5, TwoPass),
            (25_000, 5, TwoPass),
            (400_000, 64, TwoPass),
        ];
        for (tile_len, k, expected) in table {
            assert_eq!(
                AutoBackend::route(tile_len, k),
                expected,
                "route({tile_len}, {k})"
            );
        }
        // k alone never shifts a band today (documented on `route`).
        for k in [0usize, 1, 7, 1000] {
            assert_eq!(AutoBackend::route(100, k), Vectorized);
            assert_eq!(AutoBackend::route(TWOPASS_CROSSOVER, k), TwoPass);
        }
    }

    #[test]
    fn scale_tile_default_matches_scale_pass() {
        let x = logits(100, 8);
        let md = vectorized::online_normalizer(&x);
        let mut a = vec![0.0f32; 100];
        let mut b = vec![0.0f32; 100];
        HostScalar.scale_tile(&x, &mut a, md.m, 1.0 / md.d);
        vectorized::scale_pass(&x, &mut b, md.m, 1.0 / md.d);
        assert_eq!(a, b);
    }
}
