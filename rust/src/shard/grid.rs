//! Batch×shard grid planning: tiling a whole coordinator batch over the
//! worker pool in one scheduling pass.
//!
//! A [`GridPlan`] is the 2-D extension of [`ShardPlan`]: `rows` batch
//! rows × one shared per-row shard split.  Each cell is a [`GridTile`]
//! — (batch row, vocabulary slice) — and the engine dispatches *all*
//! `rows × shards` tiles in a single scoped fan-out
//! ([`ShardEngine::grid_map`](super::ShardEngine::grid_map)), instead of
//! one fan-out/join per row.  With R rows in flight the pool never
//! drains between rows, which is exactly the occupancy the paper buys
//! by making the softmax state mergeable in any partition order.
//!
//! Two properties are deliberate:
//!
//! * **The per-row shard shape is independent of the row count.**  A
//!   batch dispatched as one R×S grid is therefore bitwise-identical to
//!   R independent 1×S dispatches (same tile boundaries → same scans →
//!   same ⊕ bracketing).  The rows dimension only multiplies the number
//!   of available tiles.
//! * **Tiles enumerate row-major** ([`GridPlan::tiles`]): the earliest
//!   row's tiles dequeue first from the pool's FIFO, so its ⊕ tree
//!   reduction runs while later rows are still scanning — completions
//!   pipeline instead of arriving in one burst, and the R×S
//!   oversubscription lets idle workers backfill from later rows the
//!   way a work-stealing deque would.

use super::plan::{ShardPlan, ShardRange};

/// One cell of a [`GridPlan`]: batch row `row` × vocabulary slice
/// `range`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridTile {
    /// Batch-row index in `[0, grid.rows())`.
    pub row: usize,
    /// The vocabulary slice this tile scans (`range.index` is the shard
    /// index within the row).
    pub range: ShardRange,
}

/// A 2-D execution grid: `rows` batch rows, each split by the same
/// [`ShardPlan`].
///
/// `rows == 1` is the degenerate single-row grid (the pre-grid serving
/// path); `shards == 1` degenerates to plain row-level batching.  Both
/// degenerate forms execute the identical kernels, so results never
/// depend on which shape the scheduler picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridPlan {
    rows: usize,
    row_plan: ShardPlan,
}

impl GridPlan {
    /// A grid of `rows` rows, each split by `row_plan`.
    pub fn new(rows: usize, row_plan: ShardPlan) -> GridPlan {
        GridPlan { rows, row_plan }
    }

    /// The degenerate 1×S grid over one row.
    pub fn single_row(row_plan: ShardPlan) -> GridPlan {
        GridPlan::new(1, row_plan)
    }

    /// Number of batch rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// The shared per-row shard split.
    pub fn row_plan(&self) -> ShardPlan {
        self.row_plan
    }

    /// Row length covered by every row of the grid.
    pub fn v(&self) -> usize {
        self.row_plan.v()
    }

    /// Shards per row (the S in R×S).
    pub fn shards_per_row(&self) -> usize {
        self.row_plan.shards()
    }

    /// Total tile count, `rows × shards_per_row`.
    pub fn tile_count(&self) -> usize {
        self.rows * self.row_plan.shards()
    }

    /// Whether executing this grid fans out at all (more than one tile).
    pub fn is_parallel(&self) -> bool {
        self.tile_count() > 1
    }

    /// The tile at (`row`, `shard`).
    pub fn tile(&self, row: usize, shard: usize) -> GridTile {
        assert!(row < self.rows, "row index {row} out of {}", self.rows);
        GridTile { row, range: self.row_plan.range(shard) }
    }

    /// All tiles in row-major order (row 0's shards first).  See the
    /// module docs for why this ordering is the scheduling policy.
    pub fn tiles(&self) -> impl Iterator<Item = GridTile> + '_ {
        (0..self.rows).flat_map(move |row| {
            self.row_plan.ranges().map(move |range| GridTile { row, range })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiles_cover_every_row_exactly() {
        for rows in [1usize, 2, 3, 7] {
            for shards in [1usize, 2, 5] {
                let grid = GridPlan::new(rows, ShardPlan::with_shards(1003, shards));
                assert_eq!(grid.tile_count(), rows * grid.shards_per_row());
                let mut per_row_next = vec![0usize; rows];
                let mut seen = 0usize;
                for t in grid.tiles() {
                    assert_eq!(
                        t.range.start, per_row_next[t.row],
                        "row {} tiles must be contiguous",
                        t.row
                    );
                    per_row_next[t.row] = t.range.end;
                    seen += 1;
                }
                assert_eq!(seen, grid.tile_count());
                assert!(per_row_next.iter().all(|&end| end == 1003), "{per_row_next:?}");
            }
        }
    }

    #[test]
    fn tiles_enumerate_row_major() {
        let grid = GridPlan::new(3, ShardPlan::with_shards(100, 4));
        let order: Vec<(usize, usize)> =
            grid.tiles().map(|t| (t.row, t.range.index)).collect();
        let want: Vec<(usize, usize)> =
            (0..3).flat_map(|r| (0..4).map(move |s| (r, s))).collect();
        assert_eq!(order, want);
    }

    #[test]
    fn tile_accessor_matches_iterator() {
        let grid = GridPlan::new(2, ShardPlan::with_shards(77, 3));
        let all: Vec<GridTile> = grid.tiles().collect();
        for (i, t) in all.iter().enumerate() {
            assert_eq!(*t, grid.tile(i / 3, i % 3));
        }
    }

    #[test]
    fn degenerate_shapes() {
        let single = GridPlan::single_row(ShardPlan::with_shards(512, 4));
        assert_eq!(single.rows(), 1);
        assert!(single.is_parallel());
        let serial = GridPlan::new(1, ShardPlan::single(512));
        assert!(!serial.is_parallel());
        let empty = GridPlan::new(0, ShardPlan::single(512));
        assert_eq!(empty.tile_count(), 0);
        assert_eq!(empty.tiles().count(), 0);
    }

    #[test]
    #[should_panic(expected = "row index")]
    fn tile_row_bounds_checked() {
        GridPlan::new(2, ShardPlan::single(10)).tile(2, 0);
    }
}
