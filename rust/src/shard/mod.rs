//! Shard-reduction execution layer — §3.1/§4 of the paper promoted to a
//! system boundary.
//!
//! The paper proves the online normalizer `(m, d)` forms an associative,
//! commutative monoid under ⊕ (eq. 4), and that the fused softmax+top-k
//! state (Algorithm 4) merges the same way.  That licenses evaluating a
//! vocabulary row in *any* partition order: SIMD lanes
//! ([`crate::softmax::vectorized`]), worker threads within one vector
//! ([`crate::softmax::parallel`]), and — this module — **vocabulary
//! shards** distributed across a persistent worker pool:
//!
//! ```text
//!   row x[0..V] ── ShardPlan ──► shard 0 ─ scan ─► (m₀, d₀, topk₀) ┐
//!                               shard 1 ─ scan ─► (m₁, d₁, topk₁) ├─ ⊕ tree ─► finalize
//!                               ...                               │   (reduce)
//!                               shard S ─ scan ─► (m_S, d_S, topk_S) ┘
//! ```
//!
//! Whole batches tile as a 2-D **batch×shard grid** ([`grid`]): R rows
//! × S vocabulary shards dispatched to the pool in one scheduling pass,
//! per-row ⊕ reductions running concurrently, one scoped join:
//!
//! ```text
//!   batch of R rows ── GridPlan ──► tile(0,0) … tile(0,S) ─ ⊕ ─► row 0
//!                                   tile(1,0) … tile(1,S) ─ ⊕ ─► row 1
//!                                   ...        (one run_scoped join)
//! ```
//!
//! * [`plan`] — balanced shard arithmetic ([`ShardPlan`]).
//! * [`grid`] — the batch×shard tiling ([`GridPlan`]/[`GridTile`]):
//!   per-row shard shape independent of the row count, so grid results
//!   are bitwise-identical to per-row dispatch.
//! * [`reduce`] — [`ShardPartial`] and the ⊕/buffer tree reduction,
//!   the cross-shard analogue of the paper's Algorithm 4.
//! * [`engine`] — [`ShardEngine`]: executes plans and grids on an
//!   [`exec::ThreadPool`](crate::exec::ThreadPool), with a
//!   threshold-gated single-thread fallback that is bitwise-identical
//!   to the unsharded kernels.
//!
//! The coordinator routes large-vocabulary requests here (see
//! [`crate::coordinator::executor`]); the same partials arrive from
//! PJRT engines when AOT artifacts are served, so the reduction code is
//! shared between the host and accelerator backends.
//!
//! ## ⊕ merge invariants
//!
//! The property tests (`rust/tests/prop_invariants.rs`) and the grid's
//! bitwise-identity contract rest on these guarantees, stated once here
//! and relied on everywhere:
//!
//! * **Associativity / commutativity** — `(m, d)` merges with ⊕
//!   (eq. 4), associative and commutative with identity `(−∞, 0)`;
//!   `m` is *exact* under any bracketing, `d` reassociates within fp
//!   rounding.  Top-k buffer merge is associative in the selected
//!   *indices* for any bracketing that preserves relative index order.
//! * **−∞ handling** — `e^{−∞ − −∞}` is defined as 0 (identity merge,
//!   not IEEE NaN), so all-(−∞) shards act as "no contribution".
//! * **NaN handling** — NaN logits fail every `>` comparison: they
//!   never become a shard's running max nor enter a top-k buffer, so
//!   merged results are NaN-free wherever the serial kernels are.
//! * **Tie-breaking** — equal logit values resolve to the *earliest
//!   global index*; buffer merges keep the incumbent (left) side, so
//!   shard-ordered reductions reproduce the whole-row scan exactly.
//!
//! ## Pluggable scan backends
//!
//! *Where* each per-tile partial is computed is a pluggable layer
//! ([`backend`]): the engine dispatches every tile to a
//! [`ShardBackend`] object (`scalar` fused scan, `vectorized`
//! lane-split scan, the `artifacts-stub` PJRT contract adapter, or
//! `auto`), and a tile the backend declines is rerun on the total host
//! scalar scan — the per-tile fallback protocol.  The backend-author
//! contract lives in `docs/BACKENDS.md`.

#![warn(missing_docs)]

pub mod backend;
pub mod engine;
pub mod grid;
pub mod plan;
pub mod reduce;

pub use backend::{AutoBackend, ShardBackend, ShardBackendKind, Unsupported, TWOPASS_CROSSOVER};
pub use engine::{ShardEngine, ShardEngineConfig};
pub use grid::{GridPlan, GridTile};
pub use plan::{ShardPlan, ShardRange};
pub use reduce::{tree_reduce, ShardPartial};
