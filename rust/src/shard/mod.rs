//! Shard-reduction execution layer — §3.1/§4 of the paper promoted to a
//! system boundary.
//!
//! The paper proves the online normalizer `(m, d)` forms an associative,
//! commutative monoid under ⊕ (eq. 4), and that the fused softmax+top-k
//! state (Algorithm 4) merges the same way.  That licenses evaluating a
//! vocabulary row in *any* partition order: SIMD lanes
//! ([`crate::softmax::vectorized`]), worker threads within one vector
//! ([`crate::softmax::parallel`]), and — this module — **vocabulary
//! shards** distributed across a persistent worker pool:
//!
//! ```text
//!   row x[0..V] ── ShardPlan ──► shard 0 ─ scan ─► (m₀, d₀, topk₀) ┐
//!                               shard 1 ─ scan ─► (m₁, d₁, topk₁) ├─ ⊕ tree ─► finalize
//!                               ...                               │   (reduce)
//!                               shard S ─ scan ─► (m_S, d_S, topk_S) ┘
//! ```
//!
//! * [`plan`] — balanced shard arithmetic ([`ShardPlan`]).
//! * [`reduce`] — [`ShardPartial`] and the ⊕/buffer tree reduction,
//!   the cross-shard analogue of the paper's Algorithm 4.
//! * [`engine`] — [`ShardEngine`]: executes plans on an
//!   [`exec::ThreadPool`](crate::exec::ThreadPool), with a
//!   threshold-gated single-thread fallback that is bitwise-identical
//!   to the unsharded kernels.
//!
//! The coordinator routes large-vocabulary requests here (see
//! [`crate::coordinator::executor`]); the same partials arrive from
//! PJRT engines when AOT artifacts are served, so the reduction code is
//! shared between the host and accelerator backends.

pub mod engine;
pub mod plan;
pub mod reduce;

pub use engine::{ShardEngine, ShardEngineConfig};
pub use plan::{ShardPlan, ShardRange};
pub use reduce::{tree_reduce, ShardPartial};
