//! Cross-shard reduction: merging per-shard `(m, d, topk)` partials —
//! the distributed analogue of the paper's Algorithm 4.
//!
//! Each shard contributes a [`ShardPartial`]: its online-normalizer
//! state (eq. 3) and its top-k candidate buffer with *global* indices.
//! Both components merge associatively — ⊕ (eq. 4) on the normalizer,
//! incumbent-wins buffer merge on the candidates — so the reduction may
//! run in any bracketing.  [`tree_reduce`] uses a pairwise bottom-up
//! tree: log-depth (parallelizable) and slightly *better* fp accuracy
//! than a left fold (error grows with tree depth, not shard count).

use crate::sample::{self, SampleSpec, SampledBuffer};
use crate::softmax::fused;
use crate::softmax::monoid::MD;
use crate::topk::TopKBuffer;

/// One vocabulary shard's contribution to a fused softmax+top-k query.
#[derive(Clone, Debug)]
pub struct ShardPartial {
    /// Partial online normalizer over the shard's elements.
    pub md: MD,
    /// Shard-local top-k candidates carrying global indices.
    pub topk: TopKBuffer,
    /// Shard-local Gumbel-top-k candidates (perturbed-score selection),
    /// present iff the query is sampled.  Because each perturbation is
    /// a pure function of `(seed, global index)`, this state obeys the
    /// same ⊕ merge law as `topk` — see `docs/BACKENDS.md`.
    pub sampled: Option<SampledBuffer>,
}

impl ShardPartial {
    /// Scan one shard slice in a single fused sweep (Algorithm 4's
    /// loop over `[base, base + x.len())` of the global row).
    pub fn scan(x: &[f32], k: usize, base: i64) -> ShardPartial {
        Self::scan_with(x, k, base, None)
    }

    /// [`Self::scan`] with an optional sampled (Gumbel-top-k) state:
    /// the same single sweep additionally tracks the top-k by seeded
    /// perturbed score when `spec` is present.
    pub fn scan_with(
        x: &[f32],
        k: usize,
        base: i64,
        spec: Option<SampleSpec>,
    ) -> ShardPartial {
        let (md, topk) = fused::fused_partial(x, k, base);
        let sampled = spec.map(|s| sample::scan_sampled(x, k, base, s));
        ShardPartial { md, topk, sampled }
    }

    /// An empty partial (the reduction identity).
    pub fn identity(k: usize) -> ShardPartial {
        ShardPartial { md: MD::IDENTITY, topk: TopKBuffer::new(k), sampled: None }
    }

    /// Associative merge: ⊕ on `(m, d)`, buffer-merge on the top-k.
    ///
    /// Ties between equal logit values resolve to `self`'s incumbent,
    /// so merging shards in ascending vocabulary order preserves the
    /// whole-row scan's earliest-index-wins convention.
    ///
    /// Merging two shard scans recovers the whole-row scan (the law
    /// every [`ShardBackend`](super::backend::ShardBackend) partial
    /// must satisfy — `m` and the selected indices exactly, `d` up to
    /// fp reassociation):
    ///
    /// ```
    /// use onlinesoftmax::shard::ShardPartial;
    ///
    /// let x = [1.0f32, 4.0, -2.0, 4.0, 3.0, 0.5];
    /// let whole = ShardPartial::scan(&x, 2, 0);
    /// let merged = ShardPartial::scan(&x[..3], 2, 0)
    ///     .merge(ShardPartial::scan(&x[3..], 2, 3));
    /// assert_eq!(merged.md.m, whole.md.m);
    /// assert!((merged.md.d - whole.md.d).abs() <= 1e-5 * whole.md.d);
    /// // the tied 4.0s resolve to the earliest global index, 1 then 3
    /// assert_eq!(merged.topk.indices(), whole.topk.indices());
    /// assert_eq!(merged.topk.indices(), &[1, 3]);
    ///
    /// // ⊕ identity: merging the empty partial changes nothing
    /// let with_id = whole.clone().merge(ShardPartial::identity(2));
    /// assert_eq!(with_id.md, whole.md);
    /// ```
    pub fn merge(mut self, other: ShardPartial) -> ShardPartial {
        self.md = self.md.combine(other.md);
        self.topk.merge(&other.topk);
        // Sampled state merges under the same law; an absent side (the
        // identity partial, or an unsampled query) is neutral.
        self.sampled = match (self.sampled.take(), other.sampled) {
            (Some(mut a), Some(b)) => {
                a.merge(&b);
                Some(a)
            }
            (a, b) => a.or(b),
        };
        self
    }

    /// Lines 17–19 of Algorithm 4 over the merged state.
    pub fn finalize(&self) -> (Vec<f32>, Vec<i64>) {
        fused::finalize(&self.topk, self.md)
    }

    /// Sampled-selection finalization: the untempered probability
    /// `e^{x−m}/d` of each Gumbel-top-k candidate, in descending
    /// perturbed-score order.  Panics if the partial was scanned
    /// without a [`SampleSpec`] — callers route here only for sampled
    /// queries.
    pub fn finalize_sampled(&self) -> (Vec<f32>, Vec<i64>) {
        let buf = self
            .sampled
            .as_ref()
            .expect("finalize_sampled on a partial scanned without a SampleSpec");
        sample::finalize_sampled(buf, self.md)
    }
}

/// Pairwise bottom-up tree reduction of shard partials.
///
/// Equivalent (up to fp reassociation of `d`; indices exactly) to the
/// sequential left fold for any input order; adjacent pairing preserves
/// ascending-shard tie-breaking.
pub fn tree_reduce(mut parts: Vec<ShardPartial>) -> ShardPartial {
    assert!(!parts.is_empty(), "tree_reduce of zero shard partials");
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(a.merge(b)),
                None => next.push(a),
            }
        }
        parts = next;
    }
    parts.pop().expect("non-empty by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::shard::plan::ShardPlan;
    use crate::softmax::fused::online_topk;

    fn logits(n: usize, seed: u64) -> Vec<f32> {
        Xoshiro256pp::seed_from_u64(seed).logits(n, 8.0)
    }

    fn partials(x: &[f32], k: usize, shards: usize) -> Vec<ShardPartial> {
        ShardPlan::with_shards(x.len(), shards)
            .ranges()
            .map(|r| ShardPartial::scan(&x[r.start..r.end], k, r.start as i64))
            .collect()
    }

    #[test]
    fn tree_reduce_equals_whole_row_scan() {
        let x = logits(5000, 1);
        let k = 7;
        let (want_vals, want_idx) = online_topk(&x, k);
        for shards in [1usize, 2, 3, 4, 7, 16, 64] {
            let merged = tree_reduce(partials(&x, k, shards));
            let (vals, idx) = merged.finalize();
            assert_eq!(idx, want_idx, "shards={shards}");
            for (a, b) in vals.iter().zip(&want_vals) {
                assert!((a - b).abs() <= 2e-5 * a.max(*b), "shards={shards}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tree_reduce_equals_sequential_fold() {
        let x = logits(2048, 2);
        let k = 5;
        let parts = partials(&x, k, 9);
        let tree = tree_reduce(parts.clone());
        let seq = parts
            .into_iter()
            .reduce(ShardPartial::merge)
            .expect("non-empty");
        assert_eq!(tree.md.m, seq.md.m);
        assert!((tree.md.d - seq.md.d).abs() <= 1e-5 * seq.md.d);
        assert_eq!(tree.topk.indices(), seq.topk.indices());
    }

    #[test]
    fn merge_with_identity_is_noop() {
        let x = logits(600, 3);
        let part = ShardPartial::scan(&x, 4, 0);
        let merged = part.clone().merge(ShardPartial::identity(4));
        assert_eq!(merged.md, part.md);
        assert_eq!(merged.topk.indices(), part.topk.indices());
        let merged = ShardPartial::identity(4).merge(part.clone());
        assert_eq!(merged.md, part.md);
        assert_eq!(merged.topk.indices(), part.topk.indices());
    }

    #[test]
    fn single_partial_passes_through() {
        let x = logits(100, 4);
        let part = ShardPartial::scan(&x, 3, 0);
        let reduced = tree_reduce(vec![part.clone()]);
        assert_eq!(reduced.md, part.md);
        assert_eq!(reduced.topk.indices(), part.topk.indices());
    }

    #[test]
    #[should_panic(expected = "zero shard partials")]
    fn empty_reduction_panics() {
        tree_reduce(Vec::new());
    }

    fn sampled_partials(
        x: &[f32],
        k: usize,
        shards: usize,
        spec: SampleSpec,
    ) -> Vec<ShardPartial> {
        ShardPlan::with_shards(x.len(), shards)
            .ranges()
            .map(|r| ShardPartial::scan_with(&x[r.start..r.end], k, r.start as i64, Some(spec)))
            .collect()
    }

    #[test]
    fn sampled_tree_reduce_equals_whole_row_scan() {
        let x = logits(5000, 21);
        let k = 6;
        let spec = SampleSpec { seed: 17, temperature: 0.8 };
        let whole = ShardPartial::scan_with(&x, k, 0, Some(spec));
        let (want_vals, want_idx) = whole.finalize_sampled();
        assert_eq!(want_idx.len(), k);
        for shards in [1usize, 2, 3, 4, 7, 16, 64] {
            let merged = tree_reduce(sampled_partials(&x, k, shards, spec));
            let (vals, idx) = merged.finalize_sampled();
            // Selections are bitwise-identical under any decomposition:
            // perturbed scores are pure functions of (seed, index).
            assert_eq!(idx, want_idx, "shards={shards}");
            for (a, b) in vals.iter().zip(&want_vals) {
                assert!((a - b).abs() <= 2e-5 * a.max(*b), "shards={shards}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sampled_merge_with_identity_is_neutral() {
        let x = logits(600, 23);
        let spec = SampleSpec { seed: 3, temperature: 1.0 };
        let part = ShardPartial::scan_with(&x, 4, 0, Some(spec));
        let want = part.finalize_sampled();
        let merged = part.clone().merge(ShardPartial::identity(4));
        assert_eq!(merged.finalize_sampled().1, want.1);
        let merged = ShardPartial::identity(4).merge(part);
        assert_eq!(merged.finalize_sampled().1, want.1);
    }

    #[test]
    fn unsampled_scan_has_no_sampled_state() {
        let part = ShardPartial::scan(&logits(64, 1), 3, 0);
        assert!(part.sampled.is_none());
    }
}
