//! Cross-shard reduction: merging per-shard `(m, d, topk)` partials —
//! the distributed analogue of the paper's Algorithm 4.
//!
//! Each shard contributes a [`ShardPartial`]: its online-normalizer
//! state (eq. 3) and its top-k candidate buffer with *global* indices.
//! Both components merge associatively — ⊕ (eq. 4) on the normalizer,
//! incumbent-wins buffer merge on the candidates — so the reduction may
//! run in any bracketing.  [`tree_reduce`] uses a pairwise bottom-up
//! tree: log-depth (parallelizable) and slightly *better* fp accuracy
//! than a left fold (error grows with tree depth, not shard count).

use crate::json::Value;
use crate::sample::{self, SampleSpec, SampledBuffer};
use crate::softmax::fused;
use crate::softmax::monoid::MD;
use crate::topk::TopKBuffer;

/// One vocabulary shard's contribution to a fused softmax+top-k query.
#[derive(Clone, Debug)]
pub struct ShardPartial {
    /// Partial online normalizer over the shard's elements.
    pub md: MD,
    /// Shard-local top-k candidates carrying global indices.
    pub topk: TopKBuffer,
    /// Shard-local Gumbel-top-k candidates (perturbed-score selection),
    /// present iff the query is sampled.  Because each perturbation is
    /// a pure function of `(seed, global index)`, this state obeys the
    /// same ⊕ merge law as `topk` — see `docs/BACKENDS.md`.
    pub sampled: Option<SampledBuffer>,
}

impl ShardPartial {
    /// Scan one shard slice in a single fused sweep (Algorithm 4's
    /// loop over `[base, base + x.len())` of the global row).
    pub fn scan(x: &[f32], k: usize, base: i64) -> ShardPartial {
        Self::scan_with(x, k, base, None)
    }

    /// [`Self::scan`] with an optional sampled (Gumbel-top-k) state:
    /// the same single sweep additionally tracks the top-k by seeded
    /// perturbed score when `spec` is present.
    pub fn scan_with(
        x: &[f32],
        k: usize,
        base: i64,
        spec: Option<SampleSpec>,
    ) -> ShardPartial {
        let (md, topk) = fused::fused_partial(x, k, base);
        let sampled = spec.map(|s| sample::scan_sampled(x, k, base, s));
        ShardPartial { md, topk, sampled }
    }

    /// An empty partial (the reduction identity).
    pub fn identity(k: usize) -> ShardPartial {
        ShardPartial { md: MD::IDENTITY, topk: TopKBuffer::new(k), sampled: None }
    }

    /// Associative merge: ⊕ on `(m, d)`, buffer-merge on the top-k.
    ///
    /// Ties between equal logit values resolve to `self`'s incumbent,
    /// so merging shards in ascending vocabulary order preserves the
    /// whole-row scan's earliest-index-wins convention.
    ///
    /// Merging two shard scans recovers the whole-row scan (the law
    /// every [`ShardBackend`](super::backend::ShardBackend) partial
    /// must satisfy — `m` and the selected indices exactly, `d` up to
    /// fp reassociation):
    ///
    /// ```
    /// use onlinesoftmax::shard::ShardPartial;
    ///
    /// let x = [1.0f32, 4.0, -2.0, 4.0, 3.0, 0.5];
    /// let whole = ShardPartial::scan(&x, 2, 0);
    /// let merged = ShardPartial::scan(&x[..3], 2, 0)
    ///     .merge(ShardPartial::scan(&x[3..], 2, 3));
    /// assert_eq!(merged.md.m, whole.md.m);
    /// assert!((merged.md.d - whole.md.d).abs() <= 1e-5 * whole.md.d);
    /// // the tied 4.0s resolve to the earliest global index, 1 then 3
    /// assert_eq!(merged.topk.indices(), whole.topk.indices());
    /// assert_eq!(merged.topk.indices(), &[1, 3]);
    ///
    /// // ⊕ identity: merging the empty partial changes nothing
    /// let with_id = whole.clone().merge(ShardPartial::identity(2));
    /// assert_eq!(with_id.md, whole.md);
    /// ```
    pub fn merge(mut self, other: ShardPartial) -> ShardPartial {
        self.md = self.md.combine(other.md);
        self.topk.merge(&other.topk);
        // Sampled state merges under the same law; an absent side (the
        // identity partial, or an unsampled query) is neutral.
        self.sampled = match (self.sampled.take(), other.sampled) {
            (Some(mut a), Some(b)) => {
                a.merge(&b);
                Some(a)
            }
            (a, b) => a.or(b),
        };
        self
    }

    /// Lines 17–19 of Algorithm 4 over the merged state.
    pub fn finalize(&self) -> (Vec<f32>, Vec<i64>) {
        fused::finalize(&self.topk, self.md)
    }

    /// Sampled-selection finalization: the untempered probability
    /// `e^{x−m}/d` of each Gumbel-top-k candidate, in descending
    /// perturbed-score order.  Panics if the partial was scanned
    /// without a [`SampleSpec`] — callers route here only for sampled
    /// queries.
    pub fn finalize_sampled(&self) -> (Vec<f32>, Vec<i64>) {
        let buf = self
            .sampled
            .as_ref()
            .expect("finalize_sampled on a partial scanned without a SampleSpec");
        sample::finalize_sampled(buf, self.md)
    }
}

/// Encode an `(m, d)` normalizer state for the wire.
///
/// JSON numbers cannot carry `−∞` (it would serialize as `null`), so
/// the ⊕ identity gets a dedicated `{"identity":true}` shape; every
/// other state is `{"m":…, "d":…}` with finite components.
pub fn md_to_wire(md: MD) -> Value {
    let mut v = Value::object();
    if md.is_identity() {
        v.set("identity", Value::Bool(true));
    } else {
        v.set("m", Value::Number(md.m as f64));
        v.set("d", Value::Number(md.d as f64));
    }
    v
}

/// Decode an `(m, d)` normalizer state from the wire, rejecting
/// non-finite `m` and non-finite or non-positive `d` (a hostile or
/// corrupt peer must never inject a poisoned normalizer into the ⊕
/// tree).  The error string names the offending field.
pub fn md_from_wire(v: &Value) -> Result<MD, String> {
    if v.get("identity").and_then(Value::as_bool) == Some(true) {
        return Ok(MD::IDENTITY);
    }
    let m = v.get("m").and_then(Value::as_f64).ok_or("`m` must be a number")? as f32;
    let d = v.get("d").and_then(Value::as_f64).ok_or("`d` must be a number")? as f32;
    if !m.is_finite() {
        return Err(format!("non-finite m {m}"));
    }
    if !(d.is_finite() && d > 0.0) {
        return Err(format!("d {d} must be finite and > 0"));
    }
    Ok(MD { m, d })
}

fn finite_f32_array(v: &Value, what: &str) -> Result<Vec<f32>, String> {
    let arr = v.as_array().ok_or_else(|| format!("`{what}` must be an array"))?;
    arr.iter()
        .map(|e| {
            e.as_f64()
                .map(|n| n as f32)
                .filter(|f| f.is_finite())
                .ok_or_else(|| format!("`{what}` must hold finite numbers"))
        })
        .collect()
}

fn index_array(v: &Value, what: &str, start: usize, end: usize) -> Result<Vec<i64>, String> {
    let arr = v.as_array().ok_or_else(|| format!("`{what}` must be an array"))?;
    arr.iter()
        .map(|e| {
            let i = e.as_i64().ok_or_else(|| format!("`{what}` must hold integers"))?;
            if i < start as i64 || i >= end as i64 {
                return Err(format!("`{what}` index {i} outside shard range {start}:{end}"));
            }
            Ok(i)
        })
        .collect()
}

impl ShardPartial {
    /// Encode this partial for the wire (`shard_scan` partials reply).
    ///
    /// Only real (index ≥ 0) buffer entries are serialized, in stored
    /// (descending) order; the sentinel tail is reconstructed by
    /// [`from_wire`](Self::from_wire) from `k`.  Sampled state rides as
    /// aligned `s` (perturbed score) / `x` (raw logit) / `p` (index)
    /// arrays when present.
    pub fn to_wire(&self) -> Value {
        let mut v = md_to_wire(self.md);
        let mut vals = Vec::new();
        let mut idx = Vec::new();
        for (u, p) in self.topk.entries() {
            if p >= 0 {
                vals.push(Value::Number(u as f64));
                idx.push(Value::Number(p as f64));
            }
        }
        let mut topk = Value::object();
        topk.set("vals", Value::Array(vals));
        topk.set("idx", Value::Array(idx));
        v.set("topk", topk);
        if let Some(buf) = &self.sampled {
            let mut s = Vec::new();
            let mut x = Vec::new();
            let mut p = Vec::new();
            for (score, logit, index) in buf.entries() {
                if index >= 0 {
                    s.push(Value::Number(score as f64));
                    x.push(Value::Number(logit as f64));
                    p.push(Value::Number(index as f64));
                }
            }
            let mut sampled = Value::object();
            sampled.set("s", Value::Array(s));
            sampled.set("x", Value::Array(x));
            sampled.set("p", Value::Array(p));
            v.set("sampled", sampled);
        }
        v
    }

    /// Decode a partial from the wire, validating every component the
    /// router will feed into its ⊕ tree: the normalizer (via
    /// [`md_from_wire`]), buffer values/scores/logits finite, indices
    /// inside the shard's declared global `[start, end)` range, aligned
    /// lengths ≤ `k`, and sampled state present exactly when the query
    /// was sampled.  Entries rebuild through the buffers' own `push`
    /// path in stored order, so a roundtrip is bitwise-identical.
    pub fn from_wire(
        v: &Value,
        k: usize,
        start: usize,
        end: usize,
        sampled: bool,
    ) -> Result<ShardPartial, String> {
        let md = md_from_wire(v)?;
        let topk_v = v.get("topk").ok_or("missing `topk`")?;
        let vals =
            finite_f32_array(topk_v.get("vals").ok_or("missing `topk.vals`")?, "topk.vals")?;
        let idx =
            index_array(topk_v.get("idx").ok_or("missing `topk.idx`")?, "topk.idx", start, end)?;
        if vals.len() != idx.len() {
            return Err("`topk.vals` and `topk.idx` lengths differ".into());
        }
        if vals.len() > k {
            return Err(format!("`topk` carries {} entries for k={k}", vals.len()));
        }
        let mut topk = TopKBuffer::new(k);
        for (&u, &p) in vals.iter().zip(&idx) {
            topk.push(u, p);
        }
        let sampled = match (v.get("sampled"), sampled) {
            (Some(sv), true) => {
                let s = finite_f32_array(sv.get("s").ok_or("missing `sampled.s`")?, "sampled.s")?;
                let x = finite_f32_array(sv.get("x").ok_or("missing `sampled.x`")?, "sampled.x")?;
                let p =
                    index_array(sv.get("p").ok_or("missing `sampled.p`")?, "sampled.p", start, end)?;
                if s.len() != x.len() || s.len() != p.len() {
                    return Err("`sampled.s`/`sampled.x`/`sampled.p` lengths differ".into());
                }
                if s.len() > k {
                    return Err(format!("`sampled` carries {} entries for k={k}", s.len()));
                }
                let mut buf = SampledBuffer::new(k);
                for i in 0..s.len() {
                    buf.push(s[i], x[i], p[i]);
                }
                Some(buf)
            }
            (None, false) => None,
            (Some(_), false) => return Err("unexpected `sampled` state on a greedy query".into()),
            (None, true) => return Err("missing `sampled` state on a sampled query".into()),
        };
        Ok(ShardPartial { md, topk, sampled })
    }
}

/// Pairwise bottom-up tree reduction of shard partials.
///
/// Equivalent (up to fp reassociation of `d`; indices exactly) to the
/// sequential left fold for any input order; adjacent pairing preserves
/// ascending-shard tie-breaking.
pub fn tree_reduce(mut parts: Vec<ShardPartial>) -> ShardPartial {
    assert!(!parts.is_empty(), "tree_reduce of zero shard partials");
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(a.merge(b)),
                None => next.push(a),
            }
        }
        parts = next;
    }
    parts.pop().expect("non-empty by construction")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::shard::plan::ShardPlan;
    use crate::softmax::fused::online_topk;

    fn logits(n: usize, seed: u64) -> Vec<f32> {
        Xoshiro256pp::seed_from_u64(seed).logits(n, 8.0)
    }

    fn partials(x: &[f32], k: usize, shards: usize) -> Vec<ShardPartial> {
        ShardPlan::with_shards(x.len(), shards)
            .ranges()
            .map(|r| ShardPartial::scan(&x[r.start..r.end], k, r.start as i64))
            .collect()
    }

    #[test]
    fn tree_reduce_equals_whole_row_scan() {
        let x = logits(5000, 1);
        let k = 7;
        let (want_vals, want_idx) = online_topk(&x, k);
        for shards in [1usize, 2, 3, 4, 7, 16, 64] {
            let merged = tree_reduce(partials(&x, k, shards));
            let (vals, idx) = merged.finalize();
            assert_eq!(idx, want_idx, "shards={shards}");
            for (a, b) in vals.iter().zip(&want_vals) {
                assert!((a - b).abs() <= 2e-5 * a.max(*b), "shards={shards}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tree_reduce_equals_sequential_fold() {
        let x = logits(2048, 2);
        let k = 5;
        let parts = partials(&x, k, 9);
        let tree = tree_reduce(parts.clone());
        let seq = parts
            .into_iter()
            .reduce(ShardPartial::merge)
            .expect("non-empty");
        assert_eq!(tree.md.m, seq.md.m);
        assert!((tree.md.d - seq.md.d).abs() <= 1e-5 * seq.md.d);
        assert_eq!(tree.topk.indices(), seq.topk.indices());
    }

    #[test]
    fn merge_with_identity_is_noop() {
        let x = logits(600, 3);
        let part = ShardPartial::scan(&x, 4, 0);
        let merged = part.clone().merge(ShardPartial::identity(4));
        assert_eq!(merged.md, part.md);
        assert_eq!(merged.topk.indices(), part.topk.indices());
        let merged = ShardPartial::identity(4).merge(part.clone());
        assert_eq!(merged.md, part.md);
        assert_eq!(merged.topk.indices(), part.topk.indices());
    }

    #[test]
    fn single_partial_passes_through() {
        let x = logits(100, 4);
        let part = ShardPartial::scan(&x, 3, 0);
        let reduced = tree_reduce(vec![part.clone()]);
        assert_eq!(reduced.md, part.md);
        assert_eq!(reduced.topk.indices(), part.topk.indices());
    }

    #[test]
    #[should_panic(expected = "zero shard partials")]
    fn empty_reduction_panics() {
        tree_reduce(Vec::new());
    }

    fn sampled_partials(
        x: &[f32],
        k: usize,
        shards: usize,
        spec: SampleSpec,
    ) -> Vec<ShardPartial> {
        ShardPlan::with_shards(x.len(), shards)
            .ranges()
            .map(|r| ShardPartial::scan_with(&x[r.start..r.end], k, r.start as i64, Some(spec)))
            .collect()
    }

    #[test]
    fn sampled_tree_reduce_equals_whole_row_scan() {
        let x = logits(5000, 21);
        let k = 6;
        let spec = SampleSpec { seed: 17, temperature: 0.8 };
        let whole = ShardPartial::scan_with(&x, k, 0, Some(spec));
        let (want_vals, want_idx) = whole.finalize_sampled();
        assert_eq!(want_idx.len(), k);
        for shards in [1usize, 2, 3, 4, 7, 16, 64] {
            let merged = tree_reduce(sampled_partials(&x, k, shards, spec));
            let (vals, idx) = merged.finalize_sampled();
            // Selections are bitwise-identical under any decomposition:
            // perturbed scores are pure functions of (seed, index).
            assert_eq!(idx, want_idx, "shards={shards}");
            for (a, b) in vals.iter().zip(&want_vals) {
                assert!((a - b).abs() <= 2e-5 * a.max(*b), "shards={shards}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sampled_merge_with_identity_is_neutral() {
        let x = logits(600, 23);
        let spec = SampleSpec { seed: 3, temperature: 1.0 };
        let part = ShardPartial::scan_with(&x, 4, 0, Some(spec));
        let want = part.finalize_sampled();
        let merged = part.clone().merge(ShardPartial::identity(4));
        assert_eq!(merged.finalize_sampled().1, want.1);
        let merged = ShardPartial::identity(4).merge(part);
        assert_eq!(merged.finalize_sampled().1, want.1);
    }

    #[test]
    fn unsampled_scan_has_no_sampled_state() {
        let part = ShardPartial::scan(&logits(64, 1), 3, 0);
        assert!(part.sampled.is_none());
    }

    // ----- wire serde -----------------------------------------------------

    /// Encode → serialize → parse → decode, as the router does over TCP.
    fn roundtrip(part: &ShardPartial, k: usize, start: usize, end: usize, sampled: bool) -> ShardPartial {
        let doc = crate::json::parse(&part.to_wire().to_json()).expect("wire JSON parses");
        ShardPartial::from_wire(&doc, k, start, end, sampled).expect("wire partial decodes")
    }

    #[test]
    fn wire_roundtrip_is_bitwise() {
        let x = logits(700, 31);
        let k = 6;
        let part = ShardPartial::scan(&x[100..400], k, 100);
        let back = roundtrip(&part, k, 100, 400, false);
        assert_eq!(back.md, part.md);
        assert_eq!(back.topk.values(), part.topk.values());
        assert_eq!(back.topk.indices(), part.topk.indices());
        assert!(back.sampled.is_none());
    }

    #[test]
    fn wire_roundtrip_preserves_sentinel_tail() {
        // A shard smaller than k serializes only its real entries; the
        // decoder reconstructs the −∞/−1 sentinel tail from k.
        let x = logits(3, 7);
        let part = ShardPartial::scan(&x, 5, 40);
        assert_eq!(part.topk.len_filled(), 3);
        let back = roundtrip(&part, 5, 40, 43, false);
        assert_eq!(back.topk.values(), part.topk.values());
        assert_eq!(back.topk.indices(), part.topk.indices());
        assert_eq!(back.topk.len_filled(), 3);
    }

    #[test]
    fn wire_roundtrip_sampled_is_bitwise() {
        let x = logits(512, 33);
        let k = 4;
        let spec = SampleSpec { seed: 99, temperature: 0.7 };
        let part = ShardPartial::scan_with(&x, k, 0, Some(spec));
        let back = roundtrip(&part, k, 0, 512, true);
        assert_eq!(back.md, part.md);
        assert_eq!(back.topk.values(), part.topk.values());
        assert_eq!(back.topk.indices(), part.topk.indices());
        let (a, b) = (back.sampled.expect("sampled state"), part.sampled.expect("sampled state"));
        assert_eq!(a.scores(), b.scores());
        assert_eq!(a.indices(), b.indices());
        assert_eq!(back.finalize_sampled(), part.finalize_sampled());
    }

    #[test]
    fn wire_roundtrip_identity() {
        let part = ShardPartial::identity(3);
        let doc = crate::json::parse(&part.to_wire().to_json()).expect("parses");
        assert_eq!(doc.get("identity").and_then(crate::json::Value::as_bool), Some(true));
        let back = ShardPartial::from_wire(&doc, 3, 0, 10, false).expect("decodes");
        assert!(back.md.is_identity());
        assert_eq!(back.topk.len_filled(), 0);
    }

    #[test]
    fn wire_rejects_corruption_typed() {
        let k = 3;
        // Every case must decode to Err — never panic.
        let bad = [
            // non-finite m (JSON can't say Inf; null and strings must fail)
            r#"{"m":null,"d":1.0,"topk":{"vals":[],"idx":[]}}"#,
            r#"{"m":"inf","d":1.0,"topk":{"vals":[],"idx":[]}}"#,
            // d must be finite and > 0
            r#"{"m":1.0,"d":0.0,"topk":{"vals":[],"idx":[]}}"#,
            r#"{"m":1.0,"d":-2.0,"topk":{"vals":[],"idx":[]}}"#,
            r#"{"m":1.0,"d":null,"topk":{"vals":[],"idx":[]}}"#,
            // missing / malformed topk
            r#"{"m":1.0,"d":1.0}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[1.0]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[1.0],"idx":[5,6]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[1,2,3,4],"idx":[5,6,7,8]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[null],"idx":[5]}}"#,
            // out-of-range global indices (shard range is 4..9 below)
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[1.0],"idx":[3]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[1.0],"idx":[9]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[1.0],"idx":[-1]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[1.0],"idx":[5.5]}}"#,
            // sampled state on a greedy query
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]},"sampled":{"s":[],"x":[],"p":[]}}"#,
        ];
        for doc in bad {
            let v = crate::json::parse(doc).expect("test corpus is valid JSON");
            let got = ShardPartial::from_wire(&v, k, 4, 9, false);
            assert!(got.is_err(), "decoded corrupt partial: {doc}");
        }
        // A sampled query must find its sampled state...
        let v = crate::json::parse(r#"{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]}}"#).unwrap();
        assert!(ShardPartial::from_wire(&v, k, 4, 9, true).is_err());
        // ...with aligned, in-range, finite components.
        for doc in [
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]},"sampled":{"s":[1.0],"x":[1.0],"p":[5,6]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]},"sampled":{"s":[null],"x":[1.0],"p":[5]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]},"sampled":{"s":[1.0],"x":[1.0],"p":[99]}}"#,
            r#"{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]},"sampled":{"s":[1.0],"x":[1.0]}}"#,
        ] {
            let v = crate::json::parse(doc).expect("test corpus is valid JSON");
            assert!(ShardPartial::from_wire(&v, k, 4, 9, true).is_err(), "decoded: {doc}");
        }
    }

    #[test]
    fn wire_md_roundtrip() {
        let md = MD { m: 3.25, d: 17.5 };
        let doc = crate::json::parse(&md_to_wire(md).to_json()).unwrap();
        assert_eq!(md_from_wire(&doc).unwrap(), md);
        let id = crate::json::parse(&md_to_wire(MD::IDENTITY).to_json()).unwrap();
        assert!(md_from_wire(&id).unwrap().is_identity());
    }
}
