//! The shard execution engine: runs per-shard scans on a persistent
//! [`ThreadPool`](crate::exec::ThreadPool) and reduces the partials.
//!
//! This is the host-side execution layer behind the coordinator's
//! sharded path: a query over a vocabulary-length row is planned into
//! shards ([`super::plan`]), each shard is scanned on a pool worker
//! (fused online-softmax + top-k, Algorithm 4), and the partials merge
//! through the ⊕ tree reduction ([`super::reduce`]).  Rows below the
//! configured threshold never fan out — the single-thread vectorized
//! kernels are bitwise-identical in that regime and avoid all dispatch
//! overhead.
//!
//! Whole batches execute through the batch×shard grid
//! ([`super::grid`]): every (row, shard) tile of a [`GridPlan`] is
//! submitted to the pool in **one** scoped dispatch
//! ([`ShardEngine::grid_map`]), each row's ⊕ tree reduction runs
//! concurrently on whichever worker finishes that row's last tile, and
//! the caller joins once.  The single-row entry points are the
//! degenerate 1×S grid, so batched and per-row execution are
//! bitwise-identical by construction.
//!
//! Per-tile scans are delegated to a pluggable [`ShardBackend`]
//! (selected by [`ShardEngineConfig::backend`]): every tile dispatch —
//! fused scans, normalizer passes, and scale passes alike — goes
//! through the backend object, and a tile the backend declines at
//! runtime ([`backend::Unsupported`]) is transparently rerun on the
//! total [`backend::HostScalar`] scan (the **per-tile fallback**,
//! counted in `shard.backend.<name>.fallbacks`).  Planning, the ⊕
//! reduction, and scheduling never move — only the leaf scan does.
//! See `docs/BACKENDS.md` for the backend-author contract.

// xtask:atomics-allowlist: AcqRel
// AcqRel: the grid's per-row countdown — each tile's decrement must
// release its slot write and the final decrementer must acquire every
// sibling's; see the comment at the `fetch_sub` site.

use std::ops::Range;
use std::sync::Arc;

use crate::exec::sync::{AtomicUsize, Ordering};
use crate::exec::{self, SchedPolicy, ThreadPool};
use crate::metrics::{self, Counter};
use crate::sample::SampleSpec;
use crate::softmax::monoid::{self, MD};

use super::backend::{self, ShardBackend, ShardBackendKind};
use super::grid::{GridPlan, GridTile};
use super::plan::{ShardPlan, ShardRange};
use super::reduce::{self, ShardPartial};

/// Tuning knobs for a [`ShardEngine`].
#[derive(Clone, Copy, Debug)]
pub struct ShardEngineConfig {
    /// Pool worker threads (0 = one per available core).
    pub workers: usize,
    /// Maximum shards per query (0 = same as `workers`).
    pub max_shards: usize,
    /// Minimum elements per shard (guards against over-splitting).
    pub min_shard: usize,
    /// Row length at which queries start sharding; below it the
    /// single-thread kernel runs inline (bitwise-identical results).
    pub threshold: usize,
    /// Scheduling policy for the shard pool.  `Steal` (the default)
    /// keeps workers fed under skewed tile costs; `Fifo` preserves
    /// strict submission order.  Results are bitwise-identical under
    /// either — the ⊕ bracketing is fixed by the plan, not by which
    /// worker runs which tile when.
    pub sched: SchedPolicy,
    /// Which per-tile scan backend the engine dispatches to.  `Scalar`
    /// (the default) is the original fused host scan and keeps every
    /// output bitwise-identical to the pre-backend engine; the serving
    /// layer selects its own default via `ServeConfig::shard_backend`.
    pub backend: ShardBackendKind,
}

impl Default for ShardEngineConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            max_shards: 0,
            min_shard: ShardPlan::DEFAULT_MIN_SHARD,
            threshold: 32_768,
            sched: SchedPolicy::Steal,
            backend: ShardBackendKind::Scalar,
        }
    }
}

/// Persistent shard-parallel executor for vocabulary-length rows.
pub struct ShardEngine {
    pool: Option<ThreadPool>,
    workers: usize,
    max_shards: usize,
    min_shard: usize,
    threshold: usize,
    sched: SchedPolicy,
    /// The selected per-tile scan backend.
    backend: Arc<dyn ShardBackend>,
    /// The total host scan every declined tile falls back to.
    fallback: backend::HostScalar,
    /// `shard.backend.<name>.tiles` — tiles dispatched to `backend`.
    tile_ctr: Arc<Counter>,
    /// `shard.backend.<name>.fallbacks` — tiles `backend` declined at
    /// runtime and the host scalar scan reran.
    fallback_ctr: Arc<Counter>,
}

impl ShardEngine {
    /// Build an engine from `cfg`: spawns the shard pool (when more
    /// than one worker is configured) and instantiates the selected
    /// per-tile scan backend.
    pub fn new(cfg: ShardEngineConfig) -> ShardEngine {
        let workers = if cfg.workers == 0 { exec::default_threads() } else { cfg.workers };
        let max_shards = if cfg.max_shards == 0 { workers } else { cfg.max_shards };
        let backend_obj = cfg.backend.instantiate();
        let reg = metrics::global();
        let tile_ctr = reg.counter(&format!("shard.backend.{}.tiles", backend_obj.name()));
        let fallback_ctr = reg.counter(&format!("shard.backend.{}.fallbacks", backend_obj.name()));
        ShardEngine {
            pool: (workers > 1).then(|| ThreadPool::with_policy(workers, "shard", cfg.sched)),
            workers,
            max_shards,
            min_shard: cfg.min_shard,
            threshold: cfg.threshold.max(1),
            sched: cfg.sched,
            backend: backend_obj,
            fallback: backend::HostScalar,
            tile_ctr,
            fallback_ctr,
        }
    }

    /// Number of pool workers (1 = fully inline engine).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The scheduling policy the shard pool runs under.
    pub fn sched(&self) -> SchedPolicy {
        self.sched
    }

    /// Name of the per-tile scan backend this engine dispatches to
    /// (the `shard.backend.<name>.*` metric prefix).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Cumulative count of tiles the selected backend declined at
    /// runtime and the host scalar scan reran (the process-wide
    /// `shard.backend.<name>.fallbacks` counter — monotone, shared by
    /// every engine running the same backend; consumers compare
    /// before/after deltas).
    pub fn backend_fallbacks(&self) -> u64 {
        self.fallback_ctr.get()
    }

    /// Dispatch one fused scan tile to the selected backend, falling
    /// back to the total [`backend::HostScalar`] scan if the backend
    /// declines the tile at runtime.
    ///
    /// `tile` holds exactly the elements of the *global* vocabulary
    /// interval `range` (callers that materialize their own logits —
    /// sharded projection decode — hand in just their slice), and the
    /// returned partial carries global candidate indices.  This is the
    /// engine's only path to a backend for fused queries, so every
    /// tile is counted in `shard.backend.<name>.tiles`.
    ///
    /// When `sample` is present the partial additionally carries the
    /// Gumbel-top-k candidate state — the per-tile perturbations are
    /// pure functions of `(seed, global index)`, so the fallback rerun
    /// produces the identical sampled partial too.
    pub fn scan_tile(
        &self,
        tile: &[f32],
        range: Range<usize>,
        k: usize,
        sample: Option<SampleSpec>,
    ) -> ShardPartial {
        assert_eq!(
            tile.len(),
            range.end - range.start,
            "tile slice must cover exactly its vocabulary range"
        );
        self.tile_ctr.inc();
        match self.backend.scan_tile(tile, range.clone(), k, sample) {
            Ok(part) => part,
            Err(unsupported) => {
                self.fallback_ctr.inc();
                // Debug level: the stub backend declines every tile by
                // design, so anything louder would flood the log; the
                // fallbacks counter is the always-on signal.
                crate::debug!("shard.backend", "host fallback: {unsupported}");
                self.fallback
                    .scan_tile(tile, range, k, sample)
                    .expect("HostScalar is total over every tile geometry")
            }
        }
    }

    /// Normalizer-only flavour of [`Self::scan_tile`] (pass 1 of a
    /// sharded softmax), with the same fallback protocol.
    pub fn normalizer_tile(&self, tile: &[f32], range: Range<usize>) -> MD {
        assert_eq!(
            tile.len(),
            range.end - range.start,
            "tile slice must cover exactly its vocabulary range"
        );
        self.tile_ctr.inc();
        match self.backend.normalizer_tile(tile, range.clone()) {
            Ok(md) => md,
            Err(unsupported) => {
                self.fallback_ctr.inc();
                crate::debug!("shard.backend", "host fallback: {unsupported}");
                self.fallback
                    .normalizer_tile(tile, range)
                    .expect("HostScalar is total over every tile geometry")
            }
        }
    }

    /// Output scale pass for one tile, through the backend (total — no
    /// fallback needed; see [`ShardBackend::scale_tile`]).
    fn scale_tile(&self, tile: &[f32], out: &mut [f32], m: f32, inv: f32) {
        self.backend.scale_tile(tile, out, m, inv);
    }

    /// Public scale-pass entry for one externally-materialized tile
    /// (the router tier's workers run pass 2 of a distributed softmax
    /// through this): `out[i] = e^{tile[i] − m} · inv` via the backend,
    /// exactly the kernel the in-process sharded scale pass dispatches.
    pub fn scale_slice(&self, tile: &[f32], out: &mut [f32], m: f32, inv: f32) {
        assert_eq!(tile.len(), out.len(), "scale output must match its tile");
        self.scale_tile(tile, out, m, inv);
    }

    /// Cumulative task-steal count from the pool metrics (the
    /// process-wide `exec.pool.steal.steals` counter; 0 for an inline
    /// engine).  Monotone — consumers compare before/after deltas.
    pub fn pool_steal_count(&self) -> u64 {
        self.pool.as_ref().map_or(0, |p| p.steal_stats().0)
    }

    /// The sharding threshold (row length) this engine was built with.
    pub fn threshold(&self) -> usize {
        self.threshold
    }

    /// Plan a query over a length-`v` row under this engine's config.
    pub fn plan(&self, v: usize) -> ShardPlan {
        if v < self.threshold || self.workers <= 1 {
            ShardPlan::single(v)
        } else {
            ShardPlan::auto(v, self.max_shards, self.min_shard)
        }
    }

    /// Plan a whole batch of `rows` length-`v` rows under this engine's
    /// config.
    ///
    /// The per-row split equals [`Self::plan`] exactly — threshold
    /// gating included, and deliberately **independent of `rows`**: the
    /// shards dimension already saturates the pool, so extra rows only
    /// multiply available tiles, and keeping the tile shape
    /// row-count-invariant is what makes an R×S grid dispatch
    /// bitwise-identical to R single-row dispatches.
    pub fn grid_plan(&self, rows: usize, v: usize) -> GridPlan {
        GridPlan::new(rows, self.plan(v))
    }

    /// Run `f` over every shard of `plan` (on the pool when the plan is
    /// sharded, inline otherwise), returning results in shard order.
    ///
    /// This is the engine's general fan-out primitive; the coordinator
    /// uses it directly for sharded *projection + scan* decode, where
    /// each shard materializes only its own slice of the logits.
    pub fn map<R, F>(&self, plan: &ShardPlan, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(ShardRange) -> R + Sync,
    {
        let n = plan.shards();
        let pool = match &self.pool {
            Some(pool) if n > 1 => pool,
            _ => return plan.ranges().map(f).collect(),
        };
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let slots_ptr = SendPtr(slots.as_mut_ptr());
        let f = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = plan
            .ranges()
            .map(|r| {
                let slots_ptr = &slots_ptr;
                Box::new(move || {
                    let out = f(r);
                    // SAFETY: each shard index is produced exactly once
                    // and run_scoped joins all tasks before `slots` is
                    // read, so writes are disjoint and complete.
                    unsafe { *slots_ptr.0.add(r.index) = Some(out) };
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        slots
            .into_iter()
            .map(|s| s.expect("shard task did not complete"))
            .collect()
    }

    /// Execute a [`GridPlan`] in one scoped dispatch: `scan` runs over
    /// every (row, shard) tile on the pool, and `reduce` folds each
    /// row's shard-ordered partials into that row's result **as soon as
    /// the row's last tile lands** — per-row reductions run concurrently
    /// with still-scanning rows, and the caller joins exactly once.
    ///
    /// Falls back to an inline row-major loop when the engine has no
    /// pool or the grid has a single tile (bitwise-identical results —
    /// `scan`/`reduce` are the same functions either way).
    ///
    /// Per-tile scan latency is recorded in the `shard.grid.tile_us`
    /// histogram, per-row reductions in `shard.grid.row_reduce_us`, and
    /// dispatch/tile counts in `shard.grid.{dispatches,tiles}` (pooled
    /// path only; the inline path stays metrics-free).
    pub fn grid_map<P, T, SF, RF>(&self, grid: &GridPlan, scan: SF, reduce: RF) -> Vec<T>
    where
        P: Send,
        T: Send,
        SF: Fn(GridTile) -> P + Sync,
        RF: Fn(usize, Vec<P>) -> T + Sync,
    {
        let rows = grid.rows();
        let s = grid.shards_per_row();
        if rows == 0 {
            return Vec::new();
        }
        let pool = match &self.pool {
            Some(pool) if grid.is_parallel() => pool,
            _ => {
                return (0..rows)
                    .map(|row| {
                        let parts: Vec<P> =
                            (0..s).map(|shard| scan(grid.tile(row, shard))).collect();
                        reduce(row, parts)
                    })
                    .collect();
            }
        };

        let reg = metrics::global();
        reg.counter("shard.grid.dispatches").inc();
        reg.counter("shard.grid.tiles").add(grid.tile_count() as u64);
        let tile_hist = reg.histogram("shard.grid.tile_us");
        let reduce_hist = reg.histogram("shard.grid.row_reduce_us");

        let mut parts: Vec<Option<P>> = Vec::with_capacity(grid.tile_count());
        parts.resize_with(grid.tile_count(), || None);
        let mut results: Vec<Option<T>> = Vec::with_capacity(rows);
        results.resize_with(rows, || None);
        let remaining: Vec<AtomicUsize> = (0..rows).map(|_| AtomicUsize::new(s)).collect();

        let parts_ptr = SendPtr(parts.as_mut_ptr());
        let results_ptr = SendPtr(results.as_mut_ptr());
        let scan = &scan;
        let reduce = &reduce;
        let remaining = &remaining;
        let tile_hist = &tile_hist;
        let reduce_hist = &reduce_hist;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = grid
            .tiles()
            .map(|tile| {
                let parts_ptr = &parts_ptr;
                let results_ptr = &results_ptr;
                Box::new(move || {
                    let t0 = std::time::Instant::now();
                    let out = scan(tile);
                    tile_hist.record(t0.elapsed());
                    // SAFETY: each (row, shard) slot is written exactly
                    // once, and read only after the row's countdown hits
                    // zero (below) or after run_scoped joins.
                    unsafe { *parts_ptr.0.add(tile.row * s + tile.range.index) = Some(out) };
                    // AcqRel: release our slot write to whichever task
                    // ends up reducing the row; acquire every sibling's.
                    if remaining[tile.row].fetch_sub(1, Ordering::AcqRel) == 1 {
                        let t1 = std::time::Instant::now();
                        let row_parts: Vec<P> = (0..s)
                            .map(|shard| {
                                // SAFETY: the countdown reached zero, so
                                // all s sibling writes are visible and no
                                // other task touches these slots again.
                                unsafe {
                                    (*parts_ptr.0.add(tile.row * s + shard))
                                        .take()
                                        .expect("sibling tile completed")
                                }
                            })
                            .collect();
                        let folded = reduce(tile.row, row_parts);
                        // SAFETY: exactly one task per row observes the
                        // countdown reach zero; run_scoped joins before
                        // `results` is read.
                        unsafe { *results_ptr.0.add(tile.row) = Some(folded) };
                        reduce_hist.record(t1.elapsed());
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        results
            .into_iter()
            .map(|r| r.expect("grid row did not complete"))
            .collect()
    }

    /// Fused online softmax + top-k over one row (Algorithm 4, sharded):
    /// per-shard single-sweep partials, ⊕/buffer tree reduction, final
    /// `e^{u−m}/d` scaling.  Returns `(vals, idx)` sorted descending.
    pub fn fused_topk(&self, x: &[f32], k: usize) -> (Vec<f32>, Vec<i64>) {
        self.fused_topk_planned(x, k, &self.plan(x.len()))
    }

    /// [`Self::fused_topk`] under an explicit plan (tests and benches
    /// pin shard counts with this).  Executes as the degenerate 1×S
    /// grid.
    pub fn fused_topk_planned(
        &self,
        x: &[f32],
        k: usize,
        plan: &ShardPlan,
    ) -> (Vec<f32>, Vec<i64>) {
        assert_eq!(plan.v(), x.len(), "plan does not cover the row");
        self.fused_topk_batch_planned(&[x], k, &GridPlan::single_row(*plan))
            .pop()
            .expect("one row")
    }

    /// Fused online softmax + top-k over a whole batch of same-length
    /// rows, tiled as an R×S grid and dispatched to the pool in one
    /// scheduling pass.  Results are bitwise-identical to calling
    /// [`Self::fused_topk`] per row.
    pub fn fused_topk_batch(&self, rows: &[&[f32]], k: usize) -> Vec<(Vec<f32>, Vec<i64>)> {
        let v = rows.first().map_or(0, |r| r.len());
        self.fused_topk_batch_planned(rows, k, &self.grid_plan(rows.len(), v))
    }

    /// [`Self::fused_topk_batch`] under an explicit grid.
    pub fn fused_topk_batch_planned(
        &self,
        rows: &[&[f32]],
        k: usize,
        grid: &GridPlan,
    ) -> Vec<(Vec<f32>, Vec<i64>)> {
        self.topk_batch_core(rows, k, grid, None)
    }

    /// Seeded Gumbel-top-k sampling fused into the same single-sweep
    /// scan as [`Self::fused_topk`]: every tile additionally tracks the
    /// top-k by perturbed score `x/T + Gumbel(seed, index)` while the
    /// exact online normalizer accumulates, and the ⊕ tree reduction
    /// merges sampled candidates exactly like deterministic top-k.
    /// Returns `(vals, idx)` where `idx` is the sampled selection
    /// (descending perturbed score) and `vals` the **untempered**
    /// probabilities `e^{x−m}/d` of those tokens.  Selections are
    /// bitwise-identical for a fixed spec across backends, scheduling
    /// policies, and grid decompositions.
    pub fn sampled_topk(&self, x: &[f32], k: usize, spec: SampleSpec) -> (Vec<f32>, Vec<i64>) {
        self.sampled_topk_planned(x, k, &self.plan(x.len()), spec)
    }

    /// [`Self::sampled_topk`] under an explicit plan (the degenerate
    /// 1×S grid, like its greedy counterpart).
    pub fn sampled_topk_planned(
        &self,
        x: &[f32],
        k: usize,
        plan: &ShardPlan,
        spec: SampleSpec,
    ) -> (Vec<f32>, Vec<i64>) {
        assert_eq!(plan.v(), x.len(), "plan does not cover the row");
        self.sampled_topk_batch_planned(&[x], k, &GridPlan::single_row(*plan), spec)
            .pop()
            .expect("one row")
    }

    /// Batched [`Self::sampled_topk`] over same-length rows, tiled as
    /// an R×S grid in one scheduling pass.  All rows share one spec —
    /// per-row specs (mixed sampled/greedy batches) are composed by the
    /// coordinator through [`Self::grid_map`] directly.
    pub fn sampled_topk_batch(
        &self,
        rows: &[&[f32]],
        k: usize,
        spec: SampleSpec,
    ) -> Vec<(Vec<f32>, Vec<i64>)> {
        let v = rows.first().map_or(0, |r| r.len());
        self.sampled_topk_batch_planned(rows, k, &self.grid_plan(rows.len(), v), spec)
    }

    /// [`Self::sampled_topk_batch`] under an explicit grid.
    pub fn sampled_topk_batch_planned(
        &self,
        rows: &[&[f32]],
        k: usize,
        grid: &GridPlan,
        spec: SampleSpec,
    ) -> Vec<(Vec<f32>, Vec<i64>)> {
        self.topk_batch_core(rows, k, grid, Some(spec))
    }

    /// Shared grid executor behind the greedy and sampled fused top-k
    /// entry points: identical planning, scan dispatch, and ⊕ tree
    /// reduction; only the finalization (deterministic vs sampled
    /// ranking) differs.
    fn topk_batch_core(
        &self,
        rows: &[&[f32]],
        k: usize,
        grid: &GridPlan,
        sample: Option<SampleSpec>,
    ) -> Vec<(Vec<f32>, Vec<i64>)> {
        assert_eq!(grid.rows(), rows.len(), "grid does not cover the batch");
        for r in rows {
            assert_eq!(r.len(), grid.v(), "all rows must match the planned length");
        }
        self.grid_map(
            grid,
            |tile| {
                let x = rows[tile.row];
                self.scan_tile(
                    &x[tile.range.start..tile.range.end],
                    tile.range.start..tile.range.end,
                    k,
                    sample,
                )
            },
            |_row, parts| {
                let merged = reduce::tree_reduce(parts);
                if sample.is_some() {
                    merged.finalize_sampled()
                } else {
                    merged.finalize()
                }
            },
        )
    }

    /// Sharded online normalizer: per-shard `(m, d)` partials reduced
    /// with the ⊕ tree (§3.1 across shards).
    pub fn normalizer(&self, x: &[f32]) -> MD {
        self.normalizer_planned(x, &self.plan(x.len()))
    }

    /// [`Self::normalizer`] under an explicit plan.
    pub fn normalizer_planned(&self, x: &[f32], plan: &ShardPlan) -> MD {
        assert_eq!(plan.v(), x.len(), "plan does not cover the row");
        if !plan.is_sharded() {
            return self.normalizer_tile(x, 0..x.len());
        }
        let parts = self.map(plan, |r| self.normalizer_tile(&x[r.start..r.end], r.start..r.end));
        monoid::tree_reduce(&parts)
    }

    /// Full sharded online softmax: normalizer reduction, then a
    /// shard-parallel scale pass into disjoint slices of `out`.
    pub fn softmax_into(&self, x: &[f32], out: &mut [f32]) {
        let plan = self.plan(x.len());
        self.softmax_into_planned(x, out, &plan);
    }

    /// [`Self::softmax_into`] under an explicit plan.
    pub fn softmax_into_planned(&self, x: &[f32], out: &mut [f32], plan: &ShardPlan) {
        assert_eq!(x.len(), out.len());
        if !plan.is_sharded() {
            // Single-tile path: normalizer + scale through the backend
            // (for the scalar backend this is exactly the unsharded
            // `vectorized::online` kernel, bitwise).
            let md = self.normalizer_tile(x, 0..x.len());
            self.scale_tile(x, out, md.m, 1.0 / md.d);
            return;
        }
        let md = self.normalizer_planned(x, plan);
        let inv = 1.0 / md.d;
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        self.map(plan, |r| {
            // SAFETY: shard ranges are disjoint and in-bounds for `out`
            // (same length as `x`); map joins before `out` is reused.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out_ref.0.add(r.start), r.len())
            };
            self.scale_tile(&x[r.start..r.end], dst, md.m, inv);
        });
    }

    /// Allocating convenience form of [`Self::softmax_into`].
    pub fn softmax(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        self.softmax_into(x, &mut out);
        out
    }

    /// Full online softmax over a whole batch of same-length rows, tiled
    /// as an R×S grid.  Results are bitwise-identical to calling
    /// [`Self::softmax`] per row.
    pub fn softmax_batch(&self, rows: &[&[f32]]) -> Vec<Vec<f32>> {
        let v = rows.first().map_or(0, |r| r.len());
        self.softmax_batch_planned(rows, &self.grid_plan(rows.len(), v))
    }

    /// [`Self::softmax_batch`] under an explicit grid.
    ///
    /// Softmax needs each row's *global* `(m, d)` before any output can
    /// be written, so the sharded form is two grid dispatches — a
    /// normalizer grid (per-tile `(m, d)`, per-row ⊕ tree reduction)
    /// and a scale grid writing into disjoint slices of preallocated
    /// row buffers — rather than fused top-k's single one.  That is
    /// still two scoped joins per **batch** instead of two per row, and
    /// no output byte is ever copied.
    pub fn softmax_batch_planned(&self, rows: &[&[f32]], grid: &GridPlan) -> Vec<Vec<f32>> {
        assert_eq!(grid.rows(), rows.len(), "grid does not cover the batch");
        for r in rows {
            assert_eq!(r.len(), grid.v(), "all rows must match the planned length");
        }
        let mut outs: Vec<Vec<f32>> = rows.iter().map(|r| vec![0.0f32; r.len()]).collect();
        let out_ptrs: Vec<SendPtr<f32>> =
            outs.iter_mut().map(|o| SendPtr(o.as_mut_ptr())).collect();
        let out_ptrs = &out_ptrs;
        if !grid.row_plan().is_sharded() {
            // Degenerate R×1 grid: one normalizer + scale visit per row
            // through the backend (for the scalar backend this is the
            // unsharded `vectorized::online` kernel, bitwise), with the
            // rows themselves as the dispatch's tiles.
            self.grid_map(
                grid,
                |tile| {
                    let row = rows[tile.row];
                    let md = self.normalizer_tile(row, 0..row.len());
                    // SAFETY: one tile per row → exclusive access to the
                    // row's output buffer; grid_map joins before `outs`
                    // is returned.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(
                            out_ptrs[tile.row].0,
                            tile.range.len(),
                        )
                    };
                    self.scale_tile(row, dst, md.m, 1.0 / md.d);
                },
                |_row, _parts| (),
            );
            return outs;
        }
        // Pass 1: per-tile (m, d) partials, per-row ⊕ tree reduction.
        let mds: Vec<MD> = self.grid_map(
            grid,
            |tile| {
                self.normalizer_tile(
                    &rows[tile.row][tile.range.start..tile.range.end],
                    tile.range.start..tile.range.end,
                )
            },
            |_row, parts| monoid::tree_reduce(&parts),
        );
        // Pass 2: per-tile `e^{x−m}/d` scale with the row's global
        // normalizer, each tile writing its own disjoint output slice.
        let mds = &mds;
        self.grid_map(
            grid,
            |tile| {
                let md = mds[tile.row];
                // SAFETY: tile ranges within a row are disjoint and
                // in-bounds for its output buffer (same length as the
                // row); grid_map joins before `outs` is returned.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(
                        out_ptrs[tile.row].0.add(tile.range.start),
                        tile.range.len(),
                    )
                };
                self.scale_tile(
                    &rows[tile.row][tile.range.start..tile.range.end],
                    dst,
                    md.m,
                    1.0 / md.d,
                );
            },
            |_row, _parts| (),
        );
        outs
    }
}

/// Raw pointer wrapper asserting cross-thread transfer is safe under
/// the disjoint-write discipline documented at each use site.
///
/// SAFETY contract (all three clauses required at every construction
/// site, which is why the type and its tuple constructor are private to
/// this module):
///
/// 1. **Disjoint writes** — each element index reachable through the
///    pointer is written by at most one task; tasks never read another
///    task's slot until a synchronization point (the row countdown in
///    [`ShardEngine::grid_map`], or the scoped join) orders the write
///    before the read.
/// 2. **Outlives the fan-out** — the pointee is owned by the dispatching
///    frame and is only read back after `run_scoped`/`grid_map` joins
///    every task.
/// 3. **`T: Send`** — writing (or `take()`-ing) a `T` through the
///    pointer on a worker thread transfers a `T` across threads.  The
///    bound makes an attempt to fan out a `!Send` payload (`Rc`,
///    `RefCell` guards, raw-pointer-holding partials …) a compile
///    error instead of undefined behaviour; an unbounded
///    `unsafe impl<T> Send/Sync` silently erased exactly that check.
struct SendPtr<T>(*mut T);
// SAFETY: per the three-clause contract above — disjoint writes, the
// pointee outlives the fan-out, and `T: Send` covers the cross-thread
// transfer of the written values.
unsafe impl<T: Send> Sync for SendPtr<T> {}
// SAFETY: as above — moving the wrapper only moves the raw pointer.
unsafe impl<T: Send> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::softmax::vectorized;
    use crate::softmax::{self, fused, Algorithm};

    fn logits(n: usize, seed: u64) -> Vec<f32> {
        Xoshiro256pp::seed_from_u64(seed).logits(n, 7.0)
    }

    fn engine(workers: usize, threshold: usize) -> ShardEngine {
        ShardEngine::new(ShardEngineConfig {
            workers,
            max_shards: 0,
            min_shard: 64,
            threshold,
            ..ShardEngineConfig::default()
        })
    }

    #[test]
    #[cfg_attr(miri, ignore)] // multi-thousand-element rows; grid unsafe paths are miri-covered by the small tests
    fn sharded_softmax_matches_single_thread() {
        let eng = engine(4, 256);
        for n in [256usize, 1000, 4097, 20_000] {
            let x = logits(n, n as u64);
            let sharded = eng.softmax(&x);
            let serial = softmax::compute(&x, Algorithm::Online);
            for (i, (a, b)) in sharded.iter().zip(&serial).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-9 + 1e-5 * b.abs(),
                    "n={n} idx={i}: {a} vs {b}"
                );
            }
            let sum: f32 = sharded.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "n={n} sum={sum}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 5k-element row; grid unsafe paths are miri-covered by the small tests
    fn below_threshold_is_bitwise_identical() {
        let eng = engine(4, 100_000);
        let x = logits(5000, 5);
        assert_eq!(eng.plan(x.len()).shards(), 1);
        let a = eng.softmax(&x);
        let b = softmax::compute(&x, Algorithm::Online);
        assert_eq!(a, b, "serial fallback must be the identical kernel");
        let md = eng.normalizer(&x);
        let want = vectorized::online_normalizer(&x);
        assert_eq!((md.m, md.d), (want.m, want.d));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // multi-thousand-element rows; grid unsafe paths are miri-covered by the small tests
    fn sharded_fused_topk_matches_single_sweep() {
        let eng = engine(4, 256);
        for (n, k) in [(300usize, 1usize), (2048, 5), (10_000, 16), (511, 50)] {
            let x = logits(n, (n * k) as u64);
            let (sv, si) = eng.fused_topk(&x, k);
            let (wv, wi) = fused::online_topk(&x, k);
            assert_eq!(si, wi, "n={n} k={k}");
            for (a, b) in sv.iter().zip(&wv) {
                assert!((a - b).abs() <= 2e-5 * a.max(*b), "n={n} k={k}");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // repeated 1k-element dispatches; grid unsafe paths are miri-covered by the small tests
    fn explicit_plans_cover_odd_shard_counts() {
        let eng = engine(3, 1);
        let x = logits(1003, 9);
        let whole = fused::online_topk(&x, 6);
        for shards in [1usize, 2, 3, 5, 7, 11, 1003] {
            let plan = ShardPlan::with_shards(x.len(), shards);
            let (_, idx) = eng.fused_topk_planned(&x, 6, &plan);
            assert_eq!(idx, whole.1, "shards={shards}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 9k-element row; grid unsafe paths are miri-covered by the small tests
    fn single_worker_engine_runs_inline() {
        let eng = engine(1, 1);
        assert_eq!(eng.workers(), 1);
        let x = logits(9000, 2);
        assert!(!eng.plan(x.len()).is_sharded());
        let (_, idx) = eng.fused_topk(&x, 4);
        assert_eq!(idx, fused::online_topk(&x, 4).1);
    }

    #[test]
    fn map_preserves_shard_order() {
        let eng = engine(4, 1);
        let plan = ShardPlan::with_shards(1000, 7);
        let spans = eng.map(&plan, |r| (r.index, r.start, r.end));
        for (i, (idx, start, end)) in spans.iter().enumerate() {
            assert_eq!(*idx, i);
            assert!(start < end);
        }
        assert_eq!(spans.len(), 7);
    }

    #[test]
    fn empty_and_tiny_rows() {
        let eng = engine(2, 1);
        assert!(eng.softmax(&[]).is_empty());
        let (vals, idx) = eng.fused_topk(&[], 3);
        assert!(vals.is_empty() && idx.is_empty());
        let y = eng.softmax(&[4.0]);
        assert_eq!(y, vec![1.0]);
    }

    #[test]
    fn miri_sized_sharded_grid_smoke() {
        // Small enough for `cargo miri test shard::engine::`: drives the
        // sharded scan, the per-row countdown, and every SendPtr write
        // path with two 96-element rows over 3 shards.
        let eng = ShardEngine::new(ShardEngineConfig {
            workers: 2,
            max_shards: 3,
            min_shard: 16,
            threshold: 32,
            ..ShardEngineConfig::default()
        });
        let data: Vec<Vec<f32>> = (0..2).map(|i| logits(96, i as u64)).collect();
        let rows: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        assert!(eng.plan(96).is_sharded());
        let got = eng.fused_topk_batch(&rows, 3);
        for (row, out) in rows.iter().zip(&got) {
            assert_eq!(*out, eng.fused_topk(row, 3), "batch vs per-row must be bitwise");
        }
        for p in &eng.softmax_batch(&rows) {
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // multi-row 4k grids; grid unsafe paths are miri-covered by the small tests
    fn grid_batch_matches_per_row_dispatch_bitwise() {
        let eng = engine(4, 256);
        for (rows_n, n, k) in [(1usize, 2048usize, 5usize), (3, 1003, 4), (8, 4097, 7)] {
            let data: Vec<Vec<f32>> =
                (0..rows_n).map(|i| logits(n, (n + i) as u64)).collect();
            let rows: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
            let got = eng.fused_topk_batch(&rows, k);
            assert_eq!(got.len(), rows_n);
            for (row, out) in rows.iter().zip(&got) {
                assert_eq!(*out, eng.fused_topk(row, k), "grid topk must be bitwise");
            }
            let probs = eng.softmax_batch(&rows);
            for (row, out) in rows.iter().zip(&probs) {
                assert_eq!(*out, eng.softmax(row), "grid softmax must be bitwise");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 5x3k grid; grid unsafe paths are miri-covered by the small tests
    fn grid_degenerate_shapes_run() {
        // Threshold above every row: the grid is R×1 — rows themselves
        // are the tiles, each running the unsharded fused kernel.
        let eng = engine(4, 100_000);
        let data: Vec<Vec<f32>> = (0..5).map(|i| logits(3000, i as u64)).collect();
        let rows: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        let grid = eng.grid_plan(rows.len(), 3000);
        assert_eq!(grid.shards_per_row(), 1);
        assert!(grid.is_parallel(), "rows alone still fan out");
        let probs = eng.softmax_batch(&rows);
        for (row, out) in rows.iter().zip(&probs) {
            assert_eq!(*out, softmax::compute(row, Algorithm::Online));
        }
        assert!(eng.fused_topk_batch(&[], 3).is_empty());
        assert!(eng.softmax_batch(&[]).is_empty());
    }

    #[test]
    fn grid_plan_is_row_count_invariant_and_threshold_gated() {
        // The bitwise-identity contract: the per-row split never
        // changes when more rows join the grid, and threshold gating
        // applies to grids exactly as to single rows.
        let eng = engine(4, 256);
        for rows in [1usize, 2, 8, 64] {
            let grid = eng.grid_plan(rows, 20_000);
            assert_eq!(grid.row_plan(), eng.plan(20_000));
            assert_eq!(grid.rows(), rows);
        }
        assert_eq!(eng.grid_plan(16, 100).shards_per_row(), 1, "below threshold stays serial");
    }

    #[test]
    fn grid_map_reduces_rows_in_shard_order() {
        let eng = engine(4, 1);
        let grid = GridPlan::new(3, ShardPlan::with_shards(100, 4));
        let out = eng.grid_map(
            &grid,
            |tile| (tile.row, tile.range.index),
            |row, parts| {
                assert!(parts.iter().all(|&(r, _)| r == row), "row {row}: {parts:?}");
                parts.iter().map(|&(_, s)| s).collect::<Vec<usize>>()
            },
        );
        assert_eq!(out, vec![vec![0, 1, 2, 3]; 3]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // six 4k rows under two pools; grid unsafe paths are miri-covered by the small tests
    fn fifo_and_steal_pools_are_bitwise_identical() {
        // Scheduling policy is a pure performance knob: the ⊕
        // bracketing is fixed by the plan, so fifo and steal engines
        // must agree byte-for-byte on every output.
        let mk = |sched| {
            ShardEngine::new(ShardEngineConfig {
                workers: 4,
                max_shards: 0,
                min_shard: 64,
                threshold: 256,
                sched,
                ..ShardEngineConfig::default()
            })
        };
        let fifo = mk(SchedPolicy::Fifo);
        let steal = mk(SchedPolicy::Steal);
        assert_eq!(fifo.sched(), SchedPolicy::Fifo);
        assert_eq!(steal.sched(), SchedPolicy::Steal);
        let data: Vec<Vec<f32>> = (0..6).map(|i| logits(4097, 70 + i as u64)).collect();
        let rows: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        assert_eq!(fifo.fused_topk_batch(&rows, 7), steal.fused_topk_batch(&rows, 7));
        assert_eq!(fifo.softmax_batch(&rows), steal.softmax_batch(&rows));
        assert_eq!(fifo.fused_topk(&rows[0], 5), steal.fused_topk(&rows[0], 5));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 4k-element row; grid unsafe paths are miri-covered by the small tests
    fn artifacts_stub_engine_serves_via_per_tile_host_fallback() {
        // The stub backend declines every tile at runtime; the engine
        // must transparently rerun each tile on the host scalar scan,
        // count the fallbacks, and produce the scalar backend's exact
        // selections.
        let mk = |backend| {
            ShardEngine::new(ShardEngineConfig {
                workers: 3,
                min_shard: 64,
                threshold: 256,
                backend,
                ..ShardEngineConfig::default()
            })
        };
        let stub = mk(ShardBackendKind::ArtifactsStub);
        let scalar = mk(ShardBackendKind::Scalar);
        assert_eq!(stub.backend_name(), "artifacts-stub");
        let before = stub.backend_fallbacks();
        let x = logits(4097, 77);
        assert_eq!(stub.fused_topk(&x, 6), scalar.fused_topk(&x, 6));
        assert_eq!(stub.softmax(&x), scalar.softmax(&x));
        assert!(
            stub.backend_fallbacks() > before,
            "every stub tile must be counted as a fallback"
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 2k-element row; grid unsafe paths are miri-covered by the small tests
    fn vectorized_engine_matches_indices_and_falls_back_below_stripe() {
        let eng = ShardEngine::new(ShardEngineConfig {
            workers: 2,
            min_shard: 1,
            threshold: 1,
            backend: ShardBackendKind::Vectorized,
            ..ShardEngineConfig::default()
        });
        assert_eq!(eng.backend_name(), "vectorized");
        // Lane-aligned tiles: same selections as the whole-row scan.
        let x = logits(2048, 5);
        let (_, idx) = eng.fused_topk_planned(&x, 7, &ShardPlan::with_shards(2048, 4));
        assert_eq!(idx, fused::online_topk(&x, 7).1);
        // Sub-stripe tiles (40 / 8 = 5 elements each): the vectorized
        // backend declines and the host fallback answers.
        let before = eng.backend_fallbacks();
        let y = logits(40, 6);
        let (_, idx) = eng.fused_topk_planned(&y, 3, &ShardPlan::with_shards(40, 8));
        assert_eq!(idx, fused::online_topk(&y, 3).1);
        assert!(eng.backend_fallbacks() > before);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 2k-element row; grid unsafe paths are miri-covered by the small tests
    fn twopass_engine_matches_indices_and_falls_back_below_lane_width() {
        let eng = ShardEngine::new(ShardEngineConfig {
            workers: 2,
            min_shard: 1,
            threshold: 1,
            backend: ShardBackendKind::TwoPass,
            ..ShardEngineConfig::default()
        });
        assert_eq!(eng.backend_name(), "twopass");
        // Multi-stripe tiles: same selections as the whole-row scan.
        let x = logits(2048, 5);
        let (_, idx) = eng.fused_topk_planned(&x, 7, &ShardPlan::with_shards(2048, 4));
        assert_eq!(idx, fused::online_topk(&x, 7).1);
        // Sub-lane tiles (40 / 8 = 5 elements each): the twopass
        // backend declines and the host fallback answers.
        let before = eng.backend_fallbacks();
        let y = logits(40, 6);
        let (_, idx) = eng.fused_topk_planned(&y, 3, &ShardPlan::with_shards(40, 8));
        assert_eq!(idx, fused::online_topk(&y, 3).1);
        assert!(eng.backend_fallbacks() > before);
        // Normalizer path declines the same geometry.
        let before = eng.backend_fallbacks();
        let md = eng.normalizer_planned(&y, &ShardPlan::with_shards(40, 8));
        let want = vectorized::online_normalizer(&y);
        assert_eq!(md.m, want.m);
        assert!((md.d - want.d).abs() <= 1e-4 * want.d);
        assert!(eng.backend_fallbacks() > before);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 3k-element row per backend; grid unsafe paths are miri-covered by the small tests
    fn every_backend_kind_produces_reference_selections() {
        let x = logits(3000, 42);
        let plan = ShardPlan::with_shards(3000, 5);
        let want = fused::online_topk(&x, 5).1;
        for kind in ShardBackendKind::all() {
            let eng = ShardEngine::new(ShardEngineConfig {
                workers: 2,
                min_shard: 1,
                threshold: 1,
                backend: kind,
                ..ShardEngineConfig::default()
            });
            let (_, idx) = eng.fused_topk_planned(&x, 5, &plan);
            assert_eq!(idx, want, "backend {}", kind.as_str());
            let probs = eng.softmax(&x);
            let sum: f32 = probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "backend {}: sum={sum}", kind.as_str());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // multi-thousand-element rows; grid unsafe paths are miri-covered by the small tests
    fn sampled_topk_is_decomposition_invariant_and_seeded() {
        let eng = engine(4, 256);
        let spec = SampleSpec { seed: 31, temperature: 0.9 };
        let x = logits(10_000, 3);
        let whole = eng.sampled_topk_planned(&x, 5, &ShardPlan::single(x.len()), spec);
        for shards in [2usize, 3, 7, 16] {
            let got = eng.sampled_topk_planned(&x, 5, &ShardPlan::with_shards(x.len(), shards), spec);
            assert_eq!(got.1, whole.1, "shards={shards}: selections must be bitwise");
        }
        // Different seeds diverge; the greedy path is untouched.
        let other = eng.sampled_topk(&x, 5, SampleSpec { seed: 32, temperature: 0.9 });
        assert_ne!(other.1, whole.1);
        assert_ne!(whole.1, eng.fused_topk(&x, 5).1, "sampling should usually differ from greedy");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // multi-row 4k grids; grid unsafe paths are miri-covered by the small tests
    fn sampled_grid_batch_matches_per_row_dispatch_bitwise() {
        let eng = engine(4, 256);
        let spec = SampleSpec { seed: 77, temperature: 1.3 };
        let data: Vec<Vec<f32>> = (0..5).map(|i| logits(4097, 90 + i as u64)).collect();
        let rows: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        let got = eng.sampled_topk_batch(&rows, 6, spec);
        for (row, out) in rows.iter().zip(&got) {
            assert_eq!(*out, eng.sampled_topk(row, 6, spec), "grid sampled topk must be bitwise");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 3k-element row per backend; grid unsafe paths are miri-covered by the small tests
    fn every_backend_kind_produces_identical_sampled_selections() {
        let x = logits(3000, 43);
        let plan = ShardPlan::with_shards(3000, 5);
        let spec = SampleSpec { seed: 7, temperature: 0.8 };
        let mut selections = Vec::new();
        for kind in ShardBackendKind::all() {
            let eng = ShardEngine::new(ShardEngineConfig {
                workers: 2,
                min_shard: 1,
                threshold: 1,
                backend: kind,
                ..ShardEngineConfig::default()
            });
            let (_, idx) = eng.sampled_topk_planned(&x, 5, &plan, spec);
            selections.push((kind.as_str(), idx));
        }
        for (name, idx) in &selections[1..] {
            assert_eq!(idx, &selections[0].1, "backend {name} diverged from scalar");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // four 1k rows; grid unsafe paths are miri-covered by the small tests
    fn grid_map_ragged_last_tiles_cover_row() {
        // 7 shards over 1003 elements: ragged tile lengths; sums of the
        // tile slices must reassemble each row's total exactly.
        let eng = engine(3, 1);
        let data: Vec<Vec<f32>> = (0..4).map(|i| logits(1003, 50 + i as u64)).collect();
        let rows: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        let grid = GridPlan::new(rows.len(), ShardPlan::with_shards(1003, 7));
        let sums = eng.grid_map(
            &grid,
            |tile| {
                rows[tile.row][tile.range.start..tile.range.end]
                    .iter()
                    .map(|v| *v as f64)
                    .sum::<f64>()
            },
            |_row, parts| parts.into_iter().sum::<f64>(),
        );
        for (row, got) in rows.iter().zip(&sums) {
            let want: f64 = row.iter().map(|v| *v as f64).sum();
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }
}
