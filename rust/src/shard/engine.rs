//! The shard execution engine: runs per-shard scans on a persistent
//! [`ThreadPool`](crate::exec::ThreadPool) and reduces the partials.
//!
//! This is the host-side execution layer behind the coordinator's
//! sharded path: a query over a vocabulary-length row is planned into
//! shards ([`super::plan`]), each shard is scanned on a pool worker
//! (fused online-softmax + top-k, Algorithm 4), and the partials merge
//! through the ⊕ tree reduction ([`super::reduce`]).  Rows below the
//! configured threshold never fan out — the single-thread vectorized
//! kernels are bitwise-identical in that regime and avoid all dispatch
//! overhead.

use crate::exec::{self, ThreadPool};
use crate::softmax::monoid::{self, MD};
use crate::softmax::vectorized;

use super::plan::{ShardPlan, ShardRange};
use super::reduce::{self, ShardPartial};

/// Tuning knobs for a [`ShardEngine`].
#[derive(Clone, Copy, Debug)]
pub struct ShardEngineConfig {
    /// Pool worker threads (0 = one per available core).
    pub workers: usize,
    /// Maximum shards per query (0 = same as `workers`).
    pub max_shards: usize,
    /// Minimum elements per shard (guards against over-splitting).
    pub min_shard: usize,
    /// Row length at which queries start sharding; below it the
    /// single-thread kernel runs inline (bitwise-identical results).
    pub threshold: usize,
}

impl Default for ShardEngineConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            max_shards: 0,
            min_shard: ShardPlan::DEFAULT_MIN_SHARD,
            threshold: 32_768,
        }
    }
}

/// Persistent shard-parallel executor for vocabulary-length rows.
pub struct ShardEngine {
    pool: Option<ThreadPool>,
    workers: usize,
    max_shards: usize,
    min_shard: usize,
    threshold: usize,
}

impl ShardEngine {
    pub fn new(cfg: ShardEngineConfig) -> ShardEngine {
        let workers = if cfg.workers == 0 { exec::default_threads() } else { cfg.workers };
        let max_shards = if cfg.max_shards == 0 { workers } else { cfg.max_shards };
        ShardEngine {
            pool: (workers > 1).then(|| ThreadPool::new(workers, "shard")),
            workers,
            max_shards,
            min_shard: cfg.min_shard,
            threshold: cfg.threshold.max(1),
        }
    }

    /// Number of pool workers (1 = fully inline engine).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The sharding threshold (row length) this engine was built with.
    pub fn threshold(&self) -> usize {
        self.threshold
    }

    /// Plan a query over a length-`v` row under this engine's config.
    pub fn plan(&self, v: usize) -> ShardPlan {
        if v < self.threshold || self.workers <= 1 {
            ShardPlan::single(v)
        } else {
            ShardPlan::auto(v, self.max_shards, self.min_shard)
        }
    }

    /// Run `f` over every shard of `plan` (on the pool when the plan is
    /// sharded, inline otherwise), returning results in shard order.
    ///
    /// This is the engine's general fan-out primitive; the coordinator
    /// uses it directly for sharded *projection + scan* decode, where
    /// each shard materializes only its own slice of the logits.
    pub fn map<R, F>(&self, plan: &ShardPlan, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(ShardRange) -> R + Sync,
    {
        let n = plan.shards();
        let pool = match &self.pool {
            Some(pool) if n > 1 => pool,
            _ => return plan.ranges().map(f).collect(),
        };
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let slots_ptr = SendPtr(slots.as_mut_ptr());
        let f = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = plan
            .ranges()
            .map(|r| {
                let slots_ptr = &slots_ptr;
                Box::new(move || {
                    let out = f(r);
                    // SAFETY: each shard index is produced exactly once
                    // and run_scoped joins all tasks before `slots` is
                    // read, so writes are disjoint and complete.
                    unsafe { *slots_ptr.0.add(r.index) = Some(out) };
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        slots
            .into_iter()
            .map(|s| s.expect("shard task did not complete"))
            .collect()
    }

    /// Fused online softmax + top-k over one row (Algorithm 4, sharded):
    /// per-shard single-sweep partials, ⊕/buffer tree reduction, final
    /// `e^{u−m}/d` scaling.  Returns `(vals, idx)` sorted descending.
    pub fn fused_topk(&self, x: &[f32], k: usize) -> (Vec<f32>, Vec<i64>) {
        self.fused_topk_planned(x, k, &self.plan(x.len()))
    }

    /// [`Self::fused_topk`] under an explicit plan (tests and benches
    /// pin shard counts with this).
    pub fn fused_topk_planned(
        &self,
        x: &[f32],
        k: usize,
        plan: &ShardPlan,
    ) -> (Vec<f32>, Vec<i64>) {
        assert_eq!(plan.v(), x.len(), "plan does not cover the row");
        let parts =
            self.map(plan, |r| ShardPartial::scan(&x[r.start..r.end], k, r.start as i64));
        reduce::tree_reduce(parts).finalize()
    }

    /// Sharded online normalizer: per-shard `(m, d)` partials reduced
    /// with the ⊕ tree (§3.1 across shards).
    pub fn normalizer(&self, x: &[f32]) -> MD {
        self.normalizer_planned(x, &self.plan(x.len()))
    }

    /// [`Self::normalizer`] under an explicit plan.
    pub fn normalizer_planned(&self, x: &[f32], plan: &ShardPlan) -> MD {
        assert_eq!(plan.v(), x.len(), "plan does not cover the row");
        if !plan.is_sharded() {
            return vectorized::online_normalizer(x);
        }
        let parts = self.map(plan, |r| vectorized::online_normalizer(&x[r.start..r.end]));
        monoid::tree_reduce(&parts)
    }

    /// Full sharded online softmax: normalizer reduction, then a
    /// shard-parallel scale pass into disjoint slices of `out`.
    pub fn softmax_into(&self, x: &[f32], out: &mut [f32]) {
        let plan = self.plan(x.len());
        self.softmax_into_planned(x, out, &plan);
    }

    /// [`Self::softmax_into`] under an explicit plan.
    pub fn softmax_into_planned(&self, x: &[f32], out: &mut [f32], plan: &ShardPlan) {
        assert_eq!(x.len(), out.len());
        if !plan.is_sharded() {
            vectorized::online(x, out);
            return;
        }
        let md = self.normalizer_planned(x, plan);
        let inv = 1.0 / md.d;
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        self.map(plan, |r| {
            // SAFETY: shard ranges are disjoint and in-bounds for `out`
            // (same length as `x`); map joins before `out` is reused.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out_ref.0.add(r.start), r.len())
            };
            vectorized::scale_pass(&x[r.start..r.end], dst, md.m, inv);
        });
    }

    /// Allocating convenience form of [`Self::softmax_into`].
    pub fn softmax(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        self.softmax_into(x, &mut out);
        out
    }
}

/// Raw pointer wrapper asserting cross-thread transfer is safe under
/// the disjoint-write discipline documented at each use site.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::softmax::{self, fused, Algorithm};

    fn logits(n: usize, seed: u64) -> Vec<f32> {
        Xoshiro256pp::seed_from_u64(seed).logits(n, 7.0)
    }

    fn engine(workers: usize, threshold: usize) -> ShardEngine {
        ShardEngine::new(ShardEngineConfig {
            workers,
            max_shards: 0,
            min_shard: 64,
            threshold,
        })
    }

    #[test]
    fn sharded_softmax_matches_single_thread() {
        let eng = engine(4, 256);
        for n in [256usize, 1000, 4097, 20_000] {
            let x = logits(n, n as u64);
            let sharded = eng.softmax(&x);
            let serial = softmax::compute(&x, Algorithm::Online);
            for (i, (a, b)) in sharded.iter().zip(&serial).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-9 + 1e-5 * b.abs(),
                    "n={n} idx={i}: {a} vs {b}"
                );
            }
            let sum: f32 = sharded.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "n={n} sum={sum}");
        }
    }

    #[test]
    fn below_threshold_is_bitwise_identical() {
        let eng = engine(4, 100_000);
        let x = logits(5000, 5);
        assert_eq!(eng.plan(x.len()).shards(), 1);
        let a = eng.softmax(&x);
        let b = softmax::compute(&x, Algorithm::Online);
        assert_eq!(a, b, "serial fallback must be the identical kernel");
        let md = eng.normalizer(&x);
        let want = vectorized::online_normalizer(&x);
        assert_eq!((md.m, md.d), (want.m, want.d));
    }

    #[test]
    fn sharded_fused_topk_matches_single_sweep() {
        let eng = engine(4, 256);
        for (n, k) in [(300usize, 1usize), (2048, 5), (10_000, 16), (511, 50)] {
            let x = logits(n, (n * k) as u64);
            let (sv, si) = eng.fused_topk(&x, k);
            let (wv, wi) = fused::online_topk(&x, k);
            assert_eq!(si, wi, "n={n} k={k}");
            for (a, b) in sv.iter().zip(&wv) {
                assert!((a - b).abs() <= 2e-5 * a.max(*b), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn explicit_plans_cover_odd_shard_counts() {
        let eng = engine(3, 1);
        let x = logits(1003, 9);
        let whole = fused::online_topk(&x, 6);
        for shards in [1usize, 2, 3, 5, 7, 11, 1003] {
            let plan = ShardPlan::with_shards(x.len(), shards);
            let (_, idx) = eng.fused_topk_planned(&x, 6, &plan);
            assert_eq!(idx, whole.1, "shards={shards}");
        }
    }

    #[test]
    fn single_worker_engine_runs_inline() {
        let eng = engine(1, 1);
        assert_eq!(eng.workers(), 1);
        let x = logits(9000, 2);
        assert!(!eng.plan(x.len()).is_sharded());
        let (_, idx) = eng.fused_topk(&x, 4);
        assert_eq!(idx, fused::online_topk(&x, 4).1);
    }

    #[test]
    fn map_preserves_shard_order() {
        let eng = engine(4, 1);
        let plan = ShardPlan::with_shards(1000, 7);
        let spans = eng.map(&plan, |r| (r.index, r.start, r.end));
        for (i, (idx, start, end)) in spans.iter().enumerate() {
            assert_eq!(*idx, i);
            assert!(start < end);
        }
        assert_eq!(spans.len(), 7);
    }

    #[test]
    fn empty_and_tiny_rows() {
        let eng = engine(2, 1);
        assert!(eng.softmax(&[]).is_empty());
        let (vals, idx) = eng.fused_topk(&[], 3);
        assert!(vals.is_empty() && idx.is_empty());
        let y = eng.softmax(&[4.0]);
        assert_eq!(y, vec![1.0]);
    }
}
