//! Benchmark harness substrate (no `criterion` in the offline registry).
//!
//! Provides what the paper-figure benches need:
//!
//! * [`bench`] — warmup + calibrated timed iterations → [`Stats`]
//!   (mean/median/p05/p95/stddev, per-iteration),
//! * [`Stats::throughput_gbs`] — bandwidth from bytes-touched, the
//!   y-axis of every figure in the paper,
//! * [`Table`] — aligned console tables matching the paper's reporting
//!   (one row per vector size V, one column per algorithm, plus the
//!   speedup "bars"),
//! * [`black_box`] — optimization barrier.
//!
//! Deterministic workloads come from [`crate::rng`]; the harness never
//! allocates inside the timed region unless the benchmarked closure does.

use std::time::{Duration, Instant};

/// Optimization barrier (stable-rust implementation).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Per-iteration timing statistics, in seconds.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Stats {
    pub fn from_samples(mut secs: Vec<f64>) -> Stats {
        assert!(!secs.is_empty());
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = secs.len();
        let mean = secs.iter().sum::<f64>() / n as f64;
        let var = secs.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        let pick = |q: f64| secs[((q * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            iters: n,
            mean,
            median: pick(0.5),
            stddev: var.sqrt(),
            min: secs[0],
            max: secs[n - 1],
            p05: pick(0.05),
            p95: pick(0.95),
        }
    }

    /// Effective bandwidth given bytes touched per iteration.
    pub fn throughput_gbs(&self, bytes_per_iter: f64) -> f64 {
        bytes_per_iter / self.median / 1e9
    }

    /// Elements processed per second.
    pub fn elements_per_sec(&self, elems_per_iter: f64) -> f64 {
        elems_per_iter / self.median
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget for the measurement phase.
    pub measure_time: Duration,
    /// Wall-clock budget for warmup.
    pub warmup_time: Duration,
    /// Upper bound on recorded samples.
    pub max_samples: usize,
    /// Lower bound on recorded samples (overrides time budget).
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            measure_time: Duration::from_millis(300),
            warmup_time: Duration::from_millis(60),
            max_samples: 1000,
            min_samples: 10,
        }
    }
}

impl BenchConfig {
    /// Faster profile for CI / smoke runs (set `OSMAX_BENCH_FAST=1`).
    ///
    /// The *value* is parsed, not just the variable's presence:
    /// `OSMAX_BENCH_FAST=0` (or `false`, `no`, `off`, empty) keeps the
    /// full profile, so an exported-but-disabled variable can't
    /// silently shrink a measurement run.
    pub fn from_env() -> Self {
        Self::from_value(std::env::var("OSMAX_BENCH_FAST").ok().as_deref())
    }

    /// Testable core of [`Self::from_env`] — kept free of environment
    /// reads so tests never mutate process-global env vars.
    fn from_value(value: Option<&str>) -> Self {
        let fast = match value {
            None => false,
            Some(v) => !matches!(
                v.trim().to_ascii_lowercase().as_str(),
                "" | "0" | "false" | "no" | "off"
            ),
        };
        if fast {
            Self {
                measure_time: Duration::from_millis(60),
                warmup_time: Duration::from_millis(10),
                max_samples: 200,
                min_samples: 5,
            }
        } else {
            Self::default()
        }
    }
}

/// Run `f` under the config and return per-iteration stats.
///
/// The closure should perform *one* logical iteration and return a value
/// routed through [`black_box`] internally (or return unit after
/// black-boxing its outputs).
pub fn bench<R>(config: &BenchConfig, mut f: impl FnMut() -> R) -> Stats {
    // Warmup.
    let t0 = Instant::now();
    while t0.elapsed() < config.warmup_time {
        black_box(f());
    }
    // Measure.
    let mut samples = Vec::with_capacity(config.min_samples.max(64));
    let t1 = Instant::now();
    while (t1.elapsed() < config.measure_time || samples.len() < config.min_samples)
        && samples.len() < config.max_samples
    {
        let s = Instant::now();
        black_box(f());
        samples.push(s.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

// ---------------------------------------------------------------------------
// Console tables
// ---------------------------------------------------------------------------

/// Aligned console table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with right-aligned numeric-looking cells.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let pad = widths[i] - c.len();
                // right-align everything but the first column
                if i == 0 {
                    out.push_str(c);
                    out.push_str(&" ".repeat(pad));
                } else {
                    out.push_str(&" ".repeat(pad));
                    out.push_str(c);
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn bench_respects_min_samples() {
        let cfg = BenchConfig {
            measure_time: Duration::ZERO,
            warmup_time: Duration::ZERO,
            max_samples: 100,
            min_samples: 12,
        };
        let s = bench(&cfg, || black_box(1 + 1));
        assert!(s.iters >= 12);
    }

    #[test]
    fn throughput_math() {
        let s = Stats::from_samples(vec![0.001]); // 1 ms
        // 1 MB in 1 ms = 1 GB/s
        assert!((s.throughput_gbs(1e6) - 1.0).abs() < 1e-9);
        assert!((s.elements_per_sec(1000.0) - 1e6).abs() < 1e-3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["V", "safe", "online"]);
        t.row(vec!["100".into(), "1.0".into(), "1.30".into()]);
        t.row(vec!["100000".into(), "2.0".into(), "2.60".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("online"));
        assert!(lines[3].contains("100000"));
        // all rows same width
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn fast_profile_parses_the_value_not_just_presence() {
        let full = BenchConfig::default();
        let fast = BenchConfig::from_value(Some("1"));
        assert!(fast.measure_time < full.measure_time);
        assert!(fast.max_samples < full.max_samples);
        // Regression: `OSMAX_BENCH_FAST=0` used to enable fast mode
        // because only the variable's presence was checked.
        for disabled in [None, Some("0"), Some("false"), Some("no"), Some("off"), Some(""), Some(" 0 ")] {
            let cfg = BenchConfig::from_value(disabled);
            assert_eq!(cfg.measure_time, full.measure_time, "{disabled:?}");
            assert_eq!(cfg.max_samples, full.max_samples, "{disabled:?}");
        }
        for enabled in [Some("1"), Some("true"), Some("yes"), Some("fast"), Some("ON")] {
            let cfg = BenchConfig::from_value(enabled);
            assert_eq!(cfg.measure_time, fast.measure_time, "{enabled:?}");
        }
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
