//! TCP serving frontend: newline-delimited JSON over a thread-per-
//! connection listener, dispatching into the [`Coordinator`].
//!
//! * [`wire`] — the protocol codec (see its docs for the schema).
//! * [`Server`] — listener lifecycle (bind, accept loop, graceful stop).
//! * [`client::Client`] — blocking client used by the examples, the
//!   load-generator, and the integration tests.

pub mod client;
pub mod wire;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;
use crate::exec::ThreadPool;
use crate::metrics;
use crate::server::wire::Op;

/// Request-handling deadline (protects connection threads from a stuck
/// coordinator).
const REQUEST_TIMEOUT: Duration = Duration::from_secs(60);

/// The TCP server.
pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    pool: ThreadPool,
}

impl Server {
    /// Bind to `addr` (e.g. `127.0.0.1:7070`).  `conn_threads` bounds
    /// concurrently-served connections.
    pub fn bind(addr: &str, coordinator: Arc<Coordinator>, conn_threads: usize) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server {
            listener,
            coordinator,
            stop: Arc::new(AtomicBool::new(false)),
            pool: ThreadPool::new(conn_threads.max(1), "conn"),
        })
    }

    /// The bound address (useful when binding port 0 in tests).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Handle for asking the accept loop to stop.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Run the accept loop until the stop flag is set.  Blocks.
    pub fn serve(&self) -> Result<()> {
        crate::info!("server", "listening on {}", self.listener.local_addr()?);
        self.listener.set_nonblocking(true)?;
        let conns = metrics::global().counter("server.connections");
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    conns.inc();
                    crate::debug!("server", "connection from {peer}");
                    let coord = self.coordinator.clone();
                    let stop = self.stop.clone();
                    self.pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &coord, &stop) {
                            crate::debug!("server", "connection ended: {e:#}");
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let requests = metrics::global().counter("server.requests");
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        requests.inc();
        let response = dispatch(&line, coord);
        writer.write_all(response.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

fn dispatch(line: &str, coord: &Coordinator) -> String {
    match wire::decode_request(line) {
        Err(e) => wire::encode_error(&format!("{e:#}")),
        Ok(Op::Ping) => wire::encode_object(crate::json::Value::object()),
        Ok(Op::Stats) => {
            let mut v = crate::json::Value::object();
            v.set("metrics", metrics::global().snapshot_json());
            v.set(
                "sessions",
                crate::json::Value::Number(coord.executor().session_count() as f64),
            );
            wire::encode_object(v)
        }
        Ok(Op::OpenSession) => {
            let id = coord.open_session();
            let mut v = crate::json::Value::object();
            v.set("session", crate::json::Value::Number(id as f64));
            wire::encode_object(v)
        }
        Ok(Op::ForkSession(src)) => match coord.fork_session(src) {
            Ok(id) => {
                let mut v = crate::json::Value::object();
                v.set("session", crate::json::Value::Number(id as f64));
                wire::encode_object(v)
            }
            Err(e) => wire::encode_error(&format!("{e:#}")),
        },
        Ok(Op::CloseSession(id)) => {
            coord.close_session(id);
            wire::encode_object(crate::json::Value::object())
        }
        Ok(Op::Request(payload)) => match coord.call(payload, REQUEST_TIMEOUT) {
            Ok(reply) => wire::encode_reply(&reply),
            Err(e) => wire::encode_error(&e),
        },
    }
}
