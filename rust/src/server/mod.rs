//! TCP serving frontend: newline-delimited JSON over a thread-per-
//! connection listener, dispatching into the [`Coordinator`].
//!
//! * [`wire`] — the versioned protocol codec (v1 + v2 schemas; see its
//!   docs and `docs/PROTOCOL.md`).
//! * [`Server`] — listener lifecycle (bind, accept loop with bounded
//!   idle backoff, graceful stop).
//! * [`client::Client`] — blocking v2 client with a streaming
//!   generation iterator, used by the examples, the load-generator,
//!   and the integration tests.
//!
//! Connection handling is frame-bounded: a request line larger than
//! [`MAX_FRAME_BYTES`] is answered with a structured `bad_request`
//! error and discarded without buffering it, and the connection stays
//! usable.  `generate` requests stream multi-frame responses from the
//! connection thread, which drives the coordinator's server-side
//! generation loop (see [`crate::coordinator::generate`]).

// xtask:atomics-allowlist: Relaxed
// Relaxed: `stop` is a level-triggered shutdown flag polled in accept /
// stream loops; observing it one iteration late is fine, and no data is
// published through the flag itself (teardown joins the threads).

pub mod client;
pub mod wire;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{Coordinator, Payload, RequestOptions, ServeError};
use crate::json::Value;
use crate::metrics;
use crate::server::wire::{Frame, Op};

/// Hard bound on a single request frame.  Large enough for the biggest
/// legitimate payload (a full-vocabulary logits row serializes to a
/// few MB), small enough that a hostile or buggy client cannot balloon
/// a connection thread's memory.
pub const MAX_FRAME_BYTES: usize = 8 * 1024 * 1024;

/// The TCP server.
pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    pool: crate::exec::ThreadPool,
    /// Default request-handling budget (config `request_timeout`);
    /// per-request deadlines tighten it.
    request_timeout: Duration,
}

impl Server {
    /// Bind to `addr` (e.g. `127.0.0.1:7070`).  `conn_threads` bounds
    /// concurrently-served connections.  The request timeout comes
    /// from the coordinator's config.
    pub fn bind(addr: &str, coordinator: Arc<Coordinator>, conn_threads: usize) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let request_timeout = coordinator.request_timeout();
        Ok(Server {
            listener,
            coordinator,
            stop: Arc::new(AtomicBool::new(false)),
            pool: crate::exec::ThreadPool::new(conn_threads.max(1), "conn"),
            request_timeout,
        })
    }

    /// The bound address (useful when binding port 0 in tests).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Handle for asking the accept loop to stop.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Run the accept loop until the stop flag is set.  Blocks.
    pub fn serve(&self) -> Result<()> {
        crate::info!("server", "listening on {}", self.listener.local_addr()?);
        self.listener.set_nonblocking(true)?;
        let conns = metrics::global().counter("server.connections");
        let idle_polls = metrics::global().counter("server.accept.idle_polls");
        // Bounded exponential backoff for the idle accept poll: 1 ms
        // after the first empty poll, doubling to a 50 ms ceiling,
        // reset by any accepted connection.  The counter makes the
        // listener's idle cost observable instead of a silent 5 ms
        // busy loop.
        const IDLE_MIN: Duration = Duration::from_millis(1);
        const IDLE_MAX: Duration = Duration::from_millis(50);
        let mut idle_wait = IDLE_MIN;
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    idle_wait = IDLE_MIN;
                    conns.inc();
                    crate::debug!("server", "connection from {peer}");
                    let coord = self.coordinator.clone();
                    let stop = self.stop.clone();
                    let timeout = self.request_timeout;
                    self.pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &coord, &stop, timeout) {
                            crate::debug!("server", "connection ended: {e:#}");
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    idle_polls.inc();
                    std::thread::sleep(idle_wait);
                    idle_wait = (idle_wait * 2).min(IDLE_MAX);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

fn write_line(writer: &mut TcpStream, line: &str) -> std::io::Result<()> {
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

fn handle_connection(
    stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
    request_timeout: Duration,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let requests = metrics::global().counter("server.requests");
    let oversized = metrics::global().counter("server.frames.oversized");
    // Partial-frame accumulator: frames may arrive in pieces across
    // read timeouts, and one buffered chunk may hold several frames.
    let mut acc: Vec<u8> = Vec::new();
    // When a frame overflows MAX_FRAME_BYTES we stop buffering and
    // skip bytes until its terminating newline.
    let mut discarding = false;
    // Per-connection stream ids for multi-frame responses.
    let mut streams: u64 = 0;
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let mut eof = false;
        let (consumed, complete) = match reader.fill_buf() {
            Ok(c) if c.is_empty() => {
                // EOF.  A final newline-less frame still gets served
                // (the legacy read_line loop did), then the
                // connection closes.
                eof = true;
                (0, true)
            }
            Ok(c) => match c.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if !discarding {
                        acc.extend_from_slice(&c[..pos]);
                    }
                    (pos + 1, true)
                }
                None => {
                    if !discarding {
                        acc.extend_from_slice(c);
                    }
                    (c.len(), false)
                }
            },
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        };
        reader.consume(consumed);
        if complete {
            let was_discarding = std::mem::replace(&mut discarding, false);
            if was_discarding {
                acc.clear(); // tail of an oversized frame: already answered
            } else if acc.len() > MAX_FRAME_BYTES {
                oversized.inc();
                acc.clear();
                write_line(&mut writer, &oversized_error())?;
            } else {
                // Borrowed view — Cow stays Borrowed for valid UTF-8,
                // so no second copy of a potentially-8MiB frame.
                let line = String::from_utf8_lossy(&acc);
                if !line.trim().is_empty() {
                    requests.inc();
                    streams += 1;
                    dispatch(&line, coord, &mut writer, request_timeout, streams)?;
                }
                acc.clear();
            }
            if eof {
                return Ok(());
            }
        } else if !discarding && acc.len() > MAX_FRAME_BYTES {
            // Mid-frame overflow: answer now, then skip to the newline.
            oversized.inc();
            discarding = true;
            acc.clear();
            write_line(&mut writer, &oversized_error())?;
        }
    }
}

fn oversized_error() -> String {
    // Like every pre-parse failure, this renders in the v1 error shape
    // (message string + `code` rider): the frame never parsed, so the
    // requester's protocol version is unknown and v1 is the
    // compatibility default (PROTOCOL.md).
    wire::encode_error_v1(&ServeError::bad_request(format!(
        "frame exceeds {MAX_FRAME_BYTES} bytes"
    )))
}

/// Per-request handling budget: the configured timeout, tightened by
/// the request's own deadline when that is sooner.
fn effective_timeout(request_timeout: Duration, options: &RequestOptions) -> Duration {
    options.deadline.map_or(request_timeout, |d| d.min(request_timeout))
}

/// Handle one decoded frame, writing the response frame(s).  Returns
/// `Err` only for connection-level I/O failures.
fn dispatch(
    line: &str,
    coord: &Coordinator,
    writer: &mut TcpStream,
    request_timeout: Duration,
    stream_id: u64,
) -> Result<()> {
    let Frame { v, op, options } = match wire::decode_request(line) {
        Err(e) => {
            write_line(writer, &wire::encode_error_for(e.v, &e.error))?;
            return Ok(());
        }
        Ok(f) => f,
    };
    let ok_object = |fields: Value| -> String {
        if v >= 2 {
            wire::encode_object_v2(fields)
        } else {
            wire::encode_object(fields)
        }
    };
    let response = match op {
        Op::Ping => ok_object(Value::object()),
        Op::Stats => {
            let mut fields = Value::object();
            fields.set("metrics", metrics::global().snapshot_json());
            fields.set(
                "sessions",
                Value::Number(coord.executor().session_count() as f64),
            );
            fields.set("queue_depth", Value::Number(coord.queue_depth() as f64));
            let mut depths = Value::object();
            for (class, depth) in coord.class_depths() {
                depths.set(class.name(), Value::Number(depth as f64));
            }
            fields.set("queue_depths", depths);
            fields.set("active_streams", Value::Number(coord.active_streams() as f64));
            // Per-instance coalescing/cache counters (the process-wide
            // `coordinator.cache.*` metrics aggregate across every
            // coordinator in a test binary; these scope to this one).
            let cache = coord.cache_stats();
            let mut c = Value::object();
            c.set("hits", Value::Number(cache.hits as f64))
                .set("misses", Value::Number(cache.misses as f64))
                .set("coalesced", Value::Number(cache.coalesced as f64))
                .set("entries", Value::Number(cache.entries as f64));
            fields.set("cache", c);
            ok_object(fields)
        }
        Op::OpenSession => {
            let id = coord.open_session();
            let mut fields = Value::object();
            fields.set("session", Value::Number(id as f64));
            ok_object(fields)
        }
        Op::ForkSession(src) => match coord.fork_session(src) {
            Ok(id) => {
                let mut fields = Value::object();
                fields.set("session", Value::Number(id as f64));
                ok_object(fields)
            }
            Err(e) => {
                wire::encode_error_for(v, &ServeError::not_found(format!("{e:#}")))
            }
        },
        Op::CloseSession(id) => {
            coord.close_session(id);
            ok_object(Value::object())
        }
        Op::ShardScan(scan) => {
            // Worker-role fast path: shard scans bypass the batcher —
            // the router already batched rows into the frame, and the
            // per-request queueing machinery would only add latency
            // between the tiers.
            match coord.executor().shard_scan(&scan) {
                Ok(reply) => ok_object(wire::shard_scan_reply_fields(&reply)),
                Err(e) => wire::encode_error_for(v, &e),
            }
        }
        Op::Request(Payload::Generate { session, prompt_tokens, max_tokens }) => {
            return run_generate(
                coord,
                writer,
                stream_id,
                session,
                &prompt_tokens,
                max_tokens,
                options,
            );
        }
        Op::Request(payload) => {
            let timeout = effective_timeout(request_timeout, &options);
            match coord.call_opts(payload, options, timeout) {
                Ok(reply) => {
                    if v >= 2 {
                        wire::encode_reply_v2(&reply)
                    } else {
                        wire::encode_reply(&reply)
                    }
                }
                Err(e) => wire::encode_error_for(v, &e),
            }
        }
    };
    write_line(writer, &response)?;
    Ok(())
}

/// Drive one server-side generation stream, writing a token frame per
/// decoded token and a terminal frame at the end.
fn run_generate(
    coord: &Coordinator,
    writer: &mut TcpStream,
    stream_id: u64,
    session: u64,
    prompt_tokens: &[i32],
    max_tokens: usize,
    options: RequestOptions,
) -> Result<()> {
    let mut io_failed = false;
    let result = coord.generate(session, prompt_tokens, max_tokens, &options, |frame| {
        match write_line(writer, &wire::encode_stream_token(stream_id, frame)) {
            Ok(()) => true,
            Err(_) => {
                io_failed = true;
                false // client gone: cancel the stream
            }
        }
    });
    if io_failed {
        return Err(anyhow!("client disconnected mid-stream"));
    }
    let terminal = match result {
        Ok(tokens) => wire::encode_stream_done(stream_id, &tokens),
        Err(e) => wire::encode_stream_failed(stream_id, &e),
    };
    write_line(writer, &terminal)?;
    Ok(())
}
