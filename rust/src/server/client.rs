//! Blocking TCP client for the line-JSON protocol — used by the
//! examples, the load generator, and the end-to-end tests.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::wire;
use crate::json::Value;

/// A connected client (one request in flight at a time).
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(120)))?;
        let writer = stream.try_clone()?;
        Ok(Client { writer, reader: BufReader::new(stream) })
    }

    fn roundtrip(&mut self, line: &str) -> Result<Value> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut response = String::new();
        let n = self.reader.read_line(&mut response)?;
        if n == 0 {
            return Err(anyhow!("server closed connection"));
        }
        wire::decode_response(&response)
    }

    pub fn ping(&mut self) -> Result<()> {
        self.roundtrip(r#"{"op":"ping"}"#).map(|_| ())
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.roundtrip(r#"{"op":"stats"}"#)
    }

    pub fn softmax(&mut self, logits: &[f32]) -> Result<Vec<f32>> {
        let mut v = Value::object();
        v.set("op", Value::String("softmax".into()))
            .set("logits", Value::from_f32_slice(logits));
        let resp = self.roundtrip(&v.to_json())?;
        resp.require("probs")?.to_f32_vec()
    }

    pub fn decode(&mut self, hidden: &[f32], k: Option<usize>) -> Result<(Vec<f32>, Vec<i64>)> {
        let mut v = Value::object();
        v.set("op", Value::String("decode".into()))
            .set("hidden", Value::from_f32_slice(hidden));
        if let Some(k) = k {
            v.set("k", Value::Number(k as f64));
        }
        let resp = self.roundtrip(&v.to_json())?;
        let vals = resp.require("vals")?.to_f32_vec()?;
        let idx =
            resp.require("idx")?.to_i32_vec()?.into_iter().map(|i| i as i64).collect();
        Ok((vals, idx))
    }

    pub fn open_session(&mut self) -> Result<u64> {
        let resp = self.roundtrip(r#"{"op":"open_session"}"#)?;
        resp.require("session")?
            .as_i64()
            .map(|i| i as u64)
            .ok_or_else(|| anyhow!("bad session id"))
    }

    pub fn fork_session(&mut self, src: u64) -> Result<u64> {
        let mut v = Value::object();
        v.set("op", Value::String("fork_session".into()))
            .set("session", Value::Number(src as f64));
        let resp = self.roundtrip(&v.to_json())?;
        resp.require("session")?
            .as_i64()
            .map(|i| i as u64)
            .ok_or_else(|| anyhow!("bad session id"))
    }

    pub fn close_session(&mut self, id: u64) -> Result<()> {
        let mut v = Value::object();
        v.set("op", Value::String("close_session".into()))
            .set("session", Value::Number(id as f64));
        self.roundtrip(&v.to_json()).map(|_| ())
    }

    pub fn lm_step(
        &mut self,
        session: u64,
        token: i32,
        k: Option<usize>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let mut v = Value::object();
        v.set("op", Value::String("lm_step".into()))
            .set("session", Value::Number(session as f64))
            .set("token", Value::Number(token as f64));
        if let Some(k) = k {
            v.set("k", Value::Number(k as f64));
        }
        let resp = self.roundtrip(&v.to_json())?;
        let vals = resp.require("vals")?.to_f32_vec()?;
        let idx =
            resp.require("idx")?.to_i32_vec()?.into_iter().map(|i| i as i64).collect();
        Ok((vals, idx))
    }
}
