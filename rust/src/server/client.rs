//! Blocking TCP client for the line-JSON protocol — used by the
//! examples, the load generator, and the end-to-end tests.
//!
//! The client speaks **protocol v2**: every request carries `"v":2`
//! plus any configured per-request options ([`Client::set_priority`],
//! [`Client::set_deadline_ms`], [`Client::set_tag`],
//! [`Client::set_temperature`], [`Client::set_seed`]), errors decode
//! into their structured `{code, message}` form, and
//! [`Client::generate`] exposes server-side streaming generation as an
//! iterator of [`TokenFrame`]s.  (Servers still accept v1 frames from
//! older clients; see `docs/PROTOCOL.md`.)

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::wire::{self, StreamEvent};
use crate::coordinator::TokenFrame;
use crate::json::Value;

/// A connected client (one request in flight at a time).
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    priority: Option<String>,
    deadline_ms: Option<u64>,
    tag: Option<String>,
    temperature: Option<f32>,
    seed: Option<u64>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(120)))?;
        let writer = stream.try_clone()?;
        Ok(Client {
            writer,
            reader: BufReader::new(stream),
            priority: None,
            deadline_ms: None,
            tag: None,
            temperature: None,
            seed: None,
        })
    }

    /// Priority class sent with every subsequent request
    /// (`"interactive"` or `"batch"`; `None` = server default).
    pub fn set_priority(&mut self, priority: Option<&str>) {
        self.priority = priority.map(|s| s.to_string());
    }

    /// Per-request deadline in milliseconds sent with every subsequent
    /// request (`None` = no deadline).
    pub fn set_deadline_ms(&mut self, deadline_ms: Option<u64>) {
        self.deadline_ms = deadline_ms;
    }

    /// Opaque client tag sent with every subsequent request.
    pub fn set_tag(&mut self, tag: Option<&str>) {
        self.tag = tag.map(|s| s.to_string());
    }

    /// Sampling temperature sent with every subsequent request
    /// (`None` = server default 1.0).  Values other than 1.0 require a
    /// seed ([`Client::set_seed`]) — the server rejects tempered
    /// greedy decode as `invalid_argument`.
    pub fn set_temperature(&mut self, temperature: Option<f32>) {
        self.temperature = temperature;
    }

    /// Sampling seed sent with every subsequent request.  `Some`
    /// switches decode/lm_step/generate from greedy top-k to seeded
    /// Gumbel-top-k sampling; `None` (the default) is greedy.
    pub fn set_seed(&mut self, seed: Option<u64>) {
        self.seed = seed;
    }

    /// A v2 request skeleton for `op`, carrying the configured options.
    fn request(&self, op: &str) -> Value {
        let mut v = Value::object();
        v.set("v", Value::Number(wire::PROTOCOL_VERSION as f64))
            .set("op", Value::String(op.to_string()));
        if let Some(ms) = self.deadline_ms {
            v.set("deadline_ms", Value::Number(ms as f64));
        }
        if let Some(p) = &self.priority {
            v.set("priority", Value::String(p.clone()));
        }
        if let Some(t) = &self.tag {
            v.set("tag", Value::String(t.clone()));
        }
        if let Some(t) = self.temperature {
            v.set("temperature", Value::Number(t as f64));
        }
        if let Some(s) = self.seed {
            v.set("seed", Value::Number(s as f64));
        }
        v
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    fn read_line(&mut self) -> Result<String> {
        let mut response = String::new();
        let n = self.reader.read_line(&mut response)?;
        if n == 0 {
            return Err(anyhow!("server closed connection"));
        }
        Ok(response)
    }

    fn roundtrip(&mut self, line: &str) -> Result<Value> {
        self.send_line(line)?;
        let response = self.read_line()?;
        wire::decode_response(&response)
    }

    pub fn ping(&mut self) -> Result<()> {
        let line = self.request("ping").to_json();
        self.roundtrip(&line).map(|_| ())
    }

    pub fn stats(&mut self) -> Result<Value> {
        let line = self.request("stats").to_json();
        self.roundtrip(&line)
    }

    pub fn softmax(&mut self, logits: &[f32]) -> Result<Vec<f32>> {
        let mut v = self.request("softmax");
        v.set("logits", Value::from_f32_slice(logits));
        let resp = self.roundtrip(&v.to_json())?;
        resp.require("probs")?.to_f32_vec()
    }

    pub fn decode(&mut self, hidden: &[f32], k: Option<usize>) -> Result<(Vec<f32>, Vec<i64>)> {
        let mut v = self.request("decode");
        v.set("hidden", Value::from_f32_slice(hidden));
        if let Some(k) = k {
            v.set("k", Value::Number(k as f64));
        }
        let resp = self.roundtrip(&v.to_json())?;
        let vals = resp.require("vals")?.to_f32_vec()?;
        let idx =
            resp.require("idx")?.to_i32_vec()?.into_iter().map(|i| i as i64).collect();
        Ok((vals, idx))
    }

    pub fn open_session(&mut self) -> Result<u64> {
        let line = self.request("open_session").to_json();
        let resp = self.roundtrip(&line)?;
        resp.require("session")?
            .as_i64()
            .map(|i| i as u64)
            .ok_or_else(|| anyhow!("bad session id"))
    }

    pub fn fork_session(&mut self, src: u64) -> Result<u64> {
        let mut v = self.request("fork_session");
        v.set("session", Value::Number(src as f64));
        let resp = self.roundtrip(&v.to_json())?;
        resp.require("session")?
            .as_i64()
            .map(|i| i as u64)
            .ok_or_else(|| anyhow!("bad session id"))
    }

    pub fn close_session(&mut self, id: u64) -> Result<()> {
        let mut v = self.request("close_session");
        v.set("session", Value::Number(id as f64));
        self.roundtrip(&v.to_json()).map(|_| ())
    }

    pub fn lm_step(
        &mut self,
        session: u64,
        token: i32,
        k: Option<usize>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let mut v = self.request("lm_step");
        v.set("session", Value::Number(session as f64))
            .set("token", Value::Number(token as f64));
        if let Some(k) = k {
            v.set("k", Value::Number(k as f64));
        }
        let resp = self.roundtrip(&v.to_json())?;
        let vals = resp.require("vals")?.to_f32_vec()?;
        let idx =
            resp.require("idx")?.to_i32_vec()?.into_iter().map(|i| i as i64).collect();
        Ok((vals, idx))
    }

    /// Start a server-side streaming generation: feed `prompt` into
    /// `session`, then decode up to `max_tokens` tokens.  Returns an
    /// iterator yielding one [`TokenFrame`] per decoded token; the
    /// iterator ends cleanly after the terminal frame, after which
    /// [`Generation::tokens`] holds the full selected sequence.
    ///
    /// The whole stream costs one request frame on the wire — the
    /// decode loop runs server-side, batching across concurrent
    /// streams.
    pub fn generate(
        &mut self,
        session: u64,
        prompt: &[i32],
        max_tokens: usize,
        k: Option<usize>,
    ) -> Result<Generation<'_>> {
        let mut v = self.request("generate");
        v.set("session", Value::Number(session as f64))
            .set("prompt", Value::from_i32_slice(prompt))
            .set("max_tokens", Value::Number(max_tokens as f64));
        if let Some(k) = k {
            v.set("k", Value::Number(k as f64));
        }
        self.send_line(&v.to_json())?;
        Ok(Generation { client: self, finished: false, tokens: Vec::new() })
    }

    /// Convenience wrapper over [`Client::generate`]: collect every
    /// token frame of the stream.
    pub fn generate_all(
        &mut self,
        session: u64,
        prompt: &[i32],
        max_tokens: usize,
        k: Option<usize>,
    ) -> Result<Vec<TokenFrame>> {
        let mut frames = Vec::new();
        let stream = self.generate(session, prompt, max_tokens, k)?;
        for frame in stream {
            frames.push(frame?);
        }
        Ok(frames)
    }
}

/// A live generation stream (see [`Client::generate`]).  Dropping it
/// mid-stream drains the remaining frames (bounded by the server-side
/// `MAX_STREAM_TOKENS` cap) so the connection stays usable for the
/// next request.
pub struct Generation<'c> {
    client: &'c mut Client,
    finished: bool,
    tokens: Vec<i32>,
}

impl Generation<'_> {
    /// Selected tokens seen so far; after clean iterator exhaustion
    /// this is the server's authoritative full-sequence list from the
    /// terminal frame.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    fn read_event(&mut self) -> Result<StreamEvent> {
        let line = self.client.read_line()?;
        wire::decode_stream_event(&line)
    }
}

impl Iterator for Generation<'_> {
    type Item = Result<TokenFrame>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.finished {
            return None;
        }
        match self.read_event() {
            Ok(StreamEvent::Token(frame)) => {
                self.tokens.push(frame.token);
                Some(Ok(frame))
            }
            Ok(StreamEvent::Done { tokens }) => {
                self.finished = true;
                self.tokens = tokens;
                None
            }
            Err(e) => {
                self.finished = true;
                Some(Err(e))
            }
        }
    }
}

impl Drop for Generation<'_> {
    fn drop(&mut self) {
        // Abandoned mid-stream: the server keeps writing until its
        // terminal frame, so drain to it — otherwise the leftover
        // frames would desync every later request on this connection.
        // Bounded by the server-side MAX_STREAM_TOKENS cap; any read
        // error ends the drain (the connection is broken anyway).
        while !self.finished {
            match self.read_event() {
                Ok(StreamEvent::Token(_)) => {}
                Ok(StreamEvent::Done { tokens }) => {
                    self.tokens = tokens;
                    self.finished = true;
                }
                Err(_) => self.finished = true,
            }
        }
    }
}
