//! Wire protocol: newline-delimited JSON over TCP.
//!
//! Request (one per line):
//! ```json
//! {"op": "softmax",  "logits": [..]}
//! {"op": "decode",   "hidden": [..], "k": 5}
//! {"op": "open_session"}
//! {"op": "fork_session", "session": 1}
//! {"op": "lm_step",  "session": 1, "token": 42, "k": 5}
//! {"op": "close_session", "session": 1}
//! {"op": "stats"}
//! {"op": "ping"}
//! ```
//!
//! Response (one per line): `{"ok": true, ...}` or
//! `{"ok": false, "error": "..."}`.

use anyhow::{anyhow, Result};

use crate::coordinator::{Payload, Reply};
use crate::json::{self, Value};

/// Parsed client operation.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Request(Payload),
    OpenSession,
    ForkSession(u64),
    CloseSession(u64),
    Stats,
    Ping,
}

/// Decode one request line.
pub fn decode_request(line: &str) -> Result<Op> {
    let v = json::parse(line.trim()).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = v
        .require("op")?
        .as_str()
        .ok_or_else(|| anyhow!("`op` must be a string"))?;
    match op {
        "softmax" => Ok(Op::Request(Payload::Softmax {
            logits: v.require("logits")?.to_f32_vec()?,
        })),
        "decode" => Ok(Op::Request(Payload::DecodeTopK {
            hidden: v.require("hidden")?.to_f32_vec()?,
            k: v.get("k").and_then(Value::as_usize),
        })),
        "lm_step" => Ok(Op::Request(Payload::LmStep {
            session: v
                .require("session")?
                .as_i64()
                .ok_or_else(|| anyhow!("`session` must be an integer"))? as u64,
            token: v
                .require("token")?
                .as_i64()
                .ok_or_else(|| anyhow!("`token` must be an integer"))? as i32,
            k: v.get("k").and_then(Value::as_usize),
        })),
        "open_session" => Ok(Op::OpenSession),
        "fork_session" => Ok(Op::ForkSession(
            v.require("session")?
                .as_i64()
                .ok_or_else(|| anyhow!("`session` must be an integer"))? as u64,
        )),
        "close_session" => Ok(Op::CloseSession(
            v.require("session")?
                .as_i64()
                .ok_or_else(|| anyhow!("`session` must be an integer"))? as u64,
        )),
        "stats" => Ok(Op::Stats),
        "ping" => Ok(Op::Ping),
        other => Err(anyhow!("unknown op `{other}`")),
    }
}

/// Encode a successful reply.
pub fn encode_reply(reply: &Reply) -> String {
    let mut v = Value::object();
    v.set("ok", Value::Bool(true));
    match reply {
        Reply::Softmax { probs } => {
            v.set("probs", Value::from_f32_slice(probs));
        }
        Reply::TopK { vals, idx } => {
            v.set("vals", Value::from_f32_slice(vals));
            v.set(
                "idx",
                Value::Array(idx.iter().map(|&i| Value::Number(i as f64)).collect()),
            );
        }
    }
    v.to_json()
}

/// Encode an error reply.
pub fn encode_error(msg: &str) -> String {
    let mut v = Value::object();
    v.set("ok", Value::Bool(false)).set("error", Value::String(msg.to_string()));
    v.to_json()
}

/// Encode a bare-object success (open_session, stats, ping).
pub fn encode_object(mut fields: Value) -> String {
    fields.set("ok", Value::Bool(true));
    fields.to_json()
}

/// Decode a response line on the client side.
pub fn decode_response(line: &str) -> Result<Value> {
    let v = json::parse(line.trim()).map_err(|e| anyhow!("bad response json: {e}"))?;
    match v.get("ok").and_then(Value::as_bool) {
        Some(true) => Ok(v),
        Some(false) => Err(anyhow!(
            "server error: {}",
            v.get("error").and_then(Value::as_str).unwrap_or("unknown")
        )),
        None => Err(anyhow!("response missing `ok` field")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_all_ops() {
        assert_eq!(
            decode_request(r#"{"op":"softmax","logits":[1,2]}"#).unwrap(),
            Op::Request(Payload::Softmax { logits: vec![1.0, 2.0] })
        );
        assert_eq!(
            decode_request(r#"{"op":"decode","hidden":[0.5],"k":3}"#).unwrap(),
            Op::Request(Payload::DecodeTopK { hidden: vec![0.5], k: Some(3) })
        );
        assert_eq!(
            decode_request(r#"{"op":"lm_step","session":7,"token":9}"#).unwrap(),
            Op::Request(Payload::LmStep { session: 7, token: 9, k: None })
        );
        assert_eq!(decode_request(r#"{"op":"open_session"}"#).unwrap(), Op::OpenSession);
        assert_eq!(
            decode_request(r#"{"op":"fork_session","session":2}"#).unwrap(),
            Op::ForkSession(2)
        );
        assert_eq!(
            decode_request(r#"{"op":"close_session","session":3}"#).unwrap(),
            Op::CloseSession(3)
        );
        assert_eq!(decode_request(r#"{"op":"ping"}"#).unwrap(), Op::Ping);
        assert_eq!(decode_request(r#"{"op":"stats"}"#).unwrap(), Op::Stats);
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"op":"bogus"}"#).is_err());
        assert!(decode_request(r#"{"op":"decode"}"#).is_err(), "missing hidden");
        assert!(decode_request(r#"{"op":"lm_step","session":"x","token":1}"#).is_err());
    }

    #[test]
    fn reply_roundtrip() {
        let line = encode_reply(&Reply::TopK { vals: vec![0.5, 0.25], idx: vec![7, 3] });
        let v = decode_response(&line).unwrap();
        assert_eq!(v.get("vals").unwrap().to_f32_vec().unwrap(), vec![0.5, 0.25]);
        assert_eq!(v.get("idx").unwrap().to_i32_vec().unwrap(), vec![7, 3]);

        let line = encode_reply(&Reply::Softmax { probs: vec![1.0] });
        let v = decode_response(&line).unwrap();
        assert_eq!(v.get("probs").unwrap().to_f32_vec().unwrap(), vec![1.0]);
    }

    #[test]
    fn error_roundtrip() {
        let line = encode_error("boom");
        let err = decode_response(&line).unwrap_err();
        assert!(format!("{err}").contains("boom"));
    }
}
