//! Wire protocol: newline-delimited JSON over TCP, versioned.
//!
//! Two request generations share the socket (full schema, framing
//! rules, and compat table: `docs/PROTOCOL.md`):
//!
//! **v1** (no `"v"` field, or `"v":1`) — the legacy surface, still
//! decoded and served unchanged:
//! ```json
//! {"op": "softmax",  "logits": [..]}
//! {"op": "decode",   "hidden": [..], "k": 5}
//! {"op": "lm_step",  "session": 1, "token": 42, "k": 5}
//! {"op": "open_session"} {"op": "fork_session", "session": 1}
//! {"op": "close_session", "session": 1} {"op": "stats"} {"op": "ping"}
//! ```
//! v1 responses: `{"ok": true, ...}` or
//! `{"ok": false, "error": "<message>", "code": "<code>"}` (the `code`
//! rides along for v2-aware tooling; v1 clients read `error`).
//!
//! **v2** (`"v": 2`) — the typed surface: every request may carry
//! [`RequestOptions`] fields (`k`, `temperature`, `seed`, `priority`,
//! `deadline_ms`, `tag`), responses echo `"v":2`, errors are
//! structured objects, and the streaming op exists:
//! ```json
//! {"v":2, "op":"generate", "session":1, "prompt":[3,9], "max_tokens":8, "k":5}
//! ```
//! A `generate` answer is **multi-frame**: one token frame per decoded
//! token, then a terminal frame —
//! ```json
//! {"v":2, "stream":1, "index":0, "token":1744, "vals":[..], "idx":[..]}
//! {"v":2, "stream":1, "done":true, "tokens":[1744, ..]}
//! ```
//! (on failure the terminal frame carries `"error": {"code", "message"}`
//! instead of `"tokens"`).  Single-frame v2 errors look like
//! `{"v":2, "ok":false, "error":{"code":"...", "message":"..."}}`.
//!
//! The decoder never panics: every malformed, truncated, wrong-version
//! or type-confused frame decodes to a [`DecodeError`] carrying a
//! typed [`ServeError`] (fuzzed by `rust/tests/wire_fuzz.rs`).
//! Oversized frames are bounded by the server's read loop
//! ([`super::MAX_FRAME_BYTES`]).

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    ErrorCode, Payload, Priority, Reply, RequestOptions, ServeError, ShardScan,
    ShardScanKind, ShardScanReply, TokenFrame,
};
use crate::json::{self, Value};
use crate::sample::SampleSpec;
use crate::shard::{reduce, ShardPartial};
use crate::softmax::monoid::MD;

/// The current protocol version.
pub const PROTOCOL_VERSION: u64 = 2;

/// Parsed client operation.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Request(Payload),
    /// A router-tier fan-out scan over one vocabulary slice (v2 only;
    /// see `docs/PROTOCOL.md` §shard_scan).
    ShardScan(ShardScan),
    OpenSession,
    ForkSession(u64),
    CloseSession(u64),
    Stats,
    Ping,
}

/// One decoded request frame: protocol version, operation, and the
/// per-request options that ride on it.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub v: u64,
    pub op: Op,
    pub options: RequestOptions,
}

/// A decode failure, remembering which protocol version the error
/// response should be rendered in.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeError {
    pub v: u64,
    pub error: ServeError,
}

// ---------------------------------------------------------------------------
// request decoding
// ---------------------------------------------------------------------------

/// Decode one request line (either protocol version).
pub fn decode_request(line: &str) -> Result<Frame, DecodeError> {
    let doc = match json::parse(line.trim()) {
        Ok(d) => d,
        Err(e) => {
            return Err(DecodeError {
                v: 1,
                error: ServeError::bad_request(format!("bad json: {e}")),
            })
        }
    };
    let version = match doc.get("v") {
        None => 1,
        Some(val) => match val.as_i64() {
            Some(1) => 1,
            Some(2) => 2,
            Some(other) => {
                return Err(DecodeError {
                    v: PROTOCOL_VERSION,
                    error: ServeError::bad_request(format!(
                        "unsupported protocol version {other} (supported: 1, 2)"
                    )),
                })
            }
            None => {
                return Err(DecodeError {
                    v: 1,
                    error: ServeError::bad_request("`v` must be an integer"),
                })
            }
        },
    };
    decode_frame(&doc, version).map_err(|error| DecodeError { v: version, error })
}

fn decode_frame(doc: &Value, version: u64) -> Result<Frame, ServeError> {
    let op_name = doc
        .get("op")
        .and_then(Value::as_str)
        .ok_or_else(|| ServeError::bad_request("missing `op` (must be a string)"))?;
    let options =
        if version >= 2 { decode_options(doc)? } else { decode_options_v1(doc) };
    let op = match op_name {
        "softmax" => Op::Request(Payload::Softmax { logits: f32_field(doc, "logits")? }),
        "decode" => Op::Request(Payload::DecodeTopK { hidden: f32_field(doc, "hidden")? }),
        "lm_step" => Op::Request(Payload::LmStep {
            session: u64_field(doc, "session")?,
            token: i32_field(doc, "token")?,
        }),
        "generate" => {
            if version < 2 {
                return Err(ServeError::bad_request(
                    "`generate` requires protocol v2 (send \"v\":2)",
                ));
            }
            Op::Request(Payload::Generate {
                session: u64_field(doc, "session")?,
                prompt_tokens: i32_vec_field(doc, "prompt")?,
                max_tokens: usize_field(doc, "max_tokens")?,
            })
        }
        "shard_scan" => {
            if version < 2 {
                return Err(ServeError::bad_request(
                    "`shard_scan` requires protocol v2 (send \"v\":2)",
                ));
            }
            Op::ShardScan(decode_shard_scan(doc)?)
        }
        "open_session" => Op::OpenSession,
        "fork_session" => Op::ForkSession(u64_field(doc, "session")?),
        "close_session" => Op::CloseSession(u64_field(doc, "session")?),
        "stats" => Op::Stats,
        "ping" => Op::Ping,
        other => return Err(ServeError::bad_request(format!("unknown op `{other}`"))),
    };
    Ok(Frame { v: version, op, options })
}

/// Per-request options of a v2 frame.  Unlike v1, every option is
/// validated strictly — an ill-typed value is a `bad_request`.
fn decode_options(doc: &Value) -> Result<RequestOptions, ServeError> {
    let mut o = RequestOptions::default();
    if let Some(k) = doc.get("k") {
        o.k = Some(k.as_usize().ok_or_else(|| {
            ServeError::bad_request("`k` must be a non-negative integer")
        })?);
    }
    if let Some(t) = doc.get("temperature") {
        let t = t
            .as_f64()
            .ok_or_else(|| ServeError::bad_request("`temperature` must be a number"))?;
        // Range validation (finite, > 0) happens once, here at the
        // surface; the executor re-checks the same rule for in-process
        // callers.  Pairing rules (non-neutral temperature requires a
        // seed, host backend only) stay executor-side where the
        // backend is known.
        if !(t.is_finite() && t > 0.0) {
            return Err(ServeError::invalid(format!(
                "temperature {t} must be a finite value > 0"
            )));
        }
        o.temperature = t as f32;
    }
    if let Some(s) = doc.get("seed") {
        let seed = s.as_i64().filter(|n| *n >= 0).ok_or_else(|| {
            ServeError::bad_request("`seed` must be a non-negative integer")
        })?;
        o.seed = Some(seed as u64);
    }
    if let Some(p) = doc.get("priority") {
        let s = p
            .as_str()
            .ok_or_else(|| ServeError::bad_request("`priority` must be a string"))?;
        o.priority = Priority::parse(s).ok_or_else(|| {
            ServeError::bad_request(format!("unknown priority `{s}` (interactive|batch)"))
        })?;
    }
    if let Some(d) = doc.get("deadline_ms") {
        let ms = d.as_usize().ok_or_else(|| {
            ServeError::bad_request("`deadline_ms` must be a non-negative integer")
        })?;
        o.deadline = Some(Duration::from_millis(ms as u64));
    }
    if let Some(t) = doc.get("tag") {
        let s = t
            .as_str()
            .ok_or_else(|| ServeError::bad_request("`tag` must be a string"))?;
        o.client_tag = Some(s.to_string());
    }
    Ok(o)
}

/// v1 frames only carry `k`, and parse it **leniently**: an ill-typed
/// `k` falls back to the server default exactly like the legacy
/// decoder (`get("k").and_then(as_usize)`) — the v1 surface is frozen,
/// including its tolerances.
fn decode_options_v1(doc: &Value) -> RequestOptions {
    RequestOptions { k: doc.get("k").and_then(Value::as_usize), ..RequestOptions::default() }
}

fn missing(key: &str) -> ServeError {
    ServeError::bad_request(format!("missing required field `{key}`"))
}

fn f32_field(doc: &Value, key: &str) -> Result<Vec<f32>, ServeError> {
    doc.get(key)
        .ok_or_else(|| missing(key))?
        .to_f32_vec()
        .map_err(|e| ServeError::bad_request(format!("`{key}`: {e}")))
}

fn i32_vec_field(doc: &Value, key: &str) -> Result<Vec<i32>, ServeError> {
    doc.get(key)
        .ok_or_else(|| missing(key))?
        .to_i32_vec()
        .map_err(|e| ServeError::bad_request(format!("`{key}`: {e}")))
}

fn u64_field(doc: &Value, key: &str) -> Result<u64, ServeError> {
    doc.get(key)
        .ok_or_else(|| missing(key))?
        .as_i64()
        .filter(|n| *n >= 0)
        .map(|n| n as u64)
        .ok_or_else(|| {
            ServeError::bad_request(format!("`{key}` must be a non-negative integer"))
        })
}

fn i32_field(doc: &Value, key: &str) -> Result<i32, ServeError> {
    doc.get(key)
        .ok_or_else(|| missing(key))?
        .as_i64()
        .and_then(|n| i32::try_from(n).ok())
        .ok_or_else(|| ServeError::bad_request(format!("`{key}` must be an i32 integer")))
}

fn usize_field(doc: &Value, key: &str) -> Result<usize, ServeError> {
    doc.get(key).ok_or_else(|| missing(key))?.as_usize().ok_or_else(|| {
        ServeError::bad_request(format!("`{key}` must be a non-negative integer"))
    })
}

// ---------------------------------------------------------------------------
// shard_scan frames (router ↔ worker, v2 only)
// ---------------------------------------------------------------------------

/// Encode a sampling spec for a `shard_scan` frame.  The seed travels
/// as a decimal **string**: JSON numbers are f64 and a derived step
/// seed uses all 64 bits, so a numeric encoding would corrupt seeds
/// ≥ 2^53.
fn sample_spec_value(spec: SampleSpec) -> Value {
    let mut v = Value::object();
    v.set("seed", Value::String(spec.seed.to_string()))
        .set("temperature", Value::Number(spec.temperature as f64));
    v
}

fn decode_sample_spec(v: &Value) -> Result<SampleSpec, ServeError> {
    let seed = match v.get("seed") {
        Some(Value::String(s)) => s.parse::<u64>().map_err(|_| {
            ServeError::bad_request("`seed` string must be a decimal u64")
        })?,
        Some(n) => n.as_i64().filter(|s| *s >= 0).ok_or_else(|| {
            ServeError::bad_request("`seed` must be a non-negative integer or decimal string")
        })? as u64,
        None => return Err(missing("seed")),
    };
    let t = v
        .get("temperature")
        .ok_or_else(|| missing("temperature"))?
        .as_f64()
        .ok_or_else(|| ServeError::bad_request("`temperature` must be a number"))?;
    if !(t.is_finite() && t > 0.0) {
        return Err(ServeError::invalid(format!(
            "temperature {t} must be a finite value > 0"
        )));
    }
    Ok(SampleSpec { seed, temperature: t as f32 })
}

/// Encode a `shard_scan` request frame (the router's fan-out side).
pub fn encode_shard_scan(scan: &ShardScan) -> String {
    let mut v = Value::object();
    v.set("v", Value::Number(PROTOCOL_VERSION as f64))
        .set("op", Value::String("shard_scan".to_string()))
        .set("kind", Value::String(scan.kind.as_str().to_string()))
        .set("start", Value::Number(scan.start as f64))
        .set("end", Value::Number(scan.end as f64))
        .set(
            "rows",
            Value::Array(scan.rows.iter().map(|r| Value::from_f32_slice(r)).collect()),
        );
    match scan.kind {
        ShardScanKind::Decode => {
            v.set("k", Value::Number(scan.k as f64));
            if scan.samples.iter().any(Option::is_some) {
                v.set(
                    "samples",
                    Value::Array(
                        scan.samples
                            .iter()
                            .map(|s| s.map_or(Value::Null, sample_spec_value))
                            .collect(),
                    ),
                );
            }
        }
        ShardScanKind::Softmax => {}
        ShardScanKind::Scale => {
            v.set(
                "norms",
                Value::Array(scan.norms.iter().map(|&md| reduce::md_to_wire(md)).collect()),
            );
        }
    }
    v.to_json()
}

/// Decode a `shard_scan` request (worker side).  Structural validation
/// only — the executor still checks the range against its own vocab,
/// row widths, and `k` bounds (those depend on serving config).
fn decode_shard_scan(doc: &Value) -> Result<ShardScan, ServeError> {
    let kind_str = doc
        .get("kind")
        .and_then(Value::as_str)
        .ok_or_else(|| ServeError::bad_request("missing `kind` (must be a string)"))?;
    let kind = ShardScanKind::parse(kind_str).ok_or_else(|| {
        ServeError::bad_request(format!("unknown shard_scan kind `{kind_str}` (decode|softmax|scale)"))
    })?;
    let start = usize_field(doc, "start")?;
    let end = usize_field(doc, "end")?;
    if start >= end {
        return Err(ServeError::bad_request(format!(
            "empty shard range {start}:{end} (start must be < end)"
        )));
    }
    let rows = doc
        .get("rows")
        .ok_or_else(|| missing("rows"))?
        .to_f32_matrix()
        .map_err(|e| ServeError::bad_request(format!("`rows`: {e}")))?;
    if rows.is_empty() {
        return Err(ServeError::bad_request("`rows` must not be empty"));
    }
    let mut scan = ShardScan {
        kind,
        start,
        end,
        k: 0,
        rows,
        samples: Vec::new(),
        norms: Vec::new(),
    };
    match kind {
        ShardScanKind::Decode => {
            scan.k = usize_field(doc, "k")?;
            if scan.k == 0 {
                return Err(ServeError::bad_request("`k` must be ≥ 1"));
            }
            scan.samples = match doc.get("samples") {
                None => vec![None; scan.rows.len()],
                Some(v) => {
                    let arr = v.as_array().ok_or_else(|| {
                        ServeError::bad_request("`samples` must be an array")
                    })?;
                    if arr.len() != scan.rows.len() {
                        return Err(ServeError::bad_request(
                            "`samples` must align with `rows`",
                        ));
                    }
                    arr.iter()
                        .map(|s| match s {
                            Value::Null => Ok(None),
                            v => decode_sample_spec(v).map(Some),
                        })
                        .collect::<Result<_, _>>()?
                }
            };
        }
        ShardScanKind::Softmax => {}
        ShardScanKind::Scale => {
            let arr = doc
                .get("norms")
                .ok_or_else(|| missing("norms"))?
                .as_array()
                .ok_or_else(|| ServeError::bad_request("`norms` must be an array"))?;
            if arr.len() != scan.rows.len() {
                return Err(ServeError::bad_request("`norms` must align with `rows`"));
            }
            scan.norms = arr
                .iter()
                .map(|v| {
                    reduce::md_from_wire(v)
                        .map_err(|e| ServeError::bad_request(format!("`norms`: {e}")))
                })
                .collect::<Result<_, _>>()?;
        }
    }
    Ok(scan)
}

/// Encode a worker's `shard_scan` reply payload (merged into the v2
/// success envelope by the server loop).
pub fn shard_scan_reply_fields(reply: &ShardScanReply) -> Value {
    let mut v = Value::object();
    match reply {
        ShardScanReply::Partials(parts) => {
            v.set("partials", Value::Array(parts.iter().map(ShardPartial::to_wire).collect()));
        }
        ShardScanReply::Norms(norms) => {
            v.set("norms", Value::Array(norms.iter().map(|&md| reduce::md_to_wire(md)).collect()));
        }
        ShardScanReply::Slices(slices) => {
            v.set(
                "slices",
                Value::Array(slices.iter().map(|r| Value::from_f32_slice(r)).collect()),
            );
        }
    }
    v
}

fn reply_array<'v>(v: &'v Value, key: &str, rows: usize) -> Result<&'v [Value]> {
    let arr = v
        .require(key)?
        .as_array()
        .ok_or_else(|| anyhow!("`{key}` must be an array"))?;
    if arr.len() != rows {
        bail!("`{key}` carries {} rows, expected {rows}", arr.len());
    }
    Ok(arr)
}

/// Decode a `shard_scan` decode-kind reply: one validated
/// [`ShardPartial`] per row, indices global to `[start, end)`
/// (router side; validation rules in [`ShardPartial::from_wire`]).
pub fn decode_shard_partials(
    v: &Value,
    rows: usize,
    k: usize,
    start: usize,
    end: usize,
    sampled: &[bool],
) -> Result<Vec<ShardPartial>> {
    let arr = reply_array(v, "partials", rows)?;
    arr.iter()
        .enumerate()
        .map(|(i, p)| {
            ShardPartial::from_wire(p, k, start, end, sampled[i])
                .map_err(|e| anyhow!("partial row {i}: {e}"))
        })
        .collect()
}

/// Decode a `shard_scan` softmax-kind reply: one partial `(m, d)` per
/// row (router side; non-finite components are rejected).
pub fn decode_shard_norms(v: &Value, rows: usize) -> Result<Vec<MD>> {
    let arr = reply_array(v, "norms", rows)?;
    arr.iter()
        .enumerate()
        .map(|(i, n)| reduce::md_from_wire(n).map_err(|e| anyhow!("norm row {i}: {e}")))
        .collect()
}

/// Decode a `shard_scan` scale-kind reply: one probability slice of
/// width `end − start` per row (router side).
pub fn decode_shard_slices(v: &Value, rows: usize, width: usize) -> Result<Vec<Vec<f32>>> {
    let arr = reply_array(v, "slices", rows)?;
    arr.iter()
        .enumerate()
        .map(|(i, r)| {
            let slice = r.to_f32_vec().map_err(|e| anyhow!("slice row {i}: {e}"))?;
            if slice.len() != width {
                bail!("slice row {i} has {} elements, expected {width}", slice.len());
            }
            if slice.iter().any(|p| !p.is_finite()) {
                bail!("slice row {i} carries non-finite probabilities");
            }
            Ok(slice)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// response encoding — v1 (legacy shape, for v1 requests)
// ---------------------------------------------------------------------------

fn reply_fields(v: &mut Value, reply: &Reply) {
    match reply {
        Reply::Softmax { probs } => {
            v.set("probs", Value::from_f32_slice(probs));
        }
        Reply::TopK { vals, idx } => {
            v.set("vals", Value::from_f32_slice(vals));
            v.set(
                "idx",
                Value::Array(idx.iter().map(|&i| Value::Number(i as f64)).collect()),
            );
        }
    }
}

/// Encode a successful reply (v1 shape).
pub fn encode_reply(reply: &Reply) -> String {
    let mut v = Value::object();
    v.set("ok", Value::Bool(true));
    reply_fields(&mut v, reply);
    v.to_json()
}

/// Encode an error reply (v1 shape: `error` is the message string; the
/// machine-readable `code` rides along for v2-aware tooling).
pub fn encode_error_v1(err: &ServeError) -> String {
    let mut v = Value::object();
    v.set("ok", Value::Bool(false))
        .set("error", Value::String(err.message.clone()))
        .set("code", Value::String(err.code.as_str().to_string()));
    v.to_json()
}

/// Encode a bare-object success (open_session, stats, ping; v1 shape).
pub fn encode_object(mut fields: Value) -> String {
    fields.set("ok", Value::Bool(true));
    fields.to_json()
}

// ---------------------------------------------------------------------------
// response encoding — v2
// ---------------------------------------------------------------------------

/// The structured v2 error object `{code, message}`.
pub fn error_value(err: &ServeError) -> Value {
    let mut v = Value::object();
    v.set("code", Value::String(err.code.as_str().to_string()))
        .set("message", Value::String(err.message.clone()));
    v
}

/// Encode a successful reply (v2 shape).
pub fn encode_reply_v2(reply: &Reply) -> String {
    let mut v = Value::object();
    v.set("v", Value::Number(PROTOCOL_VERSION as f64)).set("ok", Value::Bool(true));
    reply_fields(&mut v, reply);
    v.to_json()
}

/// Encode a structured error reply (v2 shape).
pub fn encode_error_v2(err: &ServeError) -> String {
    let mut v = Value::object();
    v.set("v", Value::Number(PROTOCOL_VERSION as f64))
        .set("ok", Value::Bool(false))
        .set("error", error_value(err));
    v.to_json()
}

/// Encode a bare-object success (v2 shape).
pub fn encode_object_v2(mut fields: Value) -> String {
    fields
        .set("v", Value::Number(PROTOCOL_VERSION as f64))
        .set("ok", Value::Bool(true));
    fields.to_json()
}

/// Version-appropriate error encoding: v2 structured object for v2
/// requests, legacy message-string shape for v1.
pub fn encode_error_for(version: u64, err: &ServeError) -> String {
    if version >= 2 {
        encode_error_v2(err)
    } else {
        encode_error_v1(err)
    }
}

// ---------------------------------------------------------------------------
// streaming frames (v2 only)
// ---------------------------------------------------------------------------

/// Encode one streamed token frame.
pub fn encode_stream_token(stream: u64, frame: &TokenFrame) -> String {
    let mut v = Value::object();
    v.set("v", Value::Number(PROTOCOL_VERSION as f64))
        .set("stream", Value::Number(stream as f64))
        .set("index", Value::Number(frame.index as f64))
        .set("token", Value::Number(frame.token as f64))
        .set("vals", Value::from_f32_slice(&frame.vals))
        .set(
            "idx",
            Value::Array(frame.idx.iter().map(|&i| Value::Number(i as f64)).collect()),
        );
    v.to_json()
}

/// Encode the successful terminal frame of a stream.
pub fn encode_stream_done(stream: u64, tokens: &[i32]) -> String {
    let mut v = Value::object();
    v.set("v", Value::Number(PROTOCOL_VERSION as f64))
        .set("stream", Value::Number(stream as f64))
        .set("done", Value::Bool(true))
        .set("tokens", Value::from_i32_slice(tokens));
    v.to_json()
}

/// Encode the failed terminal frame of a stream.
pub fn encode_stream_failed(stream: u64, err: &ServeError) -> String {
    let mut v = Value::object();
    v.set("v", Value::Number(PROTOCOL_VERSION as f64))
        .set("stream", Value::Number(stream as f64))
        .set("done", Value::Bool(true))
        .set("error", error_value(err));
    v.to_json()
}

// ---------------------------------------------------------------------------
// client-side decoding
// ---------------------------------------------------------------------------

/// A server-reported error decoded on the client side, preserved as a
/// typed value inside the returned `anyhow::Error` chain so tooling
/// (the load generator's overload accounting, integration tests) can
/// classify failures by [`ErrorCode`] instead of parsing display
/// strings: `err.downcast_ref::<WireError>()`, or the [`error_code`]
/// convenience.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    /// The decoded `error.code`, when the server sent a recognized one.
    pub code: Option<ErrorCode>,
    /// The raw wire `code` string (kept even when unrecognized, for
    /// display fidelity against newer servers).
    pub code_str: Option<String>,
    pub message: String,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.code_str {
            Some(code) => write!(f, "server error [{code}]: {}", self.message),
            None => write!(f, "server error: {}", self.message),
        }
    }
}

impl std::error::Error for WireError {}

/// The typed [`ErrorCode`] of a client-call failure, if the failure
/// was a structured server rejection (as opposed to an I/O error).
pub fn error_code(err: &anyhow::Error) -> Option<ErrorCode> {
    err.downcast_ref::<WireError>().and_then(|w| w.code)
}

fn error_from(v: &Value) -> anyhow::Error {
    let wire = match v.get("error") {
        // v2: structured object
        Some(err @ Value::Object(_)) => {
            let code = err.get("code").and_then(Value::as_str).unwrap_or("internal");
            let message =
                err.get("message").and_then(Value::as_str).unwrap_or("unknown");
            WireError {
                code: ErrorCode::parse(code),
                code_str: Some(code.to_string()),
                message: message.to_string(),
            }
        }
        // v1: message string (code may ride along)
        Some(Value::String(s)) => {
            let code_str = v.get("code").and_then(Value::as_str);
            WireError {
                code: code_str.and_then(ErrorCode::parse),
                code_str: code_str.map(str::to_string),
                message: s.clone(),
            }
        }
        _ => WireError { code: None, code_str: None, message: "unknown".to_string() },
    };
    anyhow::Error::new(wire)
}

/// Decode a single-frame response line on the client side (either
/// version).
pub fn decode_response(line: &str) -> Result<Value> {
    let v = json::parse(line.trim()).map_err(|e| anyhow!("bad response json: {e}"))?;
    match v.get("ok").and_then(Value::as_bool) {
        Some(true) => Ok(v),
        Some(false) => Err(error_from(&v)),
        None => Err(anyhow!("response missing `ok` field")),
    }
}

/// One event of a streamed v2 response.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// A decoded token frame.
    Token(TokenFrame),
    /// Clean end of stream with the full selected-token list.
    Done { tokens: Vec<i32> },
}

/// Decode one line of a streaming response.  Plain (non-stream) error
/// responses and failed terminal frames both surface as `Err`.
pub fn decode_stream_event(line: &str) -> Result<StreamEvent> {
    let v = json::parse(line.trim()).map_err(|e| anyhow!("bad stream json: {e}"))?;
    match v.get("ok").and_then(Value::as_bool) {
        Some(false) => return Err(error_from(&v)),
        Some(true) => bail!("unexpected non-stream response during generation"),
        None => {}
    }
    if v.get("done").and_then(Value::as_bool) == Some(true) {
        if v.get("error").is_some() {
            return Err(error_from(&v));
        }
        return Ok(StreamEvent::Done { tokens: v.require("tokens")?.to_i32_vec()? });
    }
    let index = v
        .require("index")?
        .as_usize()
        .ok_or_else(|| anyhow!("`index` must be a non-negative integer"))?;
    let token = v
        .require("token")?
        .as_i64()
        .ok_or_else(|| anyhow!("`token` must be an integer"))? as i32;
    let vals = v.require("vals")?.to_f32_vec()?;
    let idx: Vec<i64> =
        v.require("idx")?.to_i32_vec()?.into_iter().map(|i| i as i64).collect();
    Ok(StreamEvent::Token(TokenFrame { index, token, vals, idx }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_all_v1_ops() {
        let f = decode_request(r#"{"op":"softmax","logits":[1,2]}"#).unwrap();
        assert_eq!(f.v, 1);
        assert_eq!(f.op, Op::Request(Payload::Softmax { logits: vec![1.0, 2.0] }));
        assert_eq!(f.options, RequestOptions::default());

        let f = decode_request(r#"{"op":"decode","hidden":[0.5],"k":3}"#).unwrap();
        assert_eq!(f.op, Op::Request(Payload::DecodeTopK { hidden: vec![0.5] }));
        assert_eq!(f.options.k, Some(3), "v1 `k` lands in options");

        let f = decode_request(r#"{"op":"lm_step","session":7,"token":9}"#).unwrap();
        assert_eq!(f.op, Op::Request(Payload::LmStep { session: 7, token: 9 }));
        assert_eq!(f.options.k, None);

        assert_eq!(decode_request(r#"{"op":"open_session"}"#).unwrap().op, Op::OpenSession);
        assert_eq!(
            decode_request(r#"{"op":"fork_session","session":2}"#).unwrap().op,
            Op::ForkSession(2)
        );
        assert_eq!(
            decode_request(r#"{"op":"close_session","session":3}"#).unwrap().op,
            Op::CloseSession(3)
        );
        assert_eq!(decode_request(r#"{"op":"ping"}"#).unwrap().op, Op::Ping);
        assert_eq!(decode_request(r#"{"op":"stats"}"#).unwrap().op, Op::Stats);
    }

    #[test]
    fn decode_v2_options_and_generate() {
        let f = decode_request(
            r#"{"v":2,"op":"decode","hidden":[0.5],"k":3,"priority":"batch",
                "deadline_ms":250,"tag":"loadgen-3","temperature":1}"#,
        )
        .unwrap();
        assert_eq!(f.v, 2);
        assert_eq!(f.options.k, Some(3));
        assert_eq!(f.options.priority, Priority::Batch);
        assert_eq!(f.options.deadline, Some(Duration::from_millis(250)));
        assert_eq!(f.options.client_tag.as_deref(), Some("loadgen-3"));
        assert_eq!(f.options.temperature, 1.0);

        let f = decode_request(
            r#"{"v":2,"op":"generate","session":4,"prompt":[3,9],"max_tokens":8,"k":5}"#,
        )
        .unwrap();
        assert_eq!(
            f.op,
            Op::Request(Payload::Generate {
                session: 4,
                prompt_tokens: vec![3, 9],
                max_tokens: 8
            })
        );
        assert_eq!(f.options.k, Some(5));
    }

    #[test]
    fn rejects_malformed_with_typed_errors() {
        use crate::coordinator::ErrorCode;
        let e = decode_request("not json").unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
        assert_eq!(e.v, 1);
        let e = decode_request(r#"{"op":"bogus"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
        let e = decode_request(r#"{"op":"decode"}"#).unwrap_err();
        assert!(e.error.message.contains("hidden"), "{}", e.error);
        let e = decode_request(r#"{"op":"lm_step","session":"x","token":1}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
        // wrong / non-integer versions
        let e = decode_request(r#"{"v":3,"op":"ping"}"#).unwrap_err();
        assert_eq!(e.v, 2, "unsupported-version errors render as v2");
        assert!(e.error.message.contains("version"), "{}", e.error);
        let e = decode_request(r#"{"v":"two","op":"ping"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
        // generate is v2-only
        let e = decode_request(r#"{"op":"generate","session":1,"prompt":[1],"max_tokens":2}"#)
            .unwrap_err();
        assert!(e.error.message.contains("v2"), "{}", e.error);
        // out-of-range temperature is invalid_argument, not bad_request
        let e = decode_request(r#"{"v":2,"op":"ping","temperature":0}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::InvalidArgument);
        let e = decode_request(r#"{"v":2,"op":"ping","temperature":-0.5}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::InvalidArgument);
        // an ill-typed seed is a bad_request (protocol misuse)
        let e = decode_request(r#"{"v":2,"op":"ping","seed":-1}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
        let e = decode_request(r#"{"v":2,"op":"ping","seed":"abc"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
    }

    #[test]
    fn decode_v2_sampling_options() {
        let f = decode_request(
            r#"{"v":2,"op":"decode","hidden":[0.5],"k":3,"temperature":0.7,"seed":42}"#,
        )
        .unwrap();
        assert_eq!(f.options.temperature, 0.7);
        assert_eq!(f.options.seed, Some(42));
        // v1 frames never parse sampling options: the surface is frozen.
        let f = decode_request(r#"{"op":"decode","hidden":[0.5],"k":3,"seed":42}"#).unwrap();
        assert_eq!(f.options.seed, None, "v1 ignores seed");
        assert_eq!(f.options.temperature, 1.0);
    }

    #[test]
    fn reply_roundtrip_both_versions() {
        for encode in [encode_reply, encode_reply_v2] {
            let line = encode(&Reply::TopK { vals: vec![0.5, 0.25], idx: vec![7, 3] });
            let v = decode_response(&line).unwrap();
            assert_eq!(v.get("vals").unwrap().to_f32_vec().unwrap(), vec![0.5, 0.25]);
            assert_eq!(v.get("idx").unwrap().to_i32_vec().unwrap(), vec![7, 3]);

            let line = encode(&Reply::Softmax { probs: vec![1.0] });
            let v = decode_response(&line).unwrap();
            assert_eq!(v.get("probs").unwrap().to_f32_vec().unwrap(), vec![1.0]);
        }
        let line = encode_reply_v2(&Reply::Softmax { probs: vec![1.0] });
        let v = decode_response(&line).unwrap();
        assert_eq!(v.get("v").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn error_roundtrip_both_versions() {
        let err = ServeError::not_found("unknown session 9");
        let e = decode_response(&encode_error_v1(&err)).unwrap_err();
        assert!(format!("{e}").contains("unknown session 9"), "{e}");
        assert!(format!("{e}").contains("not_found"), "v1 carries the code: {e}");
        let e = decode_response(&encode_error_v2(&err)).unwrap_err();
        assert!(format!("{e}").contains("unknown session 9"), "{e}");
        assert!(format!("{e}").contains("not_found"), "{e}");
        assert_eq!(encode_error_for(1, &err), encode_error_v1(&err));
        assert_eq!(encode_error_for(2, &err), encode_error_v2(&err));
    }

    #[test]
    fn decoded_errors_carry_typed_codes() {
        // v2 structured error → downcastable WireError with a parsed code.
        let line = encode_error_v2(&ServeError::overloaded("batch lane at quota"));
        let e = decode_response(&line).unwrap_err();
        assert_eq!(error_code(&e), Some(ErrorCode::Overloaded));
        let w = e.downcast_ref::<WireError>().unwrap();
        assert_eq!(w.code_str.as_deref(), Some("overloaded"));
        assert_eq!(w.message, "batch lane at quota");

        // v1 carries the code as a rider; still typed.
        let line = encode_error_v1(&ServeError::deadline("too slow"));
        let e = decode_response(&line).unwrap_err();
        assert_eq!(error_code(&e), Some(ErrorCode::DeadlineExceeded));

        // An unrecognized code from a newer server degrades gracefully:
        // no typed code, but the raw label survives in the display.
        let e = decode_response(
            r#"{"v":2,"ok":false,"error":{"code":"rate_limited","message":"slow down"}}"#,
        )
        .unwrap_err();
        assert_eq!(error_code(&e), None);
        assert!(format!("{e}").contains("[rate_limited]"), "{e}");

        // I/O-level failures have no wire code.
        assert_eq!(error_code(&anyhow!("connection reset")), None);
    }

    #[test]
    fn shard_scan_roundtrips_all_kinds() {
        // decode kind, one sampled and one greedy row
        let scan = ShardScan {
            kind: ShardScanKind::Decode,
            start: 128,
            end: 256,
            k: 4,
            rows: vec![vec![0.5, -1.25], vec![2.0, 3.5]],
            samples: vec![None, Some(SampleSpec { seed: u64::MAX - 3, temperature: 0.5 })],
            norms: vec![],
        };
        let f = decode_request(&encode_shard_scan(&scan)).unwrap();
        assert_eq!(f.v, 2);
        assert_eq!(f.op, Op::ShardScan(scan), "u64 seeds survive the string encoding");

        // softmax kind: rows are logit slices, no k/samples/norms
        let scan = ShardScan {
            kind: ShardScanKind::Softmax,
            start: 0,
            end: 3,
            k: 0,
            rows: vec![vec![1.0, 2.0, 3.0]],
            samples: vec![],
            norms: vec![],
        };
        let f = decode_request(&encode_shard_scan(&scan)).unwrap();
        assert_eq!(f.op, Op::ShardScan(scan));

        // scale kind carries the merged norms (incl. the identity shape)
        let scan = ShardScan {
            kind: ShardScanKind::Scale,
            start: 3,
            end: 6,
            k: 0,
            rows: vec![vec![1.0, 2.0, 3.0], vec![0.0, 0.5, 1.0]],
            samples: vec![],
            norms: vec![MD { m: 3.0, d: 1.5 }, MD::IDENTITY],
        };
        let f = decode_request(&encode_shard_scan(&scan)).unwrap();
        assert_eq!(f.op, Op::ShardScan(scan));
    }

    #[test]
    fn shard_scan_requires_v2() {
        let scan = ShardScan {
            kind: ShardScanKind::Softmax,
            start: 0,
            end: 2,
            k: 0,
            rows: vec![vec![1.0, 2.0]],
            samples: vec![],
            norms: vec![],
        };
        let v1 = encode_shard_scan(&scan).replace("\"v\":2", "\"v\":1");
        let e = decode_request(&v1).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
        assert!(e.error.message.contains("v2"), "{}", e.error);
    }

    #[test]
    fn shard_scan_rejects_malformed_typed() {
        for (frame, what) in [
            (r#"{"v":2,"op":"shard_scan"}"#, "missing kind"),
            (r#"{"v":2,"op":"shard_scan","kind":"transpose","start":0,"end":2,"rows":[[1]]}"#, "unknown kind"),
            (r#"{"v":2,"op":"shard_scan","kind":"softmax","end":2,"rows":[[1]]}"#, "missing start"),
            (r#"{"v":2,"op":"shard_scan","kind":"softmax","start":2,"end":2,"rows":[[1]]}"#, "empty range"),
            (r#"{"v":2,"op":"shard_scan","kind":"softmax","start":3,"end":2,"rows":[[1]]}"#, "inverted range"),
            (r#"{"v":2,"op":"shard_scan","kind":"softmax","start":0,"end":2}"#, "missing rows"),
            (r#"{"v":2,"op":"shard_scan","kind":"softmax","start":0,"end":2,"rows":[]}"#, "empty rows"),
            (r#"{"v":2,"op":"shard_scan","kind":"softmax","start":0,"end":2,"rows":[["a"]]}"#, "ill-typed rows"),
            (r#"{"v":2,"op":"shard_scan","kind":"softmax","start":0,"end":2,"rows":[[null]]}"#, "null logit"),
            (r#"{"v":2,"op":"shard_scan","kind":"decode","start":0,"end":2,"rows":[[1]]}"#, "decode without k"),
            (r#"{"v":2,"op":"shard_scan","kind":"decode","start":0,"end":2,"k":0,"rows":[[1]]}"#, "k = 0"),
            (r#"{"v":2,"op":"shard_scan","kind":"decode","start":0,"end":2,"k":2,"rows":[[1]],"samples":[null,null]}"#, "misaligned samples"),
            (r#"{"v":2,"op":"shard_scan","kind":"decode","start":0,"end":2,"k":2,"rows":[[1]],"samples":[{"seed":"x","temperature":1}]}"#, "bad seed string"),
            (r#"{"v":2,"op":"shard_scan","kind":"decode","start":0,"end":2,"k":2,"rows":[[1]],"samples":[{"seed":"1"}]}"#, "spec missing temperature"),
            (r#"{"v":2,"op":"shard_scan","kind":"scale","start":0,"end":2,"rows":[[1,2]]}"#, "scale without norms"),
            (r#"{"v":2,"op":"shard_scan","kind":"scale","start":0,"end":2,"rows":[[1,2]],"norms":[]}"#, "misaligned norms"),
            (r#"{"v":2,"op":"shard_scan","kind":"scale","start":0,"end":2,"rows":[[1,2]],"norms":[{"m":null,"d":1}]}"#, "non-finite m"),
            (r#"{"v":2,"op":"shard_scan","kind":"scale","start":0,"end":2,"rows":[[1,2]],"norms":[{"m":1,"d":0}]}"#, "d = 0"),
        ] {
            let e = decode_request(frame).unwrap_err();
            assert_eq!(e.error.code, ErrorCode::BadRequest, "{what}: {frame}");
            assert_eq!(e.v, 2, "{what}");
        }
        // a non-positive spec temperature is invalid_argument (value range)
        let e = decode_request(
            r#"{"v":2,"op":"shard_scan","kind":"decode","start":0,"end":2,"k":2,"rows":[[1]],"samples":[{"seed":"1","temperature":0}]}"#,
        )
        .unwrap_err();
        assert_eq!(e.error.code, ErrorCode::InvalidArgument);
    }

    #[test]
    fn shard_scan_reply_roundtrips() {
        // decode-kind reply: partials with global indices
        let x: Vec<f32> = (0..32).map(|i| ((i * 13) % 7) as f32).collect();
        let parts = vec![
            ShardPartial::scan(&x, 3, 64),
            ShardPartial::scan_with(&x, 3, 64, Some(SampleSpec { seed: 5, temperature: 1.0 })),
        ];
        let line = encode_object_v2(shard_scan_reply_fields(&ShardScanReply::Partials(parts.clone())));
        let v = decode_response(&line).unwrap();
        let back = decode_shard_partials(&v, 2, 3, 64, 96, &[false, true]).unwrap();
        assert_eq!(back[0].md, parts[0].md);
        assert_eq!(back[0].topk.values(), parts[0].topk.values());
        assert_eq!(back[0].topk.indices(), parts[0].topk.indices());
        assert_eq!(
            back[1].sampled.as_ref().map(|b| b.indices().to_vec()),
            parts[1].sampled.as_ref().map(|b| b.indices().to_vec())
        );
        // wrong row count / out-of-range indices are typed errors
        assert!(decode_shard_partials(&v, 3, 3, 64, 96, &[false, true, true]).is_err());
        assert!(decode_shard_partials(&v, 2, 3, 0, 32, &[false, true]).is_err(), "indices outside range");

        // softmax-kind reply
        let norms = vec![MD { m: 1.0, d: 2.0 }, MD::IDENTITY];
        let line = encode_object_v2(shard_scan_reply_fields(&ShardScanReply::Norms(norms.clone())));
        let v = decode_response(&line).unwrap();
        assert_eq!(decode_shard_norms(&v, 2).unwrap(), norms);
        assert!(decode_shard_norms(&v, 1).is_err());

        // scale-kind reply
        let slices = vec![vec![0.25, 0.75]];
        let line = encode_object_v2(shard_scan_reply_fields(&ShardScanReply::Slices(slices.clone())));
        let v = decode_response(&line).unwrap();
        assert_eq!(decode_shard_slices(&v, 1, 2).unwrap(), slices);
        assert!(decode_shard_slices(&v, 1, 3).is_err(), "width mismatch");
        assert!(decode_shard_slices(&v, 2, 2).is_err(), "row-count mismatch");
    }

    #[test]
    fn stream_frames_roundtrip() {
        let frame =
            TokenFrame { index: 2, token: 17, vals: vec![0.5, 0.125], idx: vec![17, 3] };
        let ev = decode_stream_event(&encode_stream_token(9, &frame)).unwrap();
        assert_eq!(ev, StreamEvent::Token(frame));

        let ev = decode_stream_event(&encode_stream_done(9, &[17, 3, 3])).unwrap();
        assert_eq!(ev, StreamEvent::Done { tokens: vec![17, 3, 3] });

        let line = encode_stream_failed(9, &ServeError::deadline("stream deadline exhausted"));
        let e = decode_stream_event(&line).unwrap_err();
        assert!(format!("{e}").contains("deadline_exceeded"), "{e}");

        // a plain v2 error frame also surfaces as Err
        let line = encode_error_v2(&ServeError::not_found("unknown session 8"));
        let e = decode_stream_event(&line).unwrap_err();
        assert!(format!("{e}").contains("not_found"), "{e}");
    }
}
