//! Lane-parallel (SIMD-friendly) renditions of Algorithms 1–3.
//!
//! The CPU adaptation the paper sketches in §7: keep the online
//! normalizer *vectorized* by giving every SIMD lane its own `(m, d)`
//! state and ⊕-merging the lanes once at the end — the associativity
//! of eq. (4) is exactly what makes this legal.  All inner loops are
//! branch-free over [`fast_exp`](super::fastexp::fast_exp) so LLVM
//! auto-vectorizes them (verified by the >4x speedup over
//! [`super::scalar`] in the benches).
//!
//! `LANES = 16` covers AVX-512/AVX2 with unrolling headroom.

use super::fastexp::fast_exp;
use super::monoid::MD;

/// Lane count for the stripe-wise state arrays.
pub const LANES: usize = 16;

/// Vectorized Algorithm 1 (naive).  NOTE: uses saturating `fast_exp`,
/// so unlike the scalar form it degrades (rather than Inf) past the fp32
/// exp range — it remains a *performance* baseline only, like the paper's.
#[inline]
pub fn naive(x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let mut lane_d = [0.0f32; LANES];
    let (chunks, tail) = split(x);
    for c in chunks {
        for l in 0..LANES {
            lane_d[l] += fast_exp(c[l]);
        }
    }
    let mut d: f32 = lane_d.iter().sum();
    for &v in tail {
        d += fast_exp(v);
    }
    scale_pass(x, out, 0.0, 1.0 / d);
}

/// Vectorized Algorithm 2 (safe): three vector passes.
#[inline]
pub fn safe(x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let m = rowmax(x);
    let d = expsum(x, m);
    scale_pass(x, out, m, 1.0 / d);
}

/// Vectorized max pass (pass 1 of Algorithm 2).
#[inline]
pub fn rowmax(x: &[f32]) -> f32 {
    let mut lane_m = [f32::NEG_INFINITY; LANES];
    let (chunks, tail) = split(x);
    for c in chunks {
        for l in 0..LANES {
            lane_m[l] = lane_m[l].max(c[l]);
        }
    }
    let mut m = lane_m.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    for &v in tail {
        m = m.max(v);
    }
    m
}

/// Vectorized `Σ e^{x−m}` pass (pass 2 of Algorithm 2).
#[inline]
pub fn expsum(x: &[f32], m: f32) -> f32 {
    let mut lane_d = [0.0f32; LANES];
    let (chunks, tail) = split(x);
    for c in chunks {
        for l in 0..LANES {
            lane_d[l] += fast_exp(c[l] - m);
        }
    }
    let mut d: f32 = lane_d.iter().sum();
    for &v in tail {
        d += fast_exp(v - m);
    }
    d
}

/// Cache-blocked single-pass online normalizer — the production path.
///
/// Processes `BLOCK`-element tiles: per tile, a vectorized max pass and
/// a vectorized `Σ e^{x−m_blk}` pass (the tile stays in L1, so DRAM is
/// still touched exactly once per element — "single pass" in the
/// paper's memory-access accounting), then ONE ⊕ fold into the running
/// `(m, d)` (eq. 4).  This is the same tile structure as the L1 Pallas
/// kernel's BlockSpec carry, and costs ~1 `exp` per element versus 2
/// for the per-element recurrence in [`online_normalizer_streaming`]
/// (measured ~1.6× faster; see EXPERIMENTS.md §Perf).
#[inline]
pub fn online_normalizer(x: &[f32]) -> MD {
    /// 2 KiB of f32 — comfortably L1-resident alongside the stream.
    const BLOCK: usize = 512;
    let mut acc = MD::IDENTITY;
    for blk in x.chunks(BLOCK) {
        let m_blk = rowmax(blk);
        if m_blk == f32::NEG_INFINITY {
            continue; // all-padding tile contributes the identity
        }
        let d_blk = expsum(blk, m_blk);
        acc = acc.combine(MD { m: m_blk, d: d_blk });
    }
    acc
}

/// Strictly-streaming lane-parallel online normalizer (lines 1–6 of
/// Algorithm 3 verbatim at lane granularity: one ⊕ fold per element per
/// lane).  Kept for the ablation bench and for single-visit streaming
/// use cases where elements cannot be revisited even from L1.
#[inline]
pub fn online_normalizer_streaming(x: &[f32]) -> MD {
    let mut lane_m = [f32::NEG_INFINITY; LANES];
    let mut lane_d = [0.0f32; LANES];
    let (chunks, tail) = split(x);
    for c in chunks {
        for l in 0..LANES {
            // Branch-free lane update: m' = max(m, x);
            // d' = d · e^{m−m'} + e^{x−m'}.
            // With m = −∞ initially, fast_exp saturates to ~1e−38 and
            // d = 0 annihilates it — no NaN, no branch.
            let xv = c[l];
            let m_new = lane_m[l].max(xv);
            // e^{xv − m'} with the ⊕ identity corner pinned: when xv
            // AND m' are both −∞ (an all-padding lane), the IEEE
            // −∞ − −∞ = NaN would hit fast_exp's input clamp and come
            // back as e^88, silently poisoning d.  The comparison
            // lowers to a select, so the loop still vectorizes; this
            // matches MD::push's exp_guard convention exactly.
            let e_x = if xv == f32::NEG_INFINITY { 0.0 } else { fast_exp(xv - m_new) };
            lane_d[l] = lane_d[l] * fast_exp(lane_m[l] - m_new) + e_x;
            lane_m[l] = m_new;
        }
    }
    let mut acc = MD::IDENTITY;
    for l in 0..LANES {
        // lanes that never saw data stay (−∞, 0) = identity
        acc = acc.combine(MD { m: lane_m[l], d: lane_d[l] });
    }
    for &v in tail {
        acc = acc.push(v);
    }
    acc
}

/// Vectorized Algorithm 3 (online): normalizer pass + scale pass.
#[inline]
pub fn online(x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let MD { m, d } = online_normalizer(x);
    scale_pass(x, out, m, 1.0 / d);
}

/// Shared final pass: `y = e^{x − m} · inv`, lane-chunked so the store
/// loop vectorizes like the reduction loops.
///
/// Perf note (EXPERIMENTS.md §Perf, L3 iteration 3): this pass is
/// *store-bound* on the testbed — the write stream pays read-for-
/// ownership + writeback, capping it at ~10–13 GB/s versus ~50 GB/s for
/// the read passes.  Non-temporal `_mm_stream_ps` stores were tried and
/// measured 2.2× *slower* in this virtualized environment, so the plain
/// cached-store form below is the practical roofline.  This asymmetry
/// compresses the softmax-only speedups (Figures 1–2) relative to the
/// paper's GPU, and is precisely why the fused Algorithm 4 — which
/// eliminates the store pass entirely — shows the paper's effect most
/// clearly here (Figures 3–4).
#[inline]
pub fn scale_pass(x: &[f32], out: &mut [f32], m: f32, inv: f32) {
    assert_eq!(x.len(), out.len());
    let mut xc = x.chunks_exact(LANES);
    let mut yc = out.chunks_exact_mut(LANES);
    for (c, y) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            y[l] = fast_exp(c[l] - m) * inv;
        }
    }
    for (y, &v) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *y = fast_exp(v - m) * inv;
    }
}

#[inline]
fn split(x: &[f32]) -> (std::slice::ChunksExact<'_, f32>, &[f32]) {
    let chunks = x.chunks_exact(LANES);
    let tail = chunks.remainder();
    (chunks, tail)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::scalar;

    fn logits(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        crate::rng::Xoshiro256pp::seed_from_u64(seed).logits(n, scale)
    }

    fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= atol + rtol * x.abs().max(y.abs()),
                "idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn online_matches_scalar_across_lengths() {
        for n in [1, 2, 7, 15, 16, 17, 64, 100, 1023, 1024, 4097] {
            let x = logits(n, n as u64, 6.0);
            let mut yv = vec![0.0; n];
            let mut ys = vec![0.0; n];
            online(&x, &mut yv);
            scalar::online(&x, &mut ys);
            assert_close(&yv, &ys, 1e-5, 1e-9);
        }
    }

    #[test]
    fn safe_matches_scalar() {
        let x = logits(777, 5, 12.0);
        let mut yv = vec![0.0; 777];
        let mut ys = vec![0.0; 777];
        safe(&x, &mut yv);
        scalar::safe(&x, &mut ys);
        assert_close(&yv, &ys, 1e-5, 1e-9);
    }

    #[test]
    fn naive_matches_scalar_in_safe_range() {
        let x = logits(500, 6, 3.0);
        let mut yv = vec![0.0; 500];
        let mut ys = vec![0.0; 500];
        naive(&x, &mut yv);
        scalar::naive(&x, &mut ys);
        assert_close(&yv, &ys, 1e-5, 1e-9);
    }

    #[test]
    fn normalizer_equals_scalar_normalizer() {
        for seed in 0..10 {
            let x = logits(931, seed, 20.0);
            let a = online_normalizer(&x);
            let b = scalar::online_normalizer(&x);
            assert_eq!(a.m, b.m);
            assert!((a.d - b.d).abs() <= 2e-5 * b.d, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn streaming_normalizer_equals_blocked() {
        for n in [1usize, 15, 511, 512, 513, 5000] {
            let x = logits(n, n as u64, 12.0);
            let a = online_normalizer(&x);
            let b = online_normalizer_streaming(&x);
            assert_eq!(a.m, b.m, "n={n}");
            assert!((a.d - b.d).abs() <= 2e-5 * b.d.max(1.0), "n={n}: {a:?} vs {b:?}");
        }
        assert!(online_normalizer_streaming(&[]).is_identity());
    }

    #[test]
    fn streaming_normalizer_treats_neg_infinity_as_identity() {
        // Regression: −∞ lanes used to hit fast_exp(−∞ − −∞ = NaN),
        // whose input clamp returns e^88 — an all-padding vector came
        // back with a huge garbage d instead of the ⊕ identity.
        for n in [1usize, 7, LANES, LANES + 3, 64, 700] {
            let all_pad = vec![f32::NEG_INFINITY; n];
            assert!(
                online_normalizer_streaming(&all_pad).is_identity(),
                "n={n}: all-padding input must reduce to the identity"
            );
        }
        // Mixed: padding elements contribute (at most fp-saturation
        // dust) nothing; m and d match the blocked kernel.
        let mut x = logits(300, 17, 8.0);
        for i in (0..300).step_by(7) {
            x[i] = f32::NEG_INFINITY;
        }
        let a = online_normalizer(&x);
        let b = online_normalizer_streaming(&x);
        assert_eq!(a.m, b.m);
        assert!(b.d.is_finite());
        assert!((a.d - b.d).abs() <= 2e-5 * b.d.max(1.0), "{a:?} vs {b:?}");
    }

    #[test]
    fn extreme_magnitudes_stay_finite() {
        let mut x = logits(320, 9, 2.0);
        x.iter_mut().for_each(|v| *v += 150.0);
        let mut y = vec![0.0; 320];
        online(&x, &mut y);
        assert!(y.iter().all(|v| v.is_finite()));
        let s: f32 = y.iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
    }

    #[test]
    fn empty_and_single() {
        assert!(online_normalizer(&[]).is_identity());
        let mut y = [0.0f32];
        online(&[3.0], &mut y);
        assert!((y[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sub_lane_lengths_use_tail_path() {
        for n in 1..LANES {
            let x = logits(n, 100 + n as u64, 4.0);
            let a = online_normalizer(&x);
            let b = scalar::online_normalizer(&x);
            assert_eq!(a.m, b.m, "n={n}");
            assert!((a.d - b.d).abs() <= 1e-5 * b.d.max(1.0), "n={n}");
        }
    }
}
