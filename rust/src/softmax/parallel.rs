//! Multithreaded online normalizer — §3.1 at thread granularity.
//!
//! The input vector is split into chunks; each worker folds its chunk
//! with the vectorized single-pass kernel; partial `(m, d)` states (and
//! top-k buffers, for the fused form) merge with ⊕.  This is the same
//! reduction the coordinator performs across vocabulary *shards*, here
//! applied across *threads* within one vector — both legal for the same
//! reason: eq. (4) is associative and commutative.

use super::fused;
use super::monoid::MD;
use super::vectorized;
use crate::exec::parallel_chunks;
use crate::topk::TopKBuffer;

/// Minimum per-thread work; below this, threading overhead dominates and
/// we fall back to the single-thread kernel.
pub const MIN_CHUNK: usize = 16_384;

/// Parallel single-pass normalizer over `threads` workers.
pub fn online_normalizer(x: &[f32], threads: usize) -> MD {
    if x.len() < 2 * MIN_CHUNK || threads <= 1 {
        return vectorized::online_normalizer(x);
    }
    let chunk = x.len().div_ceil(threads).max(MIN_CHUNK);
    let parts = parallel_chunks(threads, x, chunk, |_, c| vectorized::online_normalizer(c));
    parts.into_iter().fold(MD::IDENTITY, MD::combine)
}

/// Parallel full online softmax: parallel normalizer + parallel scale.
pub fn online(x: &[f32], out: &mut [f32], threads: usize) {
    assert_eq!(x.len(), out.len());
    let md = online_normalizer(x, threads);
    scale(x, out, md, threads);
}

/// Parallel scale pass `y = e^{x−m}/d`.
pub fn scale(x: &[f32], out: &mut [f32], md: MD, threads: usize) {
    assert_eq!(x.len(), out.len());
    let inv = 1.0 / md.d;
    if x.len() < 2 * MIN_CHUNK || threads <= 1 {
        vectorized::scale_pass(x, out, md.m, inv);
        return;
    }
    let chunk = x.len().div_ceil(threads).max(MIN_CHUNK);
    // Write into disjoint slices of `out` from worker threads.
    let out_ptr = OutPtr(out.as_mut_ptr());
    let out_ref = &out_ptr;
    parallel_chunks(threads, x, chunk, |i, c| {
        // SAFETY: chunks are disjoint ranges of out, len matches x.
        let dst = unsafe { out_ref.slice(i * chunk, c.len()) };
        vectorized::scale_pass(c, dst, md.m, inv);
    });
}

/// Raw output pointer shared across scale-pass workers.
///
/// SAFETY contract: only [`OutPtr::slice`] dereferences it, each worker
/// with a disjoint in-bounds `[start, start+len)` range (the chunk grid
/// guarantees disjointness), and `parallel_chunks` joins every worker
/// before `out` is read again.
struct OutPtr(*mut f32);
// SAFETY: per the contract above — disjoint in-bounds writes only, and
// the scoped join orders them before any read; `f32` is plain data
// (`Send`), so handing slices of it to workers transfers no ownership
// semantics.
unsafe impl Sync for OutPtr {}
// SAFETY: as above — moving the wrapper only moves the raw pointer.
unsafe impl Send for OutPtr {}

impl OutPtr {
    /// SAFETY: caller guarantees [start, start+len) ranges are disjoint
    /// across threads and in-bounds for the underlying allocation.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice(&self, start: usize, len: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

/// Parallel fused online softmax + top-k (Algorithm 4 across threads).
pub fn online_topk(x: &[f32], k: usize, threads: usize) -> (Vec<f32>, Vec<i64>) {
    if x.len() < 2 * MIN_CHUNK || threads <= 1 {
        return fused::online_topk(x, k);
    }
    let chunk = x.len().div_ceil(threads).max(MIN_CHUNK);
    let parts: Vec<(MD, TopKBuffer)> =
        parallel_chunks(threads, x, chunk, |i, c| fused::shard_partial(c, k, (i * chunk) as i64));
    fused::merge_partials(&parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::scalar;

    fn logits(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        crate::rng::Xoshiro256pp::seed_from_u64(seed).logits(n, scale)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 200k elements; the OutPtr paths are miri-covered below
    fn parallel_normalizer_matches_scalar() {
        let x = logits(200_000, 1, 9.0);
        let serial = scalar::online_normalizer(&x);
        for threads in [1, 2, 4, 8] {
            let par = online_normalizer(&x, threads);
            assert_eq!(par.m, serial.m, "threads={threads}");
            assert!((par.d - serial.d).abs() <= 2e-5 * serial.d, "threads={threads}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 150k elements; the OutPtr paths are miri-covered below
    fn parallel_softmax_matches_vectorized() {
        let x = logits(150_000, 2, 5.0);
        let mut y_par = vec![0.0; x.len()];
        let mut y_vec = vec![0.0; x.len()];
        online(&x, &mut y_par, 4);
        vectorized::online(&x, &mut y_vec);
        // Same fast_exp everywhere; only the (m, d) reassociation differs.
        for (a, b) in y_par.iter().zip(&y_vec) {
            assert!((a - b).abs() <= 1e-10 + 1e-5 * b.abs(), "{a} vs {b}");
        }
        let sum: f32 = y_par.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 120k elements; the OutPtr paths are miri-covered below
    fn parallel_topk_matches_single_thread() {
        let x = logits(120_000, 3, 12.0);
        let single = fused::online_topk(&x, 9);
        let multi = online_topk(&x, 9, 6);
        assert_eq!(single.1, multi.1);
        for (a, b) in single.0.iter().zip(&multi.0) {
            assert!((a - b).abs() <= 2e-5 * a.max(*b));
        }
    }

    #[test]
    fn small_inputs_take_fallback() {
        let x = logits(100, 4, 3.0);
        let md = online_normalizer(&x, 8);
        let serial = vectorized::online_normalizer(&x);
        assert_eq!(md.m, serial.m);
        assert_eq!(md.d, serial.d, "fallback must be bitwise-identical");
    }

    #[test]
    fn threshold_sized_input_exercises_raw_output_writes() {
        // Exactly 2 * MIN_CHUNK: the smallest input that takes the
        // parallel path, so `cargo miri test softmax::parallel::` can
        // validate every OutPtr disjoint-write at tolerable cost.
        let x = logits(2 * MIN_CHUNK, 5, 6.0);
        let mut y_par = vec![0.0; x.len()];
        let mut y_vec = vec![0.0; x.len()];
        online(&x, &mut y_par, 4);
        vectorized::online(&x, &mut y_vec);
        for (a, b) in y_par.iter().zip(&y_vec) {
            assert!((a - b).abs() <= 1e-10 + 1e-5 * b.abs(), "{a} vs {b}");
        }
        let md = online_normalizer(&x, 4);
        let serial = scalar::online_normalizer(&x);
        assert_eq!(md.m, serial.m);
        assert!((md.d - serial.d).abs() <= 2e-5 * serial.d);
    }
}
