//! Two-pass softmax normalizer with **stored per-stripe partials** —
//! the Dukhan & Ablavatski formulation (arXiv 2001.04438), adapted to
//! this crate's `(m, d)` monoid.
//!
//! The paper shows that on wide vectors a two-pass scheme can beat both
//! the classical three-pass softmax *and* the online one-pass scan:
//!
//! * **Pass 1** sweeps the input once in [`STRIPE`]-element stripes.
//!   Each stripe computes its own `(m_s, d_s = Σ e^{x − m_s})` with
//!   wide-lane SIMD and **no serial dependency on any other stripe** —
//!   unlike [`vectorized::online_normalizer`], whose per-block ⊕ fold
//!   chains every block through the running accumulator.  The partials
//!   are *stored* (a few bytes per 2 KiB of input), which is what the
//!   paper means by "two-pass with stored partials".
//! * **Pass 2** reads only the stored partials: `m = max_s m_s`,
//!   `d = Σ_s d_s · e^{m_s − m}`.  O(n / STRIPE) work, exact `exp` —
//!   no third sweep over the input, no full-softmax rematerialization.
//!
//! The expensive inner loops are software-pipelined over **two
//! independent accumulator banks** of [`LANES`] lanes each
//! ([`vectorized::expsum`] uses one): consecutive 2·LANES chunks feed
//! alternating banks, halving the length of every floating-point
//! add/max dependency chain so the FMA pipes stay full.
//!
//! Numerics match the rest of the crate: `m` is the exact running max
//! (bitwise-equal to the scalar reference), `d` agrees within fp
//! reassociation, an all-(−∞) stripe stores the ⊕ identity (never
//! `fast_exp(−∞ − −∞ = NaN)`), and NaN inputs are skipped by the max
//! and excluded from top-k selection exactly like every other kernel.

use super::fastexp::fast_exp;
use super::monoid::MD;
use super::vectorized::LANES;
use crate::topk::TopKBuffer;

/// Stripe width (f32 elements) for stored partials: 2 KiB per stripe,
/// comfortably L1-resident, and the same tile size as the blocked
/// online kernel so the two are comparable in the bench.
pub const STRIPE: usize = 512;

/// Pass-1 kernel over one stripe: `(m_s, d_s = Σ e^{x − m_s})`.
///
/// Two banked sub-passes (max, then exp/accumulate), each
/// software-pipelined over two independent [`LANES`]-wide accumulator
/// banks.  The stripe is read twice, but from L1 — DRAM sees it once.
///
/// An all-(−∞) stripe returns [`MD::IDENTITY`]: running the exp pass
/// with `m_s = −∞` would evaluate `fast_exp(−∞ − −∞ = NaN)`, which
/// saturates to e^88 and poisons `d` (the exact regression the
/// streaming kernel once had).
#[inline]
pub fn stripe_partial(stripe: &[f32]) -> MD {
    let mut max_a = [f32::NEG_INFINITY; LANES];
    let mut max_b = [f32::NEG_INFINITY; LANES];
    let mut chunks = stripe.chunks_exact(2 * LANES);
    for c in &mut chunks {
        for l in 0..LANES {
            max_a[l] = max_a[l].max(c[l]);
            max_b[l] = max_b[l].max(c[LANES + l]);
        }
    }
    let mut m = f32::NEG_INFINITY;
    for l in 0..LANES {
        m = m.max(max_a[l]).max(max_b[l]);
    }
    for &v in chunks.remainder() {
        m = m.max(v);
    }
    if m == f32::NEG_INFINITY {
        return MD::IDENTITY; // all-padding stripe stores the ⊕ identity
    }

    let mut sum_a = [0.0f32; LANES];
    let mut sum_b = [0.0f32; LANES];
    let mut chunks = stripe.chunks_exact(2 * LANES);
    for c in &mut chunks {
        for l in 0..LANES {
            sum_a[l] += fast_exp(c[l] - m);
            sum_b[l] += fast_exp(c[LANES + l] - m);
        }
    }
    let mut d = 0.0f32;
    for l in 0..LANES {
        d += sum_a[l] + sum_b[l];
    }
    for &v in chunks.remainder() {
        d += fast_exp(v - m);
    }
    MD { m, d }
}

/// Pass 1 over a whole vector: append one stored partial per
/// [`STRIPE`]-element stripe (final stripe may be shorter) to `parts`.
#[inline]
pub fn stripe_partials_into(x: &[f32], parts: &mut Vec<MD>) {
    parts.reserve(x.len().div_ceil(STRIPE));
    for stripe in x.chunks(STRIPE) {
        parts.push(stripe_partial(stripe));
    }
}

/// Pass 2: rescale stored partials into the global `(m, d)`.
///
/// `m = max_s m_s` is exact; `d = Σ_s d_s · e^{m_s − m}` uses the
/// *exact* `exp` (one call per stripe, off the hot path) so the only
/// approximation left in `d` is pass 1's `fast_exp` — the same budget
/// as every other kernel in the crate.  Identity partials (all-padding
/// stripes) contribute nothing; all-identity input returns the
/// identity.
#[inline]
pub fn rescale(parts: &[MD]) -> MD {
    let mut m = f32::NEG_INFINITY;
    for p in parts {
        m = m.max(p.m);
    }
    if m == f32::NEG_INFINITY {
        return MD::IDENTITY;
    }
    let mut d = 0.0f32;
    for p in parts {
        if p.m != f32::NEG_INFINITY {
            d += p.d * (p.m - m).exp();
        }
    }
    MD { m, d }
}

/// The full two-pass normalizer: stored-partials pass 1 + rescale.
pub fn normalizer(x: &[f32]) -> MD {
    let mut parts = Vec::new();
    stripe_partials_into(x, &mut parts);
    rescale(&parts)
}

/// Fused two-pass shard scan: pass 1 additionally feeds each stripe's
/// elements through the top-k candidate buffer **while the stripe is
/// still L1-hot**, so the input is read from DRAM exactly once even for
/// fused softmax+top-k queries — no third sweep.  Candidate indices are
/// globalized by `base`; NaN never enters the buffer and ties keep the
/// earliest global index ([`TopKBuffer::push`] semantics, identical to
/// [`crate::topk::scan_topk`]).
///
/// `k` must be > 0 (asserted by [`TopKBuffer::new`]), matching the
/// other fused scans.
pub fn fused_partial(x: &[f32], k: usize, base: i64) -> (MD, TopKBuffer) {
    let mut topk = TopKBuffer::new(k);
    let mut parts = Vec::with_capacity(x.len().div_ceil(STRIPE));
    for (s, stripe) in x.chunks(STRIPE).enumerate() {
        parts.push(stripe_partial(stripe));
        let stripe_base = base + (s * STRIPE) as i64;
        for (j, &v) in stripe.iter().enumerate() {
            topk.push(v, stripe_base + j as i64);
        }
    }
    (rescale(&parts), topk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::{scalar, vectorized};
    use crate::topk::scan_topk;

    fn logits(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        crate::rng::Xoshiro256pp::seed_from_u64(seed).logits(n, scale)
    }

    #[test]
    fn normalizer_matches_scalar_across_lengths() {
        // Sub-stripe, exact-stripe, ragged, multi-stripe, and
        // sub-pipeline (< 2·LANES) lengths all hit distinct code paths.
        for n in [1usize, 7, 15, 16, 31, 32, 33, 100, 511, 512, 513, 1024, 4097] {
            let x = logits(n, n as u64, 9.0);
            let a = normalizer(&x);
            let b = scalar::online_normalizer(&x);
            assert_eq!(a.m, b.m, "n={n}");
            assert!((a.d - b.d).abs() <= 2e-5 * b.d.max(1.0), "n={n}: {a:?} vs {b:?}");
        }
    }

    #[test]
    fn normalizer_matches_blocked_vectorized() {
        for seed in 0..8 {
            let x = logits(3000, seed, 14.0);
            let a = normalizer(&x);
            let b = vectorized::online_normalizer(&x);
            assert_eq!(a.m, b.m);
            assert!((a.d - b.d).abs() <= 2e-5 * b.d.max(1.0), "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn empty_and_all_padding_reduce_to_identity() {
        assert!(normalizer(&[]).is_identity());
        for n in [1usize, 15, STRIPE, STRIPE + 9, 3 * STRIPE] {
            let pad = vec![f32::NEG_INFINITY; n];
            assert!(normalizer(&pad).is_identity(), "n={n}");
            assert!(stripe_partial(&pad[..n.min(STRIPE)]).is_identity());
        }
    }

    #[test]
    fn mixed_padding_keeps_d_finite_and_m_exact() {
        // Interleave −∞ padding and make an entire interior stripe
        // padding: the stored partial for it must be the identity, and
        // the rescale must skip it without perturbing d.
        let mut x = logits(4 * STRIPE, 21, 8.0);
        for i in (0..x.len()).step_by(11) {
            x[i] = f32::NEG_INFINITY;
        }
        x[STRIPE..2 * STRIPE].fill(f32::NEG_INFINITY);
        let a = normalizer(&x);
        let b = vectorized::online_normalizer(&x);
        assert_eq!(a.m, b.m);
        assert!(a.d.is_finite());
        assert!((a.d - b.d).abs() <= 2e-5 * b.d.max(1.0), "{a:?} vs {b:?}");
    }

    #[test]
    fn nan_inputs_never_become_the_max() {
        let mut x = logits(700, 3, 6.0);
        x[5] = f32::NAN;
        x[600] = f32::NAN;
        let a = normalizer(&x);
        assert!(!a.m.is_nan());
        assert_eq!(a.m, scalar::online_normalizer(&x).m);
    }

    #[test]
    fn stored_partials_agree_with_per_stripe_reference() {
        let x = logits(5 * STRIPE + 77, 12, 10.0);
        let mut parts = Vec::new();
        stripe_partials_into(&x, &mut parts);
        assert_eq!(parts.len(), x.len().div_ceil(STRIPE));
        for (p, stripe) in parts.iter().zip(x.chunks(STRIPE)) {
            let r = scalar::online_normalizer(stripe);
            assert_eq!(p.m, r.m);
            assert!((p.d - r.d).abs() <= 2e-5 * r.d.max(1.0));
        }
        // rescale ≡ ⊕-fold of the same partials (m exact, d within fp).
        let folded = parts.iter().fold(MD::IDENTITY, |acc, &p| acc.combine(p));
        let rescaled = rescale(&parts);
        assert_eq!(folded.m, rescaled.m);
        assert!((folded.d - rescaled.d).abs() <= 1e-5 * folded.d.max(1.0));
    }

    #[test]
    fn fused_partial_selects_single_sweep_indices() {
        for n in [16usize, 100, 512, 513, 2048, 4097] {
            let x = logits(n, 1000 + n as u64, 7.0);
            let (md, topk) = fused_partial(&x, 5, 0);
            let reference = scan_topk(&x, 5, 0);
            assert_eq!(topk.indices(), reference.indices(), "n={n}");
            assert_eq!(md.m, normalizer(&x).m, "n={n}");
        }
    }

    #[test]
    fn fused_partial_globalizes_indices_per_stripe() {
        let x = logits(2 * STRIPE + 10, 4, 7.0);
        let base = 10_000i64;
        let (_, topk) = fused_partial(&x, 4, base);
        let reference = scan_topk(&x, 4, base);
        assert_eq!(topk.indices(), reference.indices());
        assert!(topk.indices().iter().all(|&i| i >= base));
    }
}
