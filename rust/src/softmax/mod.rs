//! The paper's softmax algorithms (Algorithms 1–4) in every form the
//! evaluation needs.
//!
//! * [`scalar`] — pseudocode-faithful loops (semantic reference).
//! * [`vectorized`] — lane-parallel single-thread kernels over
//!   [`fastexp`] (the CPU stand-in for the GPU's SFU `exp`).
//! * [`parallel`] — multithreaded ⊕-reduction (§3.1).
//! * [`fused`] — Algorithm 4 and the unfused/safe-fused baselines.
//! * [`batched`] — pass-major whole-batch forms matching the paper's
//!   GPU execution model (every pass streams the full batch).
//! * [`twopass`] — the stored-partials two-pass normalizer (Dukhan &
//!   Ablavatski, arXiv 2001.04438) behind the `twopass` shard backend.
//! * [`monoid`] — the `(m, d)` ⊕ monoid itself.
//!
//! [`compute`]/[`compute_batch`] are the convenience entry points used
//! by the examples and the serving fallback path.
//!
//! One level up, [`crate::shard`] applies the same ⊕ merge across
//! **vocabulary shards** on a worker pool: [`fused::fused_partial`] is
//! the per-shard leaf, and the coordinator routes requests whose
//! vocabulary meets `shard_threshold` onto that engine, falling back to
//! [`compute`]/[`fused::online_topk`] below it (where the single-thread
//! kernels are bitwise-identical and dispatch-free).

pub mod batched;
pub mod fastexp;
pub mod fused;
pub mod monoid;
pub mod parallel;
pub mod scalar;
pub mod twopass;
pub mod vectorized;

pub use monoid::MD;

/// Which softmax algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Algorithm 1: two passes, numerically unsafe.
    Naive,
    /// Algorithm 2: three passes, the framework default.
    Safe,
    /// Algorithm 3: single-pass online normalizer — the paper.
    Online,
}

impl Algorithm {
    pub const ALL: [Algorithm; 3] = [Algorithm::Naive, Algorithm::Safe, Algorithm::Online];

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Naive => "naive",
            Algorithm::Safe => "safe",
            Algorithm::Online => "online",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "naive" => Some(Algorithm::Naive),
            "safe" => Some(Algorithm::Safe),
            "online" => Some(Algorithm::Online),
            _ => None,
        }
    }

    /// Memory accesses per input element (the paper's §2–3 accounting).
    pub fn accesses_per_element(self) -> u32 {
        match self {
            Algorithm::Naive => 3,
            Algorithm::Safe => 4,
            Algorithm::Online => 3,
        }
    }

    /// Number of passes over the input vector.
    pub fn passes(self) -> u32 {
        match self {
            Algorithm::Naive => 2,
            Algorithm::Safe => 3,
            Algorithm::Online => 2,
        }
    }
}

/// Softmax over one vector using the vectorized kernel for `algo`.
pub fn compute(x: &[f32], algo: Algorithm) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    compute_into(x, &mut out, algo);
    out
}

/// In-place variant of [`compute`].
pub fn compute_into(x: &[f32], out: &mut [f32], algo: Algorithm) {
    match algo {
        Algorithm::Naive => vectorized::naive(x, out),
        Algorithm::Safe => vectorized::safe(x, out),
        Algorithm::Online => vectorized::online(x, out),
    }
}

/// Batched softmax over row-major `(batch, v)` data.
pub fn compute_batch(x: &[f32], v: usize, algo: Algorithm, out: &mut [f32]) {
    assert!(v > 0 && x.len() % v == 0, "x must be (batch, v) row-major");
    assert_eq!(x.len(), out.len());
    for (row_in, row_out) in x.chunks_exact(v).zip(out.chunks_exact_mut(v)) {
        compute_into(row_in, row_out, algo);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn access_counts_match_paper() {
        assert_eq!(Algorithm::Naive.accesses_per_element(), 3);
        assert_eq!(Algorithm::Safe.accesses_per_element(), 4);
        assert_eq!(Algorithm::Online.accesses_per_element(), 3);
        assert_eq!(Algorithm::Safe.passes(), 3);
        assert_eq!(Algorithm::Online.passes(), 2);
    }

    #[test]
    fn parse_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("bogus"), None);
    }

    #[test]
    fn compute_batch_rows_independent() {
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(1);
        let v = 64;
        let x = rng.logits(4 * v, 5.0);
        let mut batched = vec![0.0; x.len()];
        compute_batch(&x, v, Algorithm::Online, &mut batched);
        for (i, row) in x.chunks_exact(v).enumerate() {
            let single = compute(row, Algorithm::Online);
            assert_eq!(&batched[i * v..(i + 1) * v], &single[..], "row {i}");
        }
    }

    #[test]
    #[should_panic(expected = "row-major")]
    fn compute_batch_rejects_ragged() {
        let mut out = vec![0.0; 10];
        compute_batch(&[0.0; 10], 3, Algorithm::Safe, &mut out);
    }
}
