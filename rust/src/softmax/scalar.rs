//! Scalar (one-element-at-a-time) renditions of Algorithms 1–3 —
//! faithful to the paper's pseudocode, used as the semantic reference
//! for the optimized paths and as the per-element cost baseline in the
//! benches.
//!
//! Memory accesses per element (the paper's accounting, §2–3):
//!
//! | algorithm | loads | stores | total |
//! |-----------|-------|--------|-------|
//! | naive     | 2     | 1      | 3     |
//! | safe      | 3     | 1      | 4     |
//! | online    | 2     | 1      | 3     |

use super::monoid::MD;

/// Algorithm 1 — naive softmax.  Two passes; overflows for |x| ≳ 88.7.
pub fn naive(x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    // pass 1: d_V = Σ e^{x_j}
    let mut d = 0.0f32;
    for &v in x {
        d += v.exp();
    }
    // pass 2: y_i = e^{x_i} / d_V
    let inv = 1.0 / d;
    for (y, &v) in out.iter_mut().zip(x) {
        *y = v.exp() * inv;
    }
}

/// Algorithm 2 — safe softmax.  Three passes.
pub fn safe(x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    // pass 1: m_V = max x
    let mut m = f32::NEG_INFINITY;
    for &v in x {
        m = m.max(v);
    }
    // pass 2: d_V = Σ e^{x_j − m}
    let mut d = 0.0f32;
    for &v in x {
        d += (v - m).exp();
    }
    // pass 3: y_i = e^{x_i − m} / d
    let inv = 1.0 / d;
    for (y, &v) in out.iter_mut().zip(x) {
        *y = (v - m).exp() * inv;
    }
}

/// Lines 1–6 of Algorithm 3: the single-pass online normalizer.
pub fn online_normalizer(x: &[f32]) -> MD {
    let mut acc = MD::IDENTITY;
    for &v in x {
        acc = acc.push(v);
    }
    acc
}

/// Algorithm 3 — online softmax.  Two passes (normalizer + scale).
pub fn online(x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let MD { m, d } = online_normalizer(x);
    let inv = 1.0 / d;
    for (y, &v) in out.iter_mut().zip(x) {
        *y = (v - m).exp() * inv;
    }
}

/// Safe normalizer (passes 1–2 of Algorithm 2) — for comparing the two
/// normalizer formulations directly (they are equal by Theorem 1).
pub fn safe_normalizer(x: &[f32]) -> MD {
    let mut m = f32::NEG_INFINITY;
    for &v in x {
        m = m.max(v);
    }
    if m == f32::NEG_INFINITY {
        return MD::IDENTITY;
    }
    let mut d = 0.0f32;
    for &v in x {
        d += (v - m).exp();
    }
    MD { m, d }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &[f32], b: &[f32], rtol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let tol = rtol * x.abs().max(y.abs()).max(1e-30);
            assert!((x - y).abs() <= tol, "idx {i}: {x} vs {y}");
        }
    }

    fn logits(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        crate::rng::Xoshiro256pp::seed_from_u64(seed).logits(n, scale)
    }

    #[test]
    fn all_three_agree_in_moderate_range() {
        let x = logits(501, 1, 3.0);
        let mut yn = vec![0.0; 501];
        let mut ys = vec![0.0; 501];
        let mut yo = vec![0.0; 501];
        naive(&x, &mut yn);
        safe(&x, &mut ys);
        online(&x, &mut yo);
        assert_close(&ys, &yo, 1e-5);
        assert_close(&ys, &yn, 1e-5);
    }

    #[test]
    fn probabilities_sum_to_one() {
        for scale in [0.1, 5.0, 30.0] {
            let x = logits(333, 2, scale);
            let mut y = vec![0.0; 333];
            online(&x, &mut y);
            let s: f32 = y.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "scale={scale} sum={s}");
            assert!(y.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn theorem1_safe_equals_online_normalizer() {
        for seed in 0..20 {
            let x = logits(97, seed, 15.0);
            let a = safe_normalizer(&x);
            let b = online_normalizer(&x);
            assert_eq!(a.m, b.m);
            assert!((a.d - b.d).abs() <= 1e-5 * a.d, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn naive_overflows_where_safe_survives() {
        let x = vec![100.0f32; 8];
        let mut yn = vec![0.0; 8];
        let mut ys = vec![0.0; 8];
        naive(&x, &mut yn);
        safe(&x, &mut ys);
        assert!(yn.iter().any(|v| !v.is_finite()), "naive must overflow: {yn:?}");
        assert!(ys.iter().all(|v| (v - 0.125).abs() < 1e-6), "safe stays exact: {ys:?}");
    }

    #[test]
    fn online_shift_invariant() {
        let x = logits(64, 3, 2.0);
        let shifted: Vec<f32> = x.iter().map(|v| v + 500.0).collect();
        let mut y1 = vec![0.0; 64];
        let mut y2 = vec![0.0; 64];
        online(&x, &mut y1);
        online(&shifted, &mut y2);
        // Adding 500 costs ~9 mantissa bits on the inputs themselves,
        // so invariance holds only to ~1e-3 relative — that information
        // loss happens before softmax ever runs.
        assert_close(&y1, &y2, 1e-3);
    }

    #[test]
    fn single_element() {
        let mut y = [0.0f32];
        online(&[42.0], &mut y);
        assert_eq!(y[0], 1.0);
        safe(&[-7.0], &mut y);
        assert_eq!(y[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "assertion")]
    fn mismatched_lengths_panic() {
        let mut y = [0.0f32; 2];
        online(&[1.0, 2.0, 3.0], &mut y);
    }
}
