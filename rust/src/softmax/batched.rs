//! Pass-wise batched softmax — the faithful CPU analog of the paper's
//! GPU execution model.
//!
//! The paper's benchmark launches each algorithm pass as a grid over
//! **all 4000 vectors**: every pass streams the entire batch through
//! DRAM.  Processing row-by-row on a CPU accidentally defeats this —
//! a 400 KB row stays cache-resident between its own passes, hiding
//! exactly the effect the paper measures.  The functions here iterate
//! **pass-major** over the whole `(batch, v)` matrix, so with a working
//! set ≫ LLC each pass is a genuine DRAM sweep and the access-count
//! ratios of §2–§4 become visible (see EXPERIMENTS.md §Perf L3 it. 8).
//!
//! Memory sweeps over the input matrix:
//!
//! | fn | sweeps | paper accesses/elem |
//! |---|---|---|
//! | [`naive`]  | 2 (+1 store) | 3 |
//! | [`safe`]   | 3 (+1 store) | 4 |
//! | [`online`] | 2 (+1 store) | 3 |
//! | [`safe_unfused_topk`]   | 4 + store | 5 |
//! | [`online_unfused_topk`] | 3 + store | 4 |
//! | [`safe_fused_topk`]     | 2 | 2 |
//! | [`online_fused_topk`]   | **1** | **1** |

use super::monoid::MD;
use super::{fused, vectorized};
use crate::topk::heap_topk;

fn rows(x: &[f32], v: usize) -> usize {
    assert!(v > 0 && x.len() % v == 0, "x must be (batch, v) row-major");
    x.len() / v
}

/// Algorithm 1, pass-major: sweep 1 computes every row's `d`, sweep 2
/// scales.
pub fn naive(x: &[f32], v: usize, out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let b = rows(x, v);
    let mut d = vec![0.0f32; b];
    for (r, row) in x.chunks_exact(v).enumerate() {
        d[r] = vectorized::expsum(row, 0.0);
    }
    for (r, (row, orow)) in x.chunks_exact(v).zip(out.chunks_exact_mut(v)).enumerate() {
        vectorized::scale_pass(row, orow, 0.0, 1.0 / d[r]);
    }
}

/// Algorithm 2, pass-major: max sweep, normalizer sweep, scale sweep.
pub fn safe(x: &[f32], v: usize, out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let b = rows(x, v);
    let mut m = vec![f32::NEG_INFINITY; b];
    for (r, row) in x.chunks_exact(v).enumerate() {
        m[r] = vectorized::rowmax(row);
    }
    let mut d = vec![0.0f32; b];
    for (r, row) in x.chunks_exact(v).enumerate() {
        d[r] = vectorized::expsum(row, m[r]);
    }
    for (r, (row, orow)) in x.chunks_exact(v).zip(out.chunks_exact_mut(v)).enumerate() {
        vectorized::scale_pass(row, orow, m[r], 1.0 / d[r]);
    }
}

/// Algorithm 3, pass-major: ONE fused (m, d) sweep, then the scale sweep.
pub fn online(x: &[f32], v: usize, out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let b = rows(x, v);
    let mut md = vec![MD::IDENTITY; b];
    for (r, row) in x.chunks_exact(v).enumerate() {
        md[r] = vectorized::online_normalizer(row);
    }
    for (r, (row, orow)) in x.chunks_exact(v).zip(out.chunks_exact_mut(v)).enumerate() {
        vectorized::scale_pass(row, orow, md[r].m, 1.0 / md[r].d);
    }
}

/// Batched results: per-row `(vals, idx)`.
pub type TopKBatch = Vec<(Vec<f32>, Vec<i64>)>;

/// Safe softmax then separate TopK, pass-major (the 5-access baseline):
/// 3 sweeps of softmax + full store + a 4th sweep over the stored
/// probabilities.
pub fn safe_unfused_topk(x: &[f32], v: usize, k: usize, scratch: &mut Vec<f32>) -> TopKBatch {
    scratch.resize(x.len(), 0.0);
    safe(x, v, scratch);
    scratch.chunks_exact(v).map(|row| heap_topk(row, k)).collect()
}

/// Online softmax then separate TopK (4 accesses).
pub fn online_unfused_topk(x: &[f32], v: usize, k: usize, scratch: &mut Vec<f32>) -> TopKBatch {
    scratch.resize(x.len(), 0.0);
    online(x, v, scratch);
    scratch.chunks_exact(v).map(|row| heap_topk(row, k)).collect()
}

/// Safe softmax fused with TopK, pass-major (2 sweeps): max sweep over
/// the whole matrix, then one sweep carrying `(d, topk)` per row.
pub fn safe_fused_topk(x: &[f32], v: usize, k: usize) -> TopKBatch {
    use crate::topk::TopKBuffer;
    let b = rows(x, v);
    let mut m = vec![f32::NEG_INFINITY; b];
    for (r, row) in x.chunks_exact(v).enumerate() {
        m[r] = vectorized::rowmax(row);
    }
    x.chunks_exact(v)
        .enumerate()
        .map(|(r, row)| {
            let mut buf = TopKBuffer::new(k);
            let mut d = 0.0f32;
            let mut base = 0i64;
            for blk in row.chunks(512) {
                d += vectorized::expsum(blk, m[r]);
                let blk_max = vectorized::rowmax(blk);
                let mut thr = buf.threshold();
                if blk_max > thr {
                    for (i, &xv) in blk.iter().enumerate() {
                        if xv > thr {
                            buf.push(xv, base + i as i64);
                            thr = buf.threshold();
                        }
                    }
                }
                base += blk.len() as i64;
            }
            fused::finalize(&buf, MD { m: m[r], d })
        })
        .collect()
}

/// Algorithm 4 pass-major: a single sweep per row over one matrix pass.
pub fn online_fused_topk(x: &[f32], v: usize, k: usize) -> TopKBatch {
    x.chunks_exact(v).map(|row| fused::online_topk(row, k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::scalar;

    fn logits(n: usize, seed: u64) -> Vec<f32> {
        crate::rng::Xoshiro256pp::seed_from_u64(seed).logits(n, 6.0)
    }

    fn assert_rows_close(a: &[f32], b: &[f32], rtol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= 1e-9 + rtol * x.abs().max(y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn batched_forms_match_rowwise_reference() {
        let (b, v) = (6, 333);
        let x = logits(b * v, 1);
        let mut want = vec![0.0; b * v];
        for (row, orow) in x.chunks_exact(v).zip(want.chunks_exact_mut(v)) {
            scalar::safe(row, orow);
        }
        let mut got = vec![0.0; b * v];
        safe(&x, v, &mut got);
        assert_rows_close(&got, &want, 1e-4);
        online(&x, v, &mut got);
        assert_rows_close(&got, &want, 1e-4);
        naive(&x, v, &mut got);
        assert_rows_close(&got, &want, 1e-4);
    }

    #[test]
    fn batched_topk_forms_agree() {
        let (b, v, k) = (4, 500, 5);
        let x = logits(b * v, 2);
        let mut scratch = Vec::new();
        let a = safe_unfused_topk(&x, v, k, &mut scratch);
        let c = online_unfused_topk(&x, v, k, &mut scratch);
        let d = safe_fused_topk(&x, v, k);
        let e = online_fused_topk(&x, v, k);
        for (((ra, rc), rd), re) in a.iter().zip(&c).zip(&d).zip(&e) {
            assert_eq!(ra.1, rc.1);
            assert_eq!(ra.1, rd.1);
            assert_eq!(ra.1, re.1);
        }
    }
}
