//! Branchless, auto-vectorizable `exp` approximation.
//!
//! The paper's softmax is memory-bandwidth-bound on the GPU because the
//! hardware has fast `exp` (SFU).  On CPU, `libm::expf` is a scalar call
//! that makes every softmax variant compute-bound and would mask the
//! memory-access effect Figures 1–4 measure.  This module provides the
//! CPU equivalent of the GPU SFU: a Cody–Waite range reduction plus a
//! degree-5 polynomial, written branch-free so LLVM vectorizes the
//! softmax loops (§7 of the paper: "if the original code is vectorized
//! … similar speedups could probably be expected").
//!
//! Accuracy: ≤ 3 ulp over the clamped domain [−87.3, 88.7]; inputs
//! outside saturate (no Inf/NaN), which the callers rely on for the
//! −∞-identity convention (e^{−∞} → e^{−87.3} ≈ 1e−38, annihilated by
//! the `d = 0` factor it multiplies).

/// Natural-exponential approximation, branchless.
///
/// Max relative error ≈ 2e−7 over [−87, 88] (verified in tests).
#[inline(always)]
pub fn fast_exp(x: f32) -> f32 {
    // Clamp to the exponent-arithmetic-safe domain; saturation instead
    // of Inf / negative-exponent wraparound.
    const LO: f32 = -87.0; // e^−87 ≈ 1.6e−38 (still a normal f32)
    const HI: f32 = 88.0; // e^88 ≈ 1.65e38 < f32::MAX, n ≤ 127
    let x = x.min(HI).max(LO);

    // n = round(x / ln 2) via the magic-number trick.  Adding 1.5·2^23
    // forces rounding into the mantissa, so the low bits of the float
    // ARE the integer — extracted with bit ops instead of an `as i32`
    // cast (rust's saturating float→int casts block LLVM's loop
    // vectorizer; this formulation keeps the whole function branch- and
    // cast-free).
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const MAGIC: f32 = 12_582_912.0; // 1.5 · 2^23
    let y = x * LOG2E + MAGIC;
    let n = (y.to_bits() as i32).wrapping_sub(MAGIC.to_bits() as i32);
    let nf = y - MAGIC;
    // r = x − n·ln2, split high/low for accuracy (Cody–Waite)
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    let r = (x - nf * LN2_HI) - nf * LN2_LO;

    // e^r on r ∈ [−ln2/2, ln2/2] — cephes expf minimax polynomial (deg 6).
    const C2: f32 = 5.000_000_1e-1;
    const C3: f32 = 1.666_666_5e-1;
    const C4: f32 = 4.166_579_6e-2;
    const C5: f32 = 8.333_452e-3;
    const C6: f32 = 1.398_199_9e-3;
    const C7: f32 = 1.987_569_1e-4;
    let p2 = C2 + r * (C3 + r * (C4 + r * (C5 + r * (C6 + r * C7))));
    let p = 1.0 + r + r * r * p2;

    // scale by 2^n via exponent-field arithmetic
    let bits = p.to_bits();
    let scaled = (bits as i32).wrapping_add(n << 23) as u32;
    f32::from_bits(scaled)
}

/// Vector form over a slice (LLVM vectorizes the inner loop).
#[inline]
pub fn fast_exp_slice(xs: &[f32], out: &mut [f32]) {
    assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = fast_exp(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_against_libm() {
        let mut max_rel = 0f64;
        let mut x = -87.0f32;
        while x < 88.0 {
            let approx = fast_exp(x) as f64;
            let exact = (x as f64).exp();
            let rel = ((approx - exact) / exact).abs();
            max_rel = max_rel.max(rel);
            x += 0.0137;
        }
        assert!(max_rel < 3e-7, "max relative error {max_rel}");
    }

    #[test]
    fn special_values_saturate() {
        assert!(fast_exp(f32::NEG_INFINITY) > 0.0);
        assert!(fast_exp(f32::NEG_INFINITY) < 1e-37);
        assert!(fast_exp(1000.0).is_finite());
        assert!(fast_exp(1000.0) > 1e38, "saturates at e^88 ≈ 1.65e38");
        assert_eq!(fast_exp(0.0), 1.0);
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut prev = fast_exp(-87.0);
        let mut x = -87.0f32;
        while x < 88.0 {
            let y = fast_exp(x);
            assert!(y >= prev * (1.0 - 1e-6), "non-monotone at {x}");
            prev = y;
            x += 0.01;
        }
    }

    #[test]
    fn slice_form_matches_scalar() {
        let xs: Vec<f32> = (-200..200).map(|i| i as f32 * 0.33).collect();
        let mut out = vec![0.0; xs.len()];
        fast_exp_slice(&xs, &mut out);
        for (&x, &o) in xs.iter().zip(&out) {
            assert_eq!(o, fast_exp(x));
        }
    }

    #[test]
    fn exactness_at_integer_powers_of_two_exponents() {
        // e^{n ln 2} = 2^n should be close
        for n in -10..10 {
            let x = n as f32 * std::f32::consts::LN_2;
            let rel = (fast_exp(x) - (2f32).powi(n)).abs() / (2f32).powi(n);
            assert!(rel < 1e-6, "n={n} rel={rel}");
        }
    }
}
