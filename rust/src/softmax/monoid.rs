//! The (m, d) normalizer monoid — eq. (3)–(4) of the paper (§3.1).
//!
//! `MD { m, d }` carries a running maximum and a running normalizer
//! `d = Σ e^{x_j − m}`.  [`MD::combine`] is the ⊕ operator: it is
//! associative and commutative with identity `(−∞, 0)`, which is what
//! licenses every parallel/vectorized/sharded evaluation order in this
//! crate — tile carries in the Pallas kernel, SIMD lanes in
//! [`super::vectorized`], worker threads in [`super::parallel`], and
//! vocabulary shards in the coordinator's merge.

/// Partial softmax normalizer state: running max `m` and normalizer `d`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MD {
    /// Running maximum over the elements folded so far.
    pub m: f32,
    /// Running `Σ e^{x_j − m}` over the same elements.
    pub d: f32,
}

impl MD {
    /// The ⊕ identity: zero elements folded.
    pub const IDENTITY: MD = MD { m: f32::NEG_INFINITY, d: 0.0 };

    /// State after folding a single element `x` (leaf of the ⊕ tree).
    #[inline]
    pub fn of(x: f32) -> MD {
        MD { m: x, d: 1.0 }
    }

    /// Fold one element into the state — lines 4–5 of Algorithm 3.
    ///
    /// `d_j = d_{j-1} · e^{m_{j-1} − m_j} + e^{x_j − m_j}`.
    #[inline]
    pub fn push(self, x: f32) -> MD {
        let m_new = self.m.max(x);
        // When self is the identity (m = −∞), e^{−∞ − m_new} = 0 and
        // d = 0, so the first term vanishes without special-casing —
        // UNLESS x is itself −∞ (whole-vector padding), where we keep
        // the identity-safe form below.
        let scale = exp_guard(self.m, m_new);
        MD { m: m_new, d: self.d * scale + exp_guard(x, m_new) }
    }

    /// The ⊕ operator — eq. (4).
    #[inline]
    pub fn combine(self, other: MD) -> MD {
        let m = self.m.max(other.m);
        MD { m, d: self.d * exp_guard(self.m, m) + other.d * exp_guard(other.m, m) }
    }

    /// True if no element has been folded.
    #[inline]
    pub fn is_identity(self) -> bool {
        self.m == f32::NEG_INFINITY && self.d == 0.0
    }
}

/// `e^{a − b}` with the convention `e^{−∞ − −∞} = 0` (identity merge).
///
/// IEEE gives `−∞ − −∞ = NaN`; the monoid needs that corner to act as
/// "no contribution", i.e. 0.
#[inline]
fn exp_guard(a: f32, b: f32) -> f32 {
    if a == f32::NEG_INFINITY {
        0.0
    } else {
        (a - b).exp()
    }
}

/// Tree reduction of per-element states — the parallel form of eq. (3).
///
/// Pairwise tree order also improves fp accuracy vs the sequential fold
/// (log-depth error growth), which the accuracy example measures.
pub fn tree_reduce(states: &[MD]) -> MD {
    match states.len() {
        0 => MD::IDENTITY,
        1 => states[0],
        n => {
            let (lo, hi) = states.split_at(n / 2);
            tree_reduce(lo).combine(tree_reduce(hi))
        }
    }
}

/// Sequential left fold of raw elements (lines 1–6 of Algorithm 3).
pub fn fold_slice(xs: &[f32]) -> MD {
    xs.iter().fold(MD::IDENTITY, |acc, &x| acc.push(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32, rtol: f32) -> bool {
        if a == b {
            return true;
        }
        (a - b).abs() <= rtol * a.abs().max(b.abs()).max(1e-30)
    }

    fn assert_md_close(a: MD, b: MD) {
        assert_eq!(a.m, b.m, "m mismatch: {a:?} vs {b:?}");
        assert!(close(a.d, b.d, 1e-5), "d mismatch: {a:?} vs {b:?}");
    }

    #[test]
    fn push_matches_direct_formula() {
        let xs = [1.0f32, 3.0, -2.0, 3.5, 0.0];
        let md = fold_slice(&xs);
        let m = 3.5f32;
        let d: f32 = xs.iter().map(|x| (x - m).exp()).sum();
        assert_eq!(md.m, m);
        assert!(close(md.d, d, 1e-6));
    }

    #[test]
    fn identity_laws() {
        let a = MD { m: 2.0, d: 5.0 };
        assert_md_close(a.combine(MD::IDENTITY), a);
        assert_md_close(MD::IDENTITY.combine(a), a);
        assert!(MD::IDENTITY.is_identity());
        assert!(!a.is_identity());
    }

    #[test]
    fn commutativity() {
        let a = MD { m: 1.0, d: 2.0 };
        let b = MD { m: -3.0, d: 7.0 };
        assert_md_close(a.combine(b), b.combine(a));
    }

    #[test]
    fn associativity() {
        let a = MD { m: 0.5, d: 1.5 };
        let b = MD { m: 4.0, d: 2.0 };
        let c = MD { m: -2.0, d: 9.0 };
        assert_md_close(a.combine(b).combine(c), a.combine(b.combine(c)));
    }

    #[test]
    fn tree_reduce_equals_fold() {
        let xs: Vec<f32> = (0..97).map(|i| ((i * 37) % 23) as f32 - 11.0).collect();
        let leaves: Vec<MD> = xs.iter().map(|&x| MD::of(x)).collect();
        assert_md_close(tree_reduce(&leaves), fold_slice(&xs));
    }

    #[test]
    fn paper_bound_1_le_d_le_n() {
        // §3: 1 ≤ d_j ≤ j.
        let xs: Vec<f32> = (0..1000).map(|i| ((i * 7919) % 200) as f32 - 100.0).collect();
        let mut acc = MD::IDENTITY;
        for (j, &x) in xs.iter().enumerate() {
            acc = acc.push(x);
            assert!(acc.d >= 1.0 - 1e-6, "d < 1 at j={j}");
            assert!(acc.d <= (j + 1) as f32 * (1.0 + 1e-6), "d > j at j={j}");
        }
    }

    #[test]
    fn no_overflow_at_extreme_magnitudes() {
        let md = fold_slice(&[300.0, 300.0, 300.0]);
        assert!(md.d.is_finite() && md.m == 300.0 && (md.d - 3.0).abs() < 1e-6);
        let md = fold_slice(&[-300.0, -299.0]);
        assert!(md.d.is_finite() && md.d >= 1.0);
    }

    #[test]
    fn neg_infinity_elements_are_padding() {
        // −∞ elements act as padding: no effect on (m, d).
        let a = fold_slice(&[1.0, f32::NEG_INFINITY, 2.0]);
        let b = fold_slice(&[1.0, 2.0]);
        assert_md_close(a, b);
        // all-padding stays identity
        assert!(fold_slice(&[f32::NEG_INFINITY; 4]).is_identity());
    }

    #[test]
    fn empty_tree_reduce_is_identity() {
        assert!(tree_reduce(&[]).is_identity());
    }
}
