//! Algorithm 4 — Online Softmax fused with TopK — and the baseline
//! combinations of §4/§5.2 of the paper:
//!
//! | path                       | sweeps over x | accesses/elem |
//! |----------------------------|---------------|---------------|
//! | [`safe_unfused_topk`]      | 4 (3 + topk)  | 5             |
//! | [`online_unfused_topk`]    | 3 (2 + topk)  | 4             |
//! | [`safe_fused_topk`]        | 2             | 2             |
//! | [`online_topk`] (Alg 4)    | **1**         | **1**         |
//!
//! All return `(vals, idx)` where `vals[i] = softmax(x)[idx[i]]`, sorted
//! descending — eq. (5) applied to the softmax output.

use super::fastexp::fast_exp;
use super::monoid::MD;
use super::vectorized;
use crate::topk::{heap_topk, scan_topk, TopKBuffer};

/// Result of a softmax+topk evaluation.
pub type TopKResult = (Vec<f32>, Vec<i64>);

/// Lines 17–19 of Algorithm 4: convert raw top-k logits into
/// probabilities using the final `(m, d)`.
pub fn finalize(buf: &TopKBuffer, md: MD) -> TopKResult {
    let inv = 1.0 / md.d;
    let mut vals = Vec::with_capacity(buf.k());
    let mut idx = Vec::with_capacity(buf.k());
    for (u, p) in buf.entries() {
        if p >= 0 {
            vals.push(fast_exp(u - md.m) * inv);
            idx.push(p);
        }
    }
    (vals, idx)
}

/// **Algorithm 4**, scalar-faithful: one pass keeping `(m, d)` and the
/// (K+1)-slot insertion buffer side by side.
pub fn online_topk_scalar(x: &[f32], k: usize) -> TopKResult {
    let mut md = MD::IDENTITY;
    let mut buf = TopKBuffer::new(k);
    for (j, &xj) in x.iter().enumerate() {
        // lines 6–7: online normalizer update
        md = md.push(xj);
        // lines 8–15: insertion into the candidate buffer
        buf.push(xj, j as i64);
    }
    finalize(&buf, md)
}

/// **Algorithm 4**, production path: cache-blocked online normalizer
/// (the ⊕ trick of §3.1 at tile granularity, same structure as the L1
/// Pallas kernel) with the top-k insertion riding the same single DRAM
/// sweep.  The normalizer tiles are fully vectorized; the buffer
/// insertion is the scalar tail whose cost grows with K — exactly the
/// effect §5.2's K-sweep measures.
pub fn online_topk(x: &[f32], k: usize) -> TopKResult {
    let (md, buf) = fused_partial(x, k, 0);
    finalize(&buf, md)
}

/// The single-sweep core of [`online_topk`], exposed as a shard scan:
/// one fused pass over `x` producing the partial `(m, d)` and the raw
/// top-k candidate buffer, with global indices offset by `base`.
///
/// This is the per-shard leaf of the cross-shard reduction in
/// [`crate::shard`]: each shard runs `fused_partial` over its slice of
/// the vocabulary, and the partials merge associatively (⊕ on the
/// normalizer, buffer-merge on the candidates) in any order.
pub fn fused_partial(x: &[f32], k: usize, base: i64) -> (MD, TopKBuffer) {
    const BLOCK: usize = 512;
    let mut md = MD::IDENTITY;
    let mut buf = TopKBuffer::new(k);
    let mut pos = base;
    for blk in x.chunks(BLOCK) {
        // Vectorized tile: (m_blk, d_blk), then ONE ⊕ fold (eq. 4).
        let m_blk = vectorized::rowmax(blk);
        if m_blk > f32::NEG_INFINITY {
            let d_blk = vectorized::expsum(blk, m_blk);
            md = md.combine(MD { m: m_blk, d: d_blk });
        }
        // Candidate scan, pre-filtered by the tile max we already have:
        // once the buffer warms up, the running k-th value exceeds most
        // tiles' maxima, so entire 512-element tiles are skipped for the
        // price of one compare (EXPERIMENTS.md §Perf, L1 iteration 4).
        let mut thr = buf.threshold();
        if m_blk > thr {
            for (i, &xv) in blk.iter().enumerate() {
                if xv > thr {
                    buf.push(xv, pos + i as i64);
                    thr = buf.threshold();
                }
            }
        }
        pos += blk.len() as i64;
    }
    (md, buf)
}

/// Safe softmax fused with TopK: max pass, then one pass carrying both
/// the normalizer and the candidate buffer (2 accesses/element).
pub fn safe_fused_topk(x: &[f32], k: usize) -> TopKResult {
    let m = vectorized::rowmax(x);
    if m == f32::NEG_INFINITY {
        return (Vec::new(), Vec::new());
    }
    const LANES: usize = vectorized::LANES;
    let mut lane_d = [0.0f32; LANES];
    let mut buf = TopKBuffer::new(k);
    let chunks = x.chunks_exact(LANES);
    let tail = chunks.remainder();
    let mut base = 0i64;
    let mut d_tail = 0.0f32;
    for c in chunks {
        for l in 0..LANES {
            lane_d[l] += fast_exp(c[l] - m);
        }
        for (l, &xv) in c.iter().enumerate() {
            buf.push(xv, base + l as i64);
        }
        base += LANES as i64;
    }
    for (t, &xv) in tail.iter().enumerate() {
        d_tail += fast_exp(xv - m);
        buf.push(xv, base + t as i64);
    }
    let d = lane_d.iter().sum::<f32>() + d_tail;
    finalize(&buf, MD { m, d })
}

/// Safe softmax then TopK, run separately (the framework-default path:
/// 4 + 1 = 5 accesses/element).  Materializes the full probability
/// vector like a framework softmax kernel would.
pub fn safe_unfused_topk(x: &[f32], k: usize, scratch: &mut Vec<f32>) -> TopKResult {
    scratch.resize(x.len(), 0.0);
    vectorized::safe(x, scratch);
    heap_topk(scratch, k)
}

/// Online softmax then TopK, still separate (4 accesses/element) — the
/// intermediate point the paper's §4 access-count table lists.
pub fn online_unfused_topk(x: &[f32], k: usize, scratch: &mut Vec<f32>) -> TopKResult {
    scratch.resize(x.len(), 0.0);
    vectorized::online(x, scratch);
    heap_topk(scratch, k)
}

/// Merge shard-level partials: each shard contributes its `(m, d)` and a
/// top-k buffer with *global* indices; the results combine by ⊕ and
/// buffer-merge, then finalize.  This is the coordinator's reduction.
pub fn merge_partials(parts: &[(MD, TopKBuffer)]) -> TopKResult {
    assert!(!parts.is_empty(), "merge of zero partials");
    let mut md = MD::IDENTITY;
    let mut buf = TopKBuffer::new(parts[0].1.k());
    for (part_md, part_buf) in parts {
        md = md.combine(*part_md);
        buf.merge(part_buf);
    }
    finalize(&buf, md)
}

/// Compute one shard's partial for [`merge_partials`].
pub fn shard_partial(x: &[f32], k: usize, base: i64) -> (MD, TopKBuffer) {
    (vectorized::online_normalizer(x), scan_topk(x, k, base))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::scalar;

    fn logits(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        crate::rng::Xoshiro256pp::seed_from_u64(seed).logits(n, scale)
    }

    /// Reference: full safe softmax + exact sort.
    fn reference(x: &[f32], k: usize) -> TopKResult {
        let mut y = vec![0.0; x.len()];
        scalar::safe(x, &mut y);
        let mut pairs: Vec<(f32, i64)> =
            y.iter().enumerate().map(|(i, &v)| (v, i as i64)).collect();
        pairs.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        pairs.truncate(k.min(x.len()));
        pairs.into_iter().unzip()
    }

    fn assert_result_close(a: &TopKResult, b: &TopKResult, rtol: f32) {
        assert_eq!(a.1, b.1, "indices differ");
        for (x, y) in a.0.iter().zip(&b.0) {
            assert!((x - y).abs() <= rtol * x.abs().max(*y), "{x} vs {y}");
        }
    }

    #[test]
    fn all_paths_agree_with_reference() {
        let mut scratch = Vec::new();
        for (n, k) in [(100, 5), (1000, 5), (4097, 8), (64, 1), (50, 50)] {
            let x = logits(n, (n + k) as u64, 6.0);
            let r = reference(&x, k);
            assert_result_close(&online_topk_scalar(&x, k), &r, 1e-5);
            assert_result_close(&online_topk(&x, k), &r, 1e-5);
            assert_result_close(&safe_fused_topk(&x, k), &r, 1e-5);
            assert_result_close(&safe_unfused_topk(&x, k, &mut scratch), &r, 1e-5);
            assert_result_close(&online_unfused_topk(&x, k, &mut scratch), &r, 1e-5);
        }
    }

    #[test]
    fn probabilities_descending_and_bounded() {
        let x = logits(2000, 3, 25.0);
        let (vals, idx) = online_topk(&x, 10);
        assert_eq!(vals.len(), 10);
        assert_eq!(idx.len(), 10);
        for w in vals.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(vals.iter().all(|&v| v > 0.0 && v <= 1.0));
    }

    #[test]
    fn extreme_magnitudes_safe() {
        let mut x = logits(512, 4, 3.0);
        x.iter_mut().for_each(|v| *v += 140.0);
        let (vals, _) = online_topk(&x, 5);
        assert!(vals.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn shard_merge_equals_whole() {
        let x = logits(1200, 5, 8.0);
        let k = 7;
        let whole = online_topk(&x, k);
        for shards in [2usize, 3, 5] {
            let size = x.len() / shards;
            let parts: Vec<_> = (0..shards)
                .map(|s| {
                    let lo = s * size;
                    let hi = if s + 1 == shards { x.len() } else { lo + size };
                    shard_partial(&x[lo..hi], k, lo as i64)
                })
                .collect();
            let merged = merge_partials(&parts);
            assert_eq!(merged.1, whole.1, "shards={shards}");
            for (a, b) in merged.0.iter().zip(&whole.0) {
                assert!((a - b).abs() <= 1e-5 * a.max(*b), "shards={shards}");
            }
        }
    }

    #[test]
    fn k_exceeding_v_returns_v_entries() {
        let x = logits(3, 6, 2.0);
        let (vals, idx) = online_topk(&x, 10);
        assert_eq!(vals.len(), 3);
        assert_eq!(idx.len(), 3);
        let s: f32 = vals.iter().sum();
        assert!((s - 1.0).abs() < 1e-5, "k≥V returns the whole distribution");
    }

    #[test]
    fn paper_k_sweep_stays_correct() {
        let x = logits(25_000, 8, 10.0);
        for k in [5usize, 10, 15, 30] {
            let r = reference(&x, k);
            assert_result_close(&online_topk(&x, k), &r, 1e-5);
        }
    }

    #[test]
    fn fused_partial_agrees_with_two_sweep_shard_partial() {
        let x = logits(3000, 9, 7.0);
        let k = 6;
        for (lo, hi) in [(0usize, 3000usize), (100, 1500), (513, 514), (0, 0)] {
            let (md_a, buf_a) = fused_partial(&x[lo..hi], k, lo as i64);
            let (md_b, buf_b) = shard_partial(&x[lo..hi], k, lo as i64);
            assert_eq!(md_a.m, md_b.m, "[{lo}, {hi})");
            assert!((md_a.d - md_b.d).abs() <= 2e-5 * md_b.d.max(1.0), "[{lo}, {hi})");
            assert_eq!(buf_a.indices(), buf_b.indices(), "[{lo}, {hi})");
        }
    }

    #[test]
    #[should_panic(expected = "zero partials")]
    fn empty_merge_panics() {
        merge_partials(&[]);
    }
}
