//! Paper-figure benchmark implementations.
//!
//! One function per figure/table in the paper's evaluation (§5), shared
//! by the `cargo bench` targets (`rust/benches/*.rs`, `harness = false`)
//! and the `onlinesoftmax bench` CLI:
//!
//! * [`fig1`] — softmax, large batch (paper: batch 4000, V 10→100k)
//! * [`fig2`] — softmax, small batch (batch 10)
//! * [`fig3`] — softmax+topk, large batch, K=5
//! * [`fig4`] — softmax+topk, small batch, K=5
//! * [`k_sweep`] — §5.2's fused-speedup-vs-K table (K=5/10/15/30)
//! * [`shard_ablation`] — sharded fused scan vs single-thread vs unfused
//! * [`grid_ablation`] — per-row dispatch vs the batch×shard grid
//! * [`steal_ablation`] — FIFO injector vs work-stealing deques under
//!   uniform and skewed tile costs
//! * [`backend_ablation`] — scalar (fused blocked) vs vectorized
//!   (lane-split streaming) vs twopass (stored-partials two-pass)
//!   shard scan backends across vocab sizes — the crossover sweep
//!   behind `auto` routing, with a machine-readable report via
//!   `bench --json` (the committed `BENCH_backend.json` trajectory)
//! * [`sample_ablation`] — greedy fused top-k vs seeded Gumbel-top-k
//!   sampling on the same batch×shard grid: the per-element overhead of
//!   fusing the counter-based perturbation into the single-sweep scan
//! * [`cache_fig`] — the coalescing result-cache front: cold-miss vs
//!   cache-hit QPS through the full coordinator submit/batch/reply
//!   path, with the hit rate read back from the front's counters
//!
//! **Hardware scaling** (DESIGN.md §Hardware-Adaptation): the paper's
//! batch-4000 × V-100k workloads size the *GPU's* DRAM; on this CPU we
//! scale the large-batch case to keep the working set several times the
//! last-level cache, which lands the benchmark in the same
//! bandwidth-bound regime the paper measures.  The small-batch case
//! keeps the paper's batch = 10 exactly.  Expected shape: all variants
//! tie while cache-resident; past the cache cliff the ratios approach
//! the access-count ratios (4/3 for softmax, 5/1 for fused topk).

use std::io::Write;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::benchkit::{bench, black_box, fmt_time, BenchConfig, Stats, Table};
use crate::config::{BackendKind, ServeConfig};
use crate::coordinator::{Coordinator, Payload};
use crate::exec::SchedPolicy;
use crate::rng::Xoshiro256pp;
use crate::sample::SampleSpec;
use crate::shard::{
    tree_reduce, GridPlan, ShardBackendKind, ShardEngine, ShardEngineConfig, ShardPartial,
    ShardPlan,
};
use crate::softmax::{batched, fused, parallel, vectorized};

/// CLI/bench-target options.
#[derive(Clone, Debug, Default)]
pub struct BenchOpts {
    /// Vector sizes V (None = per-figure defaults).
    pub sizes: Option<Vec<usize>>,
    /// Batch size override.
    pub batch: Option<usize>,
    /// Threads for the parallel online variant (1 = off).
    pub threads: usize,
    /// Minimal sizes and iteration budgets: the CI rot check for the
    /// bench binaries, not a measurement.
    pub smoke: bool,
    /// Append JSON-lines results to this path.
    pub json_out: Option<String>,
    /// Write a single machine-readable JSON report document to this
    /// path (`bench --json FILE`).  Unlike [`Self::json_out`]'s
    /// append-only record stream, the report is one self-describing
    /// document (schema/fig/git/records) written atomically at the end
    /// of the run — the format of the committed `BENCH_backend.json`
    /// trajectory, pinned by the `bench_json` schema test.
    pub json_report: Option<String>,
}

impl BenchOpts {
    fn emit(&self, record: &crate::json::Value) -> Result<()> {
        if let Some(path) = &self.json_out {
            let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
            writeln!(f, "{}", record.to_json())?;
        }
        Ok(())
    }
}

/// Scaled "large batch": the paper's 4000 vectors saturate a V100; this
/// keeps per-(V,batch) working sets ≥ ~8× a 32 MB LLC at the default
/// sizes so the CPU run is equally bandwidth-bound.
pub const LARGE_BATCH: usize = 512;
/// The paper's small-batch case, kept verbatim.
pub const SMALL_BATCH: usize = 10;
/// Default V sweep (the paper's x-axis, truncated to CPU-feasible time).
pub const DEFAULT_SIZES: [usize; 6] = [1_000, 4_000, 10_000, 25_000, 50_000, 100_000];
/// §5.2 uses V=25000 for the K sweep.
pub const KSWEEP_V: usize = 25_000;

fn make_batch(b: usize, v: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut data = vec![0.0f32; b * v];
    rng.fill_logits(&mut data, 6.0);
    data
}

fn row_apply<F: FnMut(&[f32])>(data: &[f32], v: usize, mut f: F) {
    for row in data.chunks_exact(v) {
        f(row);
    }
}

// ---------------------------------------------------------------------------
// Figures 1–2: softmax
// ---------------------------------------------------------------------------

struct SoftmaxRow {
    v: usize,
    naive: Stats,
    safe: Stats,
    online: Stats,
    online_mt: Option<Stats>,
}

fn softmax_figure(name: &str, batch: usize, opts: &BenchOpts) -> Result<()> {
    let sizes = opts.sizes.clone().unwrap_or_else(|| DEFAULT_SIZES.to_vec());
    let batch = opts.batch.unwrap_or(batch);
    let cfg = BenchConfig::from_env();
    println!("\n=== {name}: softmax, batch {batch} (paper: naive vs safe vs online) ===");
    let mt_header = format!("online x{}", opts.threads);
    let headers: Vec<&str> = if opts.threads > 1 {
        vec!["V", "naive", "safe", "online", &mt_header, "GB/s online", "online/safe"]
    } else {
        vec!["V", "naive", "safe", "online", "GB/s online", "online/safe"]
    };
    let mut table = Table::new(&headers);

    for &v in &sizes {
        let data = make_batch(batch, v, v as u64);
        let mut out = vec![0.0f32; batch * v];

        // Pass-major batched forms: every algorithm pass streams the
        // whole (batch, v) matrix, as the paper's GPU grid does — see
        // softmax::batched.
        let naive = bench(&cfg, || {
            batched::naive(&data, v, &mut out);
            black_box(out[0])
        });
        let safe = bench(&cfg, || {
            batched::safe(&data, v, &mut out);
            black_box(out[0])
        });
        let online = bench(&cfg, || {
            batched::online(&data, v, &mut out);
            black_box(out[0])
        });
        let online_mt = (opts.threads > 1).then(|| {
            bench(&cfg, || {
                row_apply(&data, v, |row| {
                    let o = &mut out[..v];
                    parallel::online(row, o, opts.threads);
                    black_box(o[0]);
                })
            })
        });
        let row = SoftmaxRow { v, naive, safe, online, online_mt };

        // Effective bandwidth = algorithm's touched bytes / time.
        let elems = (batch * v) as f64;
        let online_gbs = row.online.throughput_gbs(elems * 4.0 * 3.0);
        let speedup = row.safe.median / row.online.median;
        let mut cells = vec![
            row.v.to_string(),
            fmt_time(row.naive.median),
            fmt_time(row.safe.median),
            fmt_time(row.online.median),
        ];
        if let Some(mt) = &row.online_mt {
            cells.push(fmt_time(mt.median));
        }
        cells.push(format!("{online_gbs:.1}"));
        cells.push(format!("{speedup:.2}x"));
        table.row(cells);

        let mut rec = crate::json::Value::object();
        rec.set("bench", crate::json::Value::String(name.into()))
            .set("v", crate::json::Value::Number(v as f64))
            .set("batch", crate::json::Value::Number(batch as f64))
            .set("naive_s", crate::json::Value::Number(row.naive.median))
            .set("safe_s", crate::json::Value::Number(row.safe.median))
            .set("online_s", crate::json::Value::Number(row.online.median))
            .set("speedup_online_vs_safe", crate::json::Value::Number(speedup));
        opts.emit(&rec)?;
    }
    println!("{}", table.render());
    println!(
        "paper reference ({}): online/safe → ~{} once V leaves cache; naive ≈ online.",
        if batch >= 100 { "fig 1" } else { "fig 2" },
        if batch >= 100 { "1.3x" } else { "1.15x" }
    );
    Ok(())
}

/// Figure 1: softmax, large batch.
pub fn fig1(opts: &BenchOpts) -> Result<()> {
    softmax_figure("fig1", LARGE_BATCH, opts)
}

/// Figure 2: softmax, small batch (paper batch = 10).
pub fn fig2(opts: &BenchOpts) -> Result<()> {
    softmax_figure("fig2", SMALL_BATCH, opts)
}

// ---------------------------------------------------------------------------
// Figures 3–4: softmax + top-k
// ---------------------------------------------------------------------------

fn topk_figure(name: &str, batch: usize, opts: &BenchOpts) -> Result<()> {
    let sizes = opts.sizes.clone().unwrap_or_else(|| DEFAULT_SIZES.to_vec());
    let batch = opts.batch.unwrap_or(batch);
    let k = 5;
    let cfg = BenchConfig::from_env();
    println!("\n=== {name}: softmax+topk (K={k}), batch {batch} ===");
    let mut table = Table::new(&[
        "V",
        "safe unfused",
        "online unfused",
        "safe fused",
        "online fused (Alg4)",
        "fused/unfused",
    ]);
    for &v in &sizes {
        let data = make_batch(batch, v, 7 + v as u64);
        let mut scratch = Vec::new();

        let safe_unfused = bench(&cfg, || {
            black_box(batched::safe_unfused_topk(&data, v, k, &mut scratch).len())
        });
        let online_unfused = bench(&cfg, || {
            black_box(batched::online_unfused_topk(&data, v, k, &mut scratch).len())
        });
        let safe_fused = bench(&cfg, || {
            black_box(batched::safe_fused_topk(&data, v, k).len())
        });
        let online_fused = bench(&cfg, || {
            black_box(batched::online_fused_topk(&data, v, k).len())
        });

        let speedup = safe_unfused.median / online_fused.median;
        table.row(vec![
            v.to_string(),
            fmt_time(safe_unfused.median),
            fmt_time(online_unfused.median),
            fmt_time(safe_fused.median),
            fmt_time(online_fused.median),
            format!("{speedup:.2}x"),
        ]);

        let mut rec = crate::json::Value::object();
        rec.set("bench", crate::json::Value::String(name.into()))
            .set("v", crate::json::Value::Number(v as f64))
            .set("batch", crate::json::Value::Number(batch as f64))
            .set("k", crate::json::Value::Number(k as f64))
            .set("safe_unfused_s", crate::json::Value::Number(safe_unfused.median))
            .set("online_unfused_s", crate::json::Value::Number(online_unfused.median))
            .set("safe_fused_s", crate::json::Value::Number(safe_fused.median))
            .set("online_fused_s", crate::json::Value::Number(online_fused.median))
            .set("speedup_fused_vs_unfused", crate::json::Value::Number(speedup));
        opts.emit(&rec)?;
    }
    println!("{}", table.render());
    println!(
        "paper reference ({}): online-fused/safe-unfused grows with V toward {} \
         (access ratio 5/1); fusion alone ≈ 2.5x of it.",
        if batch >= 100 { "fig 3" } else { "fig 4" },
        if batch >= 100 { "~5x" } else { "1.5–2.5x" },
    );
    Ok(())
}

/// Figure 3: softmax+topk, large batch.
pub fn fig3(opts: &BenchOpts) -> Result<()> {
    topk_figure("fig3", LARGE_BATCH, opts)
}

/// Figure 4: softmax+topk, small batch.
pub fn fig4(opts: &BenchOpts) -> Result<()> {
    topk_figure("fig4", SMALL_BATCH, opts)
}

// ---------------------------------------------------------------------------
// §5.2: speedup decay as K grows
// ---------------------------------------------------------------------------

/// The paper's K-sweep: fused speedup at V=25000 for K ∈ {5,10,15,30},
/// reported as 5x → 3.5x → 2x → 1.4x on V100.
pub fn k_sweep(opts: &BenchOpts) -> Result<()> {
    let v = opts.sizes.as_ref().and_then(|s| s.first().copied()).unwrap_or(KSWEEP_V);
    let batch = opts.batch.unwrap_or(LARGE_BATCH / 4);
    let cfg = BenchConfig::from_env();
    println!("\n=== k_sweep: fused online softmax+topk speedup vs K (V={v}, batch {batch}) ===");
    let data = make_batch(batch, v, 99);
    let mut scratch = Vec::new();
    let mut table =
        Table::new(&["K", "safe unfused", "online fused", "speedup", "paper (V100)"]);
    let paper: &[(usize, &str)] = &[(5, "5x"), (10, "3.5x"), (15, "2x"), (30, "1.4x"), (64, "<1.4x")];
    for &(k, paper_x) in paper {
        let unfused = bench(&cfg, || {
            black_box(batched::safe_unfused_topk(&data, v, k, &mut scratch).len())
        });
        let fused_t = bench(&cfg, || {
            black_box(batched::online_fused_topk(&data, v, k).len())
        });
        let speedup = unfused.median / fused_t.median;
        table.row(vec![
            k.to_string(),
            fmt_time(unfused.median),
            fmt_time(fused_t.median),
            format!("{speedup:.2}x"),
            paper_x.to_string(),
        ]);
        let mut rec = crate::json::Value::object();
        rec.set("bench", crate::json::Value::String("k_sweep".into()))
            .set("v", crate::json::Value::Number(v as f64))
            .set("k", crate::json::Value::Number(k as f64))
            .set("speedup", crate::json::Value::Number(speedup));
        opts.emit(&rec)?;
    }
    println!("{}", table.render());
    println!("expected shape: monotone decay with K (insertion cost grows, §5.2).");
    Ok(())
}

// ---------------------------------------------------------------------------
// Shard ablation: the tentpole's cross-shard Algorithm 4 vs the
// single-thread fused kernel vs the unfused baseline
// ---------------------------------------------------------------------------

/// Ablation over the shard-reduction engine: for each V, fused
/// softmax+top-k as (a) the safe-unfused baseline, (b) the
/// single-thread fused Algorithm 4, and (c) the sharded fused path
/// (per-shard scans on the pool, ⊕ tree reduction).  Reports effective
/// throughput so the sharded arm's scaling is directly visible.
pub fn shard_ablation(opts: &BenchOpts) -> Result<()> {
    let sizes = opts
        .sizes
        .clone()
        .unwrap_or_else(|| vec![25_000, 100_000, 400_000, 1_000_000]);
    let k = 5;
    // threads is literal (1 = single shard worker, reproducible
    // baseline); 0 means one worker per core.
    let workers = if opts.threads == 0 { crate::exec::default_threads() } else { opts.threads };
    let cfg = BenchConfig::from_env();
    let engine = ShardEngine::new(ShardEngineConfig {
        workers,
        min_shard: 4096,
        threshold: 1, // the bench pins plans explicitly
        ..ShardEngineConfig::default()
    });
    println!(
        "\n=== ablation: sharded fused softmax+topk (K={k}, {workers} shard workers) ==="
    );
    let mut table = Table::new(&[
        "V",
        "safe unfused",
        "online fused x1",
        "sharded fused",
        "shards",
        "fused/unfused",
        "shard/x1",
        "GB/s shard",
    ]);
    for &v in &sizes {
        let mut rng = Xoshiro256pp::seed_from_u64(v as u64);
        let x = rng.logits(v, 6.0);
        let plan = ShardPlan::auto(v, workers, 4096);
        let mut scratch = Vec::new();

        let unfused = bench(&cfg, || {
            black_box(fused::safe_unfused_topk(&x, k, &mut scratch).1.len())
        });
        let single = bench(&cfg, || black_box(fused::online_topk(&x, k).1.len()));
        let sharded = bench(&cfg, || {
            black_box(engine.fused_topk_planned(&x, k, &plan).1.len())
        });

        let fused_speedup = unfused.median / single.median;
        let shard_speedup = single.median / sharded.median;
        let gbs = sharded.throughput_gbs(v as f64 * 4.0);
        table.row(vec![
            v.to_string(),
            fmt_time(unfused.median),
            fmt_time(single.median),
            fmt_time(sharded.median),
            plan.shards().to_string(),
            format!("{fused_speedup:.2}x"),
            format!("{shard_speedup:.2}x"),
            format!("{gbs:.1}"),
        ]);

        let mut rec = crate::json::Value::object();
        rec.set("bench", crate::json::Value::String("shard_ablation".into()))
            .set("v", crate::json::Value::Number(v as f64))
            .set("k", crate::json::Value::Number(k as f64))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set("shards", crate::json::Value::Number(plan.shards() as f64))
            .set("safe_unfused_s", crate::json::Value::Number(unfused.median))
            .set("online_fused_s", crate::json::Value::Number(single.median))
            .set("sharded_fused_s", crate::json::Value::Number(sharded.median))
            .set("speedup_shard_vs_single", crate::json::Value::Number(shard_speedup));
        opts.emit(&rec)?;
    }
    println!("{}", table.render());
    println!(
        "expected shape: sharding pays once V·4B leaves the per-core cache; below\n\
         that the single-thread fused kernel wins on dispatch overhead (the\n\
         coordinator's shard_threshold encodes exactly this crossover)."
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Grid ablation: per-row dispatch vs the batch×shard grid
// ---------------------------------------------------------------------------

/// Ablation over the batch×shard grid scheduler: a batch of B rows of
/// length V, fused softmax+top-k, executed as (a) **per-row dispatch**
/// — B sequential 1×S fan-out/join cycles, the pool draining between
/// rows — and (b) **one B×S grid** — every tile submitted in a single
/// scoped dispatch, per-row ⊕ reductions overlapping later rows'
/// scans.  Both arms run identical tile shapes and kernels (results
/// are bitwise-identical); the delta is pure scheduling.
pub fn grid_ablation(opts: &BenchOpts) -> Result<()> {
    let sizes = opts.sizes.clone().unwrap_or_else(|| vec![50_000, 200_000, 800_000]);
    let batch = opts.batch.unwrap_or(16);
    let k = 5;
    // Unlike shard_ablation (where 1 worker is a meaningful serial
    // baseline), a 1-worker engine runs BOTH arms inline and the
    // comparison degenerates to ~1.00x — so the CLI default of
    // `--threads 1` upgrades to one worker per core here; pass
    // `--threads N` (N ≥ 2) to pin an explicit pool width.
    let workers =
        if opts.threads <= 1 { crate::exec::default_threads() } else { opts.threads };
    let cfg = BenchConfig::from_env();
    let engine = ShardEngine::new(ShardEngineConfig {
        workers,
        min_shard: 4096,
        threshold: 1, // the bench pins plans explicitly
        ..ShardEngineConfig::default()
    });
    println!(
        "\n=== grid: per-row dispatch vs batch×shard grid \
         (K={k}, batch {batch}, {workers} shard workers) ==="
    );
    let mut table = Table::new(&[
        "V",
        "per-row dispatch",
        "grid dispatch",
        "tiles",
        "grid/per-row",
        "GB/s grid",
    ]);
    let mut report_records: Vec<crate::json::Value> = Vec::new();
    for &v in &sizes {
        let data = make_batch(batch, v, v as u64);
        let rows: Vec<&[f32]> = data.chunks_exact(v).collect();
        let plan = ShardPlan::auto(v, workers, 4096);
        let grid = GridPlan::new(batch, plan);

        let per_row = bench(&cfg, || {
            let mut selected = 0usize;
            for r in &rows {
                selected += engine.fused_topk_planned(r, k, &plan).1.len();
            }
            black_box(selected)
        });
        let grid_t = bench(&cfg, || {
            black_box(engine.fused_topk_batch_planned(&rows, k, &grid).len())
        });

        let speedup = per_row.median / grid_t.median;
        let gbs = grid_t.throughput_gbs((batch * v) as f64 * 4.0);
        table.row(vec![
            v.to_string(),
            fmt_time(per_row.median),
            fmt_time(grid_t.median),
            format!("{}x{}", grid.rows(), grid.shards_per_row()),
            format!("{speedup:.2}x"),
            format!("{gbs:.1}"),
        ]);

        let mut rec = crate::json::Value::object();
        rec.set("bench", crate::json::Value::String("grid_ablation".into()))
            .set("v", crate::json::Value::Number(v as f64))
            .set("batch", crate::json::Value::Number(batch as f64))
            .set("k", crate::json::Value::Number(k as f64))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set("shards_per_row", crate::json::Value::Number(plan.shards() as f64))
            .set("per_row_s", crate::json::Value::Number(per_row.median))
            .set("grid_s", crate::json::Value::Number(grid_t.median))
            .set("speedup_grid_vs_per_row", crate::json::Value::Number(speedup));
        report_records.push(rec.clone());
        opts.emit(&rec)?;
    }
    println!("{}", table.render());
    if let Some(path) = &opts.json_report {
        let mut report = crate::json::Value::object();
        report
            .set("schema", crate::json::Value::String("osmax.bench.grid.v1".into()))
            .set("fig", crate::json::Value::String("grid".into()))
            .set("git", crate::json::Value::String(git_describe()))
            .set("smoke", crate::json::Value::Bool(opts.smoke))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set("records", crate::json::Value::Array(report_records));
        std::fs::write(path, report.to_json() + "\n")?;
        println!("wrote grid report → {path}");
    }
    println!(
        "expected shape: the grid wins whenever per-row join gaps leave workers\n\
         idle — widest at small V·shards (join overhead dominates) and at\n\
         batch ≫ workers; the arms converge as single rows already saturate\n\
         the pool."
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Steal ablation: FIFO injector vs work-stealing deques
// ---------------------------------------------------------------------------

/// Ablation over the pool scheduler ([`SchedPolicy`]): the same
/// batch×shard fused softmax+top-k grid executed on a `fifo` engine
/// (single shared injector) and a `steal` engine (per-worker deques,
/// LIFO owner pop, FIFO steal), under two tile-cost shapes:
///
/// * **uniform** — every tile scans its slice once; the balanced plan
///   makes all tile costs (near-)equal.  This is the no-regression
///   guard: stealing must not cost anything when there is nothing to
///   rebalance.
/// * **skewed** — the plan is deliberately ragged (a shard count that
///   does not divide V) *and* tile 0 of every row is a straggler,
///   re-scanning its slice `SKEW`× (standing in for a cache-cold /
///   NUMA-far / frequency-throttled shard).  Under FIFO the straggler
///   pins its worker while the queue behind it drains unevenly; under
///   steal the idle workers lift the pinned worker's remaining tiles
///   from the far end of its deque.
///
/// Both arms run identical tile shapes and kernels, so results are
/// bitwise-identical (asserted here on every iteration's output
/// length); the delta is pure scheduling.  Reports p50 per arm.
pub fn steal_ablation(opts: &BenchOpts) -> Result<()> {
    let sizes = opts.sizes.clone().unwrap_or_else(|| {
        if opts.smoke {
            vec![8_192]
        } else {
            vec![50_000, 200_000]
        }
    });
    let batch = opts.batch.unwrap_or(if opts.smoke { 3 } else { 16 });
    let k = 5;
    // Straggler rescan factor for the skewed arm.
    const SKEW: usize = 8;
    // Like grid_ablation: a 1-worker engine runs everything inline and
    // the policies are indistinguishable, so upgrade the CLI default.
    let workers =
        if opts.threads <= 1 { crate::exec::default_threads() } else { opts.threads };
    let cfg = BenchConfig::from_env();
    let mk_engine = |sched| {
        ShardEngine::new(ShardEngineConfig {
            workers,
            min_shard: 1,
            threshold: 1, // the bench pins plans explicitly
            sched,
            ..ShardEngineConfig::default()
        })
    };
    let fifo = mk_engine(SchedPolicy::Fifo);
    let steal = mk_engine(SchedPolicy::Steal);
    // Oversubscribe (~2 tiles per worker per row) so a straggler's
    // owner has a backlog worth stealing, and pick an odd shard count
    // so the last tile of every row is ragged.
    let shards_per_row = (workers * 2 + 1).max(3);
    println!(
        "\n=== steal: fifo injector vs work-stealing deques \
         (K={k}, batch {batch}, {workers} workers, {shards_per_row} shards/row, \
         straggler x{SKEW}) ==="
    );
    let mut table = Table::new(&[
        "V",
        "cost shape",
        "fifo p50",
        "steal p50",
        "steal/fifo",
        "steals",
    ]);
    let mut report_records: Vec<crate::json::Value> = Vec::new();
    for &v in &sizes {
        let data = make_batch(batch, v, v as u64);
        let rows: Vec<&[f32]> = data.chunks_exact(v).collect();
        let plan = ShardPlan::with_shards(v, shards_per_row);
        let grid = GridPlan::new(batch, plan);

        // One grid dispatch; under `skew`, tile 0 of each row re-scans
        // its slice (identical partial, skewed cost).
        let run = |engine: &ShardEngine, skew: usize| -> Vec<(Vec<f32>, Vec<i64>)> {
            engine.grid_map(
                &grid,
                |tile| {
                    let x = &rows[tile.row][tile.range.start..tile.range.end];
                    let reps = if tile.range.index == 0 { skew } else { 1 };
                    let mut part = ShardPartial::scan(x, k, tile.range.start as i64);
                    for _ in 1..reps {
                        part = ShardPartial::scan(x, k, tile.range.start as i64);
                    }
                    part
                },
                |_row, parts| tree_reduce(parts).finalize(),
            )
        };

        for (shape, skew) in [("uniform", 1usize), ("skewed", SKEW)] {
            // The scheduler must never change a result.
            assert_eq!(
                run(&fifo, skew),
                run(&steal, skew),
                "fifo and steal outputs diverged (v={v}, {shape})"
            );
            let steals_before = steal.pool_steal_count();
            let fifo_t = bench(&cfg, || black_box(run(&fifo, skew).len()));
            let steal_t = bench(&cfg, || black_box(run(&steal, skew).len()));
            let stolen = steal.pool_steal_count() - steals_before;
            let speedup = fifo_t.median / steal_t.median;
            table.row(vec![
                v.to_string(),
                shape.to_string(),
                fmt_time(fifo_t.median),
                fmt_time(steal_t.median),
                format!("{speedup:.2}x"),
                stolen.to_string(),
            ]);

            let mut rec = crate::json::Value::object();
            rec.set("bench", crate::json::Value::String("steal_ablation".into()))
                .set("v", crate::json::Value::Number(v as f64))
                .set("batch", crate::json::Value::Number(batch as f64))
                .set("k", crate::json::Value::Number(k as f64))
                .set("workers", crate::json::Value::Number(workers as f64))
                .set("shards_per_row", crate::json::Value::Number(shards_per_row as f64))
                .set("cost_shape", crate::json::Value::String(shape.into()))
                .set("skew", crate::json::Value::Number(skew as f64))
                .set("fifo_p50_s", crate::json::Value::Number(fifo_t.median))
                .set("steal_p50_s", crate::json::Value::Number(steal_t.median))
                .set("speedup_steal_vs_fifo", crate::json::Value::Number(speedup))
                .set("steals", crate::json::Value::Number(stolen as f64));
            report_records.push(rec.clone());
            opts.emit(&rec)?;
        }
    }
    println!("{}", table.render());
    if let Some(path) = &opts.json_report {
        let mut report = crate::json::Value::object();
        report
            .set("schema", crate::json::Value::String("osmax.bench.steal.v1".into()))
            .set("fig", crate::json::Value::String("steal".into()))
            .set("git", crate::json::Value::String(git_describe()))
            .set("smoke", crate::json::Value::Bool(opts.smoke))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set("records", crate::json::Value::Array(report_records));
        std::fs::write(path, report.to_json() + "\n")?;
        println!("wrote steal report → {path}");
    }
    println!(
        "expected shape: ~1.00x on uniform costs (stealing has nothing to\n\
         rebalance and must not regress); > 1x on the skewed arm, growing with\n\
         the straggler factor — idle workers drain the pinned worker's deque\n\
         instead of waiting out the longest tile."
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Backend ablation: scalar vs vectorized vs twopass per-tile scans
// ---------------------------------------------------------------------------

/// `git describe --always --dirty` for bench-report provenance;
/// `"unknown"` when git is unavailable (e.g. a source tarball).
fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Ablation over the shard-scan backend ([`ShardBackendKind`]): the
/// same batch×shard fused softmax+top-k grid executed by a `scalar`
/// engine (the fused cache-blocked scan — one ⊕ fold per 512-element
/// tile, threshold-filtered candidate insertion riding the same
/// sweep), a `vectorized` engine (the §7 lane-split streaming scan —
/// one ⊕ fold per element per lane, plus a separate candidate sweep),
/// and a `twopass` engine (Dukhan & Ablavatski stored-partials scan —
/// independent per-stripe partials with software-pipelined SIMD, exact
/// rescale from the stored partials).
///
/// All backends run identical plans and select identical indices
/// (asserted here on every size), so the delta is pure kernel choice —
/// exactly the per-ISA tuning question the related softmax work
/// (Dukhan & Ablavatski; Czaja et al.) answers per hardware target.
/// The vocab sweep is the **crossover measurement** behind
/// [`AutoBackend`](crate::shard::AutoBackend) routing: re-run with
/// `bench --fig backend --json BENCH_backend.json` after kernel or
/// hardware changes and update
/// [`TWOPASS_CROSSOVER`](crate::shard::TWOPASS_CROSSOVER) (and its
/// decision-table test) from the report.
pub fn backend_ablation(opts: &BenchOpts) -> Result<()> {
    let sizes = opts.sizes.clone().unwrap_or_else(|| {
        if opts.smoke {
            vec![8_192]
        } else {
            // ≥ 4 sizes so the committed BENCH_backend.json trajectory
            // brackets the crossover from both sides.
            vec![8_192, 25_000, 100_000, 400_000]
        }
    });
    let batch = opts.batch.unwrap_or(if opts.smoke { 3 } else { 8 });
    let k = 5;
    // Like the other scheduler/backend comparisons: a 1-worker engine
    // runs everything inline, so upgrade the CLI default.
    let workers =
        if opts.threads <= 1 { crate::exec::default_threads() } else { opts.threads };
    let cfg = BenchConfig::from_env();
    let mk = |backend| {
        ShardEngine::new(ShardEngineConfig {
            workers,
            // Tiles stay ≥ 4096 elements, so every arm's lane-geometry
            // gate passes and no arm silently measures the fallback
            // path instead of its own kernel.
            min_shard: 4096,
            threshold: 1, // the bench pins plans explicitly
            backend,
            ..ShardEngineConfig::default()
        })
    };
    // (kind, engine) arms, scalar first — it is the reference the
    // identity pin compares against.
    let arms = [
        (ShardBackendKind::Scalar, mk(ShardBackendKind::Scalar)),
        (ShardBackendKind::Vectorized, mk(ShardBackendKind::Vectorized)),
        (ShardBackendKind::TwoPass, mk(ShardBackendKind::TwoPass)),
    ];
    println!(
        "\n=== backend: scalar (fused blocked) vs vectorized (lane streaming) vs \
         twopass (stored partials) shard scans (K={k}, batch {batch}, {workers} \
         shard workers) ==="
    );
    let mut table = Table::new(&[
        "V",
        "scalar p50",
        "vectorized p50",
        "twopass p50",
        "tiles",
        "winner",
        "winner ns/el",
    ]);
    let mut report_records: Vec<crate::json::Value> = Vec::new();
    for &v in &sizes {
        let data = make_batch(batch, v, v as u64);
        let rows: Vec<&[f32]> = data.chunks_exact(v).collect();
        let plan = ShardPlan::auto(v, workers, 4096);
        let grid = GridPlan::new(batch, plan);

        // A backend must never change a *selection*: pin identical
        // indices across every arm before timing anything.
        let reference = arms[0].1.fused_topk_batch_planned(&rows, k, &grid);
        for (kind, engine) in arms.iter().skip(1) {
            let got = engine.fused_topk_batch_planned(&rows, k, &grid);
            for (row_ref, row_got) in reference.iter().zip(&got) {
                assert_eq!(
                    row_ref.1,
                    row_got.1,
                    "backend {} diverged from scalar on selected indices (v={v})",
                    kind.as_str()
                );
            }
        }

        let elems = (batch * v) as f64;
        let mut medians = [0.0f64; 3];
        for (i, (kind, engine)) in arms.iter().enumerate() {
            let t = bench(&cfg, || {
                black_box(engine.fused_topk_batch_planned(&rows, k, &grid).len())
            });
            medians[i] = t.median;
            let mut rec = crate::json::Value::object();
            rec.set("backend", crate::json::Value::String(kind.as_str().into()))
                .set("vocab", crate::json::Value::Number(v as f64))
                .set("batch", crate::json::Value::Number(batch as f64))
                .set("k", crate::json::Value::Number(k as f64))
                .set("p50_s", crate::json::Value::Number(t.median))
                .set(
                    "ns_per_element",
                    crate::json::Value::Number(t.median * 1e9 / elems),
                );
            report_records.push(rec);
        }
        let (winner_i, &winner_t) = medians
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        table.row(vec![
            v.to_string(),
            fmt_time(medians[0]),
            fmt_time(medians[1]),
            fmt_time(medians[2]),
            format!("{}x{}", grid.rows(), grid.shards_per_row()),
            arms[winner_i].0.as_str().to_string(),
            format!("{:.2}", winner_t * 1e9 / elems),
        ]);

        let mut rec = crate::json::Value::object();
        rec.set("bench", crate::json::Value::String("backend_ablation".into()))
            .set("v", crate::json::Value::Number(v as f64))
            .set("batch", crate::json::Value::Number(batch as f64))
            .set("k", crate::json::Value::Number(k as f64))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set("shards_per_row", crate::json::Value::Number(plan.shards() as f64))
            .set("scalar_p50_s", crate::json::Value::Number(medians[0]))
            .set("vectorized_p50_s", crate::json::Value::Number(medians[1]))
            .set("twopass_p50_s", crate::json::Value::Number(medians[2]))
            .set(
                "speedup_vectorized_vs_scalar",
                crate::json::Value::Number(medians[0] / medians[1]),
            )
            .set(
                "speedup_twopass_vs_scalar",
                crate::json::Value::Number(medians[0] / medians[2]),
            );
        opts.emit(&rec)?;
    }
    println!("{}", table.render());
    if let Some(path) = &opts.json_report {
        let mut report = crate::json::Value::object();
        report
            .set("schema", crate::json::Value::String("osmax.bench.backend.v1".into()))
            .set("fig", crate::json::Value::String("backend".into()))
            .set("git", crate::json::Value::String(git_describe()))
            .set("smoke", crate::json::Value::Bool(opts.smoke))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set(
                "crossover_elements",
                crate::json::Value::Number(crate::shard::TWOPASS_CROSSOVER as f64),
            )
            .set("records", crate::json::Value::Array(report_records));
        std::fs::write(path, report.to_json() + "\n")?;
        println!("wrote backend report → {path}");
    }
    println!(
        "expected shape: the streaming arm leads in the middle band (one visit,\n\
         no partial bookkeeping); past a few stored-partial stripes the twopass\n\
         arm's shorter fp dependency chains win; `auto` encodes the measured\n\
         crossover per tile (TWOPASS_CROSSOVER; see docs/BACKENDS.md and the\n\
         committed BENCH_backend.json)."
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Sampling ablation: greedy fused top-k vs seeded Gumbel-top-k
// ---------------------------------------------------------------------------

/// Overhead of fusing seeded Gumbel-top-k sampling into the
/// single-sweep scan: the same batch×shard grid runs the greedy fused
/// softmax+top-k (`fused_topk_batch_planned`) and the sampled variant
/// (`sampled_topk_batch_planned`), whose per-element extra work is one
/// counter-hash + `x/T` per candidate-threshold survivor riding the
/// existing ⊕ sweep.  Determinism is pinned before timing: two sampled
/// runs under the same seed must select bitwise-identical indices.
///
/// `bench --fig sample --json FILE` writes an `osmax.bench.sample.v1`
/// report in the `BENCH_backend.json` style so CI can rot-check the
/// figure and the overhead trajectory can be committed.
pub fn sample_ablation(opts: &BenchOpts) -> Result<()> {
    let sizes = opts.sizes.clone().unwrap_or_else(|| {
        if opts.smoke {
            vec![8_192]
        } else {
            vec![8_192, 25_000, 100_000, 400_000]
        }
    });
    let batch = opts.batch.unwrap_or(if opts.smoke { 3 } else { 8 });
    let k = 5;
    let spec = SampleSpec { seed: 0x5EED, temperature: 0.8 };
    let workers =
        if opts.threads <= 1 { crate::exec::default_threads() } else { opts.threads };
    let cfg = BenchConfig::from_env();
    let engine = ShardEngine::new(ShardEngineConfig {
        workers,
        min_shard: 4096,
        threshold: 1, // the bench pins plans explicitly
        ..ShardEngineConfig::default()
    });
    println!(
        "\n=== sample: greedy fused top-k vs seeded Gumbel-top-k sampling \
         (K={k}, T={}, batch {batch}, {workers} shard workers) ===",
        spec.temperature
    );
    let mut table = Table::new(&[
        "V",
        "greedy p50",
        "sampled p50",
        "overhead",
        "tiles",
        "sampled ns/el",
    ]);
    let mut report_records: Vec<crate::json::Value> = Vec::new();
    for &v in &sizes {
        let data = make_batch(batch, v, v as u64);
        let rows: Vec<&[f32]> = data.chunks_exact(v).collect();
        let plan = ShardPlan::auto(v, workers, 4096);
        let grid = GridPlan::new(batch, plan);

        // Sampling must never change *determinism*: pin bitwise-equal
        // selections across two runs of the same seed before timing.
        let once = engine.sampled_topk_batch_planned(&rows, k, &grid, spec);
        let twice = engine.sampled_topk_batch_planned(&rows, k, &grid, spec);
        for (a, b) in once.iter().zip(&twice) {
            assert_eq!(a.1, b.1, "sampled selection not reproducible under one seed (v={v})");
            assert!(
                a.0.iter().zip(&b.0).all(|(x, y)| x.to_bits() == y.to_bits()),
                "sampled probabilities not bitwise-reproducible (v={v})"
            );
        }

        let elems = (batch * v) as f64;
        let greedy_t = bench(&cfg, || {
            black_box(engine.fused_topk_batch_planned(&rows, k, &grid).len())
        });
        let sampled_t = bench(&cfg, || {
            black_box(engine.sampled_topk_batch_planned(&rows, k, &grid, spec).len())
        });
        let overhead = sampled_t.median / greedy_t.median;
        table.row(vec![
            v.to_string(),
            fmt_time(greedy_t.median),
            fmt_time(sampled_t.median),
            format!("{overhead:.2}x"),
            format!("{}x{}", grid.rows(), grid.shards_per_row()),
            format!("{:.2}", sampled_t.median * 1e9 / elems),
        ]);

        for (mode, t) in [("greedy", &greedy_t), ("sampled", &sampled_t)] {
            let mut rec = crate::json::Value::object();
            rec.set("mode", crate::json::Value::String(mode.into()))
                .set("vocab", crate::json::Value::Number(v as f64))
                .set("batch", crate::json::Value::Number(batch as f64))
                .set("k", crate::json::Value::Number(k as f64))
                .set(
                    "temperature",
                    crate::json::Value::Number(spec.temperature as f64),
                )
                .set("p50_s", crate::json::Value::Number(t.median))
                .set(
                    "ns_per_element",
                    crate::json::Value::Number(t.median * 1e9 / elems),
                );
            report_records.push(rec);
        }

        let mut rec = crate::json::Value::object();
        rec.set("bench", crate::json::Value::String("sample_ablation".into()))
            .set("v", crate::json::Value::Number(v as f64))
            .set("batch", crate::json::Value::Number(batch as f64))
            .set("k", crate::json::Value::Number(k as f64))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set("greedy_p50_s", crate::json::Value::Number(greedy_t.median))
            .set("sampled_p50_s", crate::json::Value::Number(sampled_t.median))
            .set("overhead_sampled_vs_greedy", crate::json::Value::Number(overhead));
        opts.emit(&rec)?;
    }
    println!("{}", table.render());
    if let Some(path) = &opts.json_report {
        let mut report = crate::json::Value::object();
        report
            .set("schema", crate::json::Value::String("osmax.bench.sample.v1".into()))
            .set("fig", crate::json::Value::String("sample".into()))
            .set("git", crate::json::Value::String(git_describe()))
            .set("smoke", crate::json::Value::Bool(opts.smoke))
            .set("workers", crate::json::Value::Number(workers as f64))
            .set("records", crate::json::Value::Array(report_records));
        std::fs::write(path, report.to_json() + "\n")?;
        println!("wrote sample report → {path}");
    }
    println!(
        "expected shape: near-1.00x overhead — the perturbation only runs on\n\
         candidates that survive the threshold fast-reject, so the sweep stays\n\
         bandwidth-bound; a growing gap means the fast-reject broke (every\n\
         element paying the counter-hash)."
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Cache figure: cold-miss vs cache-hit QPS through the coordinator
// ---------------------------------------------------------------------------

/// The coalescing result-cache front under a cache-friendly workload:
/// a small set of distinct softmax payloads driven through the *full*
/// coordinator path (submit → front → batcher → executor → reply).
///
/// Two phases over one coordinator instance:
///
/// * **cold** — each distinct payload once: every call misses, runs
///   the kernel, and populates the LRU (the front counts one miss per
///   payload).
/// * **hot** — `requests` calls cycling the same payloads: every call
///   resolves at the front without touching the batcher.
///
/// The hit rate is read back from [`Coordinator::cache_stats`] and
/// asserted, so the figure doubles as a rot check on the front: if
/// caching silently broke, the hot phase would stop hitting and the
/// run fails rather than quietly reporting kernel QPS as hit QPS.
///
/// `bench --fig cache --json FILE` writes an `osmax.bench.cache.v1`
/// report in the `BENCH_backend.json` style.
pub fn cache_fig(opts: &BenchOpts) -> Result<()> {
    let v = opts
        .sizes
        .as_ref()
        .and_then(|s| s.first().copied())
        .unwrap_or(if opts.smoke { 1_024 } else { 8_192 });
    let distinct = opts.batch.unwrap_or(8).max(1);
    let requests = if opts.smoke { 64 } else { 2_048 };
    let timeout = Duration::from_secs(30);

    let mut cfg = ServeConfig::default();
    cfg.backend = BackendKind::Host;
    cfg.vocab = v;
    cfg.hidden = 32;
    cfg.cache_capacity = distinct * 2;
    cfg.cache_coalesce = true;
    cfg.workers = if opts.threads <= 1 { 2 } else { opts.threads };
    let coord = Coordinator::start(&cfg)?;

    println!(
        "\n=== cache: result-cache front, cold miss vs hot hit \
         (V={v}, {distinct} distinct payloads, {requests} hot requests) ==="
    );
    let payloads: Vec<Vec<f32>> = (0..distinct)
        .map(|i| {
            let mut rng = Xoshiro256pp::seed_from_u64(0xCAC4E + i as u64);
            rng.logits(v, 6.0)
        })
        .collect();

    let call = |logits: Vec<f32>| -> Result<()> {
        match coord.call(Payload::Softmax { logits }, timeout) {
            Ok(_) => Ok(()),
            Err(e) => anyhow::bail!("cache-fig softmax failed: {e}"),
        }
    };

    let t0 = Instant::now();
    for p in &payloads {
        call(p.clone())?;
    }
    let cold = t0.elapsed();
    let after_cold = coord.cache_stats();

    let t1 = Instant::now();
    for i in 0..requests {
        call(payloads[i % distinct].clone())?;
    }
    let hot = t1.elapsed();
    let stats = coord.cache_stats();
    coord.shutdown();

    let hot_hits = stats.hits - after_cold.hits;
    anyhow::ensure!(
        hot_hits == requests as u64,
        "hot phase expected {requests} cache hits, front counted {hot_hits} \
         (misses {} → {})",
        after_cold.misses,
        stats.misses
    );
    let miss_qps = distinct as f64 / cold.as_secs_f64();
    let hit_qps = requests as f64 / hot.as_secs_f64();
    let total = (stats.hits + stats.misses) as f64;
    let hit_rate = stats.hits as f64 / total.max(1.0);

    let mut table = Table::new(&[
        "V",
        "distinct",
        "requests",
        "miss QPS",
        "hit QPS",
        "hit/miss",
        "hit rate",
    ]);
    table.row(vec![
        v.to_string(),
        distinct.to_string(),
        requests.to_string(),
        format!("{miss_qps:.0}"),
        format!("{hit_qps:.0}"),
        format!("{:.1}x", hit_qps / miss_qps),
        format!("{:.3}", hit_rate),
    ]);
    println!("{}", table.render());

    let mut rec = crate::json::Value::object();
    rec.set("bench", crate::json::Value::String("cache_fig".into()))
        .set("v", crate::json::Value::Number(v as f64))
        .set("distinct", crate::json::Value::Number(distinct as f64))
        .set("requests", crate::json::Value::Number(requests as f64))
        .set("miss_qps", crate::json::Value::Number(miss_qps))
        .set("hit_qps", crate::json::Value::Number(hit_qps))
        .set("hits", crate::json::Value::Number(stats.hits as f64))
        .set("misses", crate::json::Value::Number(stats.misses as f64))
        .set("hit_rate", crate::json::Value::Number(hit_rate));
    opts.emit(&rec)?;

    if let Some(path) = &opts.json_report {
        let mut report = crate::json::Value::object();
        report
            .set("schema", crate::json::Value::String("osmax.bench.cache.v1".into()))
            .set("fig", crate::json::Value::String("cache".into()))
            .set("git", crate::json::Value::String(git_describe()))
            .set("smoke", crate::json::Value::Bool(opts.smoke))
            .set("records", crate::json::Value::Array(vec![rec]));
        std::fs::write(path, report.to_json() + "\n")?;
        println!("wrote cache report → {path}");
    }
    println!(
        "expected shape: hit QPS orders of magnitude above miss QPS — a hit is\n\
         one front lookup (no batcher, no kernel); the gap narrows only if the\n\
         cached payloads are small enough that the kernel itself is trivial."
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> BenchOpts {
        std::env::set_var("OSMAX_BENCH_FAST", "1");
        BenchOpts {
            sizes: Some(vec![256, 1024]),
            batch: Some(4),
            threads: 1,
            smoke: false,
            json_out: None,
            json_report: None,
        }
    }

    #[test]
    fn figures_run_to_completion() {
        let o = fast_opts();
        fig1(&o).unwrap();
        fig2(&o).unwrap();
        fig3(&o).unwrap();
        fig4(&o).unwrap();
    }

    #[test]
    fn k_sweep_runs() {
        let mut o = fast_opts();
        o.sizes = Some(vec![2048]);
        k_sweep(&o).unwrap();
    }

    #[test]
    fn shard_ablation_runs() {
        let mut o = fast_opts();
        o.sizes = Some(vec![4096]);
        o.threads = 2;
        shard_ablation(&o).unwrap();
    }

    #[test]
    fn grid_ablation_runs() {
        let mut o = fast_opts();
        o.sizes = Some(vec![8192]);
        o.batch = Some(3);
        o.threads = 2;
        grid_ablation(&o).unwrap();
    }

    #[test]
    fn steal_ablation_runs() {
        let mut o = fast_opts();
        o.sizes = None; // exercise the smoke defaults
        o.batch = None;
        o.threads = 2;
        o.smoke = true;
        steal_ablation(&o).unwrap();
    }

    #[test]
    fn backend_ablation_runs() {
        let mut o = fast_opts();
        o.sizes = None; // exercise the smoke defaults
        o.batch = None;
        o.threads = 2;
        o.smoke = true;
        backend_ablation(&o).unwrap();
    }

    #[test]
    fn backend_json_report_is_a_single_schema_document() {
        let mut o = fast_opts();
        let path = std::env::temp_dir()
            .join(format!("osmax-backend-report-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        o.json_report = Some(path.display().to_string());
        o.sizes = None; // smoke defaults: one size, three backend arms
        o.batch = None;
        o.threads = 2;
        o.smoke = true;
        backend_ablation(&o).unwrap();
        let doc = crate::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("fig").unwrap().as_str().unwrap(), "backend");
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "osmax.bench.backend.v1");
        assert!(doc.get("git").unwrap().as_str().is_some());
        let records = doc.get("records").unwrap().as_array().unwrap();
        assert_eq!(records.len(), 3, "one record per backend per size");
        for r in records {
            assert!(r.get("backend").unwrap().as_str().is_some());
            assert!(r.get("vocab").unwrap().as_f64().unwrap() > 0.0);
            assert!(r.get("ns_per_element").unwrap().as_f64().unwrap() > 0.0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sample_ablation_runs() {
        let mut o = fast_opts();
        o.sizes = None; // exercise the smoke defaults
        o.batch = None;
        o.threads = 2;
        o.smoke = true;
        sample_ablation(&o).unwrap();
    }

    #[test]
    fn sample_json_report_is_a_single_schema_document() {
        let mut o = fast_opts();
        let path = std::env::temp_dir()
            .join(format!("osmax-sample-report-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        o.json_report = Some(path.display().to_string());
        o.sizes = None; // smoke defaults: one size, greedy + sampled arms
        o.batch = None;
        o.threads = 2;
        o.smoke = true;
        sample_ablation(&o).unwrap();
        let doc = crate::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("fig").unwrap().as_str().unwrap(), "sample");
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "osmax.bench.sample.v1");
        let records = doc.get("records").unwrap().as_array().unwrap();
        assert_eq!(records.len(), 2, "one greedy + one sampled record per size");
        for r in records {
            assert!(r.get("mode").unwrap().as_str().is_some());
            assert!(r.get("ns_per_element").unwrap().as_f64().unwrap() > 0.0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cache_fig_runs_and_reports_schema_document() {
        let mut o = fast_opts();
        let path = std::env::temp_dir()
            .join(format!("osmax-cache-report-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        o.json_report = Some(path.display().to_string());
        o.sizes = Some(vec![256]);
        o.batch = Some(4); // 4 distinct payloads
        o.smoke = true; // 64 hot requests
        cache_fig(&o).unwrap();
        let doc = crate::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("fig").unwrap().as_str().unwrap(), "cache");
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "osmax.bench.cache.v1");
        assert!(doc.get("git").unwrap().as_str().is_some());
        let records = doc.get("records").unwrap().as_array().unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert!(r.get("hit_qps").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("miss_qps").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("hit_rate").unwrap().as_f64().unwrap() > 0.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn grid_json_report_is_a_single_schema_document() {
        let mut o = fast_opts();
        let path = std::env::temp_dir()
            .join(format!("osmax-grid-report-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        o.json_report = Some(path.display().to_string());
        o.sizes = Some(vec![8192]);
        o.batch = Some(3);
        o.threads = 2;
        grid_ablation(&o).unwrap();
        let doc = crate::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("fig").unwrap().as_str().unwrap(), "grid");
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "osmax.bench.grid.v1");
        let records = doc.get("records").unwrap().as_array().unwrap();
        assert_eq!(records.len(), 1, "one record per size");
        assert!(records[0].get("speedup_grid_vs_per_row").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn steal_json_report_is_a_single_schema_document() {
        let mut o = fast_opts();
        let path = std::env::temp_dir()
            .join(format!("osmax-steal-report-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        o.json_report = Some(path.display().to_string());
        o.sizes = None; // smoke defaults: one size
        o.batch = None;
        o.threads = 2;
        o.smoke = true;
        steal_ablation(&o).unwrap();
        let doc = crate::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("fig").unwrap().as_str().unwrap(), "steal");
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "osmax.bench.steal.v1");
        let records = doc.get("records").unwrap().as_array().unwrap();
        assert_eq!(records.len(), 2, "uniform + skewed per size");
        for r in records {
            assert!(r.get("cost_shape").unwrap().as_str().is_some());
            assert!(r.get("speedup_steal_vs_fifo").unwrap().as_f64().unwrap() > 0.0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_out_appends_records() {
        let mut o = fast_opts();
        let path = std::env::temp_dir().join(format!("osmax-bench-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        o.json_out = Some(path.display().to_string());
        o.sizes = Some(vec![128]);
        fig1(&o).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let first = crate::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("bench").unwrap().as_str().unwrap(), "fig1");
        std::fs::remove_file(&path).ok();
    }
}
