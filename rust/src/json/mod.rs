//! Minimal-but-complete JSON substrate (no `serde` in the offline registry).
//!
//! Implements RFC 8259: a [`Value`] tree, a recursive-descent [`parse`]r
//! with precise error positions, and a compact [`Value::to_json`] /
//! pretty serializer.  Used by the artifact [`manifest`](crate::runtime),
//! the wire protocol ([`server`](crate::server)), golden-vector tests,
//! and the config loader.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document node.  Object keys are ordered (BTreeMap) so
/// serialization is deterministic — handy for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

/// Parse error with byte offset and a short message.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ----- constructors ---------------------------------------------------

    pub fn object() -> Value {
        Value::Object(BTreeMap::new())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x as f64)).collect())
    }

    pub fn from_i32_slice(xs: &[i32]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x as f64)).collect())
    }

    pub fn from_str_slice(xs: &[&str]) -> Value {
        Value::Array(xs.iter().map(|&s| Value::String(s.to_string())).collect())
    }

    // ----- accessors ------------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|n| n as f32)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|n| usize::try_from(n).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Required-field lookup with a contextual error.
    pub fn require(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required json field `{key}`"))
    }

    /// Insert into an object value (panics on non-objects — programmer error).
    pub fn set(&mut self, key: &str, v: Value) -> &mut Self {
        match self {
            Value::Object(o) => {
                o.insert(key.to_string(), v);
            }
            _ => panic!("Value::set on non-object"),
        }
        self
    }

    /// Decode an array of numbers into `Vec<f32>`.
    pub fn to_f32_vec(&self) -> anyhow::Result<Vec<f32>> {
        let arr = self
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("expected json array of numbers"))?;
        arr.iter()
            .map(|v| v.as_f32().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    /// Decode an array of integers into `Vec<i32>`.
    pub fn to_i32_vec(&self) -> anyhow::Result<Vec<i32>> {
        let arr = self
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("expected json array of integers"))?;
        arr.iter()
            .map(|v| {
                v.as_i64()
                    .and_then(|n| i32::try_from(n).ok())
                    .ok_or_else(|| anyhow::anyhow!("expected i32"))
            })
            .collect()
    }

    /// Decode a nested array-of-arrays of numbers (row-major matrix).
    pub fn to_f32_matrix(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        let arr = self
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("expected json array of rows"))?;
        arr.iter().map(|r| r.to_f32_vec()).collect()
    }

    // ----- serialization --------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Number(n) => write_number(*n, out),
            Value::String(s) => write_escaped(s, out),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; serialize as null (documented lossy corner).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else {
        // Ryu-style shortest repr is what `{}` gives for f64 in rust.
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(Value::String(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Value::Bool(true)),
            Some(b'f') => self.parse_lit("false", Value::Bool(false)),
            Some(b'n') => self.parse_lit("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.parse_number(),
            Some(c) => Err(self.err(&format!("unexpected byte `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected `{lit}`")))
        }
    }

    fn parse_object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `}` in object"));
                }
            }
        }
    }

    fn parse_array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `]` in array"));
                }
            }
        }
    }

    fn parse_string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.parse_hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: must pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.parse_hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn parse_number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| ParseError { offset: start, message: format!("invalid number `{text}`") })
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "1e3"] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_json()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parse_nested_document() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\n\"y"}], "c": null, "d": -1.5e-2}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Value::Null));
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -0.015);
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\n\"y");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "01x", "[1 2]", "{}extra", "", "nul"] {
            assert!(parse(bad).is_err(), "should reject `{bad}`");
        }
    }

    #[test]
    fn unicode_escapes_and_surrogates() {
        assert_eq!(parse(r#""A""#).unwrap().as_str().unwrap(), "A");
        assert_eq!(parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
        assert!(parse(r#""\ud83d""#).is_err(), "unpaired surrogate");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo ⊕ wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ⊕ wörld");
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn object_builder_and_access() {
        let mut v = Value::object();
        v.set("xs", Value::from_f32_slice(&[1.0, 2.5]))
            .set("n", Value::Number(7.0))
            .set("name", Value::String("bench".into()));
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("xs").unwrap().to_f32_vec().unwrap(), vec![1.0, 2.5]);
        assert!(v.require("missing").is_err());
    }

    #[test]
    fn matrix_decode() {
        let v = parse("[[1, 2], [3, 4]]").unwrap();
        assert_eq!(v.to_f32_matrix().unwrap(), vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }

    #[test]
    fn deterministic_serialization() {
        let mut v = Value::object();
        v.set("zeta", Value::Number(1.0)).set("alpha", Value::Number(2.0));
        assert_eq!(v.to_json(), r#"{"alpha":2,"zeta":1}"#);
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(Value::Number(f64::NAN).to_json(), "null");
        assert_eq!(Value::Number(f64::INFINITY).to_json(), "null");
    }

    #[test]
    fn large_integers_preserved() {
        let v = parse("9007199254740992").unwrap(); // 2^53
        assert!(v.as_i64().is_none(), "2^53 exceeds exact i64 window");
        let v = parse("9007199254740991").unwrap(); // 2^53 - 1
        assert_eq!(v.as_i64().unwrap(), 9007199254740991);
    }
}
