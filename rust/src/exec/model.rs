//! Deterministic-schedule model checking for the exec substrate —
//! loom-style stateless exploration with no external crates.
//!
//! A *model run* executes a scenario closure on real OS threads whose
//! shared-memory operations all go through [`super::sync`].  The model
//! serializes execution with a baton: exactly one scenario thread runs
//! at a time, and at every schedule point (each atomic op, lock
//! acquisition, condvar wait/notify, spawn join) the scheduler picks
//! which thread runs next.  The pick sequence — the *schedule* — is
//! what the explorer enumerates:
//!
//! * **Bounded-exhaustive (DFS)**: replay the scenario with a forced
//!   choice prefix, extend greedily with choice 0, then backtrack the
//!   deepest un-exhausted choice — classic stateless model checking.
//!   For small operation counts this covers *every* interleaving
//!   (`Outcome::exhaustive`).
//! * **Randomized with seed replay**: beyond the DFS budget, schedules
//!   are drawn from a seeded xorshift stream, one derived seed per
//!   schedule.  A failure report names the seed; setting
//!   `OSMAX_MODEL_SEED` (or calling [`replay`]) reruns exactly that
//!   schedule.  `OSMAX_MODEL_SCHEDULES` overrides both budgets.
//!
//! Because execution is serialized, the model sees sequentially
//! consistent memory — it checks *interleaving* bugs (lost wakeups,
//! broken claim protocols, early returns, deadlocks — detected when no
//! thread is schedulable), not weak-memory reorderings.  Miri and TSan
//! cover the latter; the split is catalogued in `docs/VERIFICATION.md`.
//!
//! The scenario closure runs once per schedule and must construct all
//! of its state inside the closure (so every schedule starts from the
//! same initial state).  Threads inside a scenario are created with
//! [`spawn`]; `std::thread::spawn` threads would be invisible to the
//! scheduler and must not touch model-instrumented state.

// xtask:atomics-allowlist: SeqCst
// SeqCst: model self-test scenarios only — the scenarios assert on
// shim atomics and deliberately use the strongest ordering, since the
// serialized model gives SC semantics regardless.

use std::cell::RefCell;
use std::collections::BTreeSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// Panic payload used to unwind secondary threads once a run has
/// already failed (or been truncated); never reported as a failure.
struct AbortRun;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TState {
    /// May be scheduled.
    Runnable,
    /// Waiting to acquire the model mutex with this id; schedulable
    /// once no thread holds it.
    BlockedMutex(u64),
    /// Waiting on condvar `cv`, which will reacquire `mutex` when
    /// notified; never schedulable until a notify moves it to
    /// [`TState::BlockedMutex`].
    BlockedCv { cv: u64, mutex: u64 },
    /// Waiting for thread `.0` to finish.
    BlockedJoin(usize),
    /// Scenario closure returned (or unwound).
    Finished,
}

enum Decider {
    /// Forced choice prefix; choice 0 beyond it (trace records the
    /// actual choices for backtracking).
    Dfs { prefix: Vec<usize> },
    /// Seeded xorshift stream.
    Random { state: u64 },
}

struct Sched {
    threads: Vec<TState>,
    /// Index of the thread holding the baton.  Only the baton holder
    /// executes scenario code, and only it mutates scheduler state (so
    /// once `current == me`, it stays that way until `me` acts).
    current: usize,
    /// Ids of model mutexes currently held.
    locked: BTreeSet<u64>,
    decider: Decider,
    /// `(choice, options)` at every schedule point with > 1 option.
    trace: Vec<(usize, usize)>,
    max_choices: usize,
    /// Total schedule points (including forced single-option picks);
    /// bounds livelocking scenarios that spin without branching.
    steps: usize,
    max_steps: usize,
    /// Once set, the run is over: threads unwind via [`AbortRun`] at
    /// their next schedule point, and blocking shims degrade to their
    /// real `std` behaviour so unwinding never deadlocks.
    abort: bool,
    /// First real failure observed (panic message or deadlock report).
    failure: Option<String>,
}

struct Ctx {
    sched: Mutex<Sched>,
    cv: Condvar,
}

thread_local! {
    static TLS: RefCell<Option<(Arc<Ctx>, usize)>> = const { RefCell::new(None) };
}

fn tls_get() -> Option<(Arc<Ctx>, usize)> {
    TLS.with(|t| t.borrow().clone())
}

/// Whether the calling thread belongs to an active model run.
pub(crate) fn in_model() -> bool {
    TLS.with(|t| t.borrow().is_some())
}

/// Unwind the calling thread out of the scenario — unless it is
/// already unwinding, in which case shim operations fall through to
/// their real `std` behaviour (free-run) so drops can complete.
fn abort_exit() {
    if !std::thread::panicking() {
        std::panic::panic_any(AbortRun);
    }
}

fn next_u64(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    *state = x;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn seed_to_state(seed: u64) -> u64 {
    let s = splitmix64(seed);
    if s == 0 {
        0x9E37_79B9_7F4A_7C15
    } else {
        s
    }
}

/// Pick `choice` among `n` options (recorded only when there is a real
/// branch).  Sets `abort` when the per-run choice budget is exhausted
/// (truncated run).
fn choose(st: &mut Sched, n: usize) -> usize {
    debug_assert!(n > 0);
    if n == 1 {
        return 0;
    }
    if st.trace.len() >= st.max_choices {
        st.abort = true;
        return 0;
    }
    let i = st.trace.len();
    let c = match &mut st.decider {
        Decider::Dfs { prefix } => {
            if i < prefix.len() {
                prefix[i].min(n - 1)
            } else {
                0
            }
        }
        Decider::Random { state } => (next_u64(state) % n as u64) as usize,
    };
    st.trace.push((c, n));
    c
}

fn schedulable(st: &Sched) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, t) in st.threads.iter().enumerate() {
        let ready = match t {
            TState::Runnable => true,
            TState::BlockedMutex(m) => !st.locked.contains(m),
            TState::BlockedCv { .. } => false,
            TState::BlockedJoin(target) => matches!(st.threads[*target], TState::Finished),
            TState::Finished => false,
        };
        if ready {
            out.push(i);
        }
    }
    out
}

/// Hand the baton to the next schedulable thread (possibly the
/// caller).  Declares deadlock — the model's lost-wakeup detector —
/// when live threads exist but none is schedulable.
fn pick_next(st: &mut Sched) {
    st.steps += 1;
    if st.steps > st.max_steps {
        st.abort = true;
        return;
    }
    let ready = schedulable(st);
    if ready.is_empty() {
        if st.threads.iter().all(|t| matches!(t, TState::Finished)) {
            return; // run complete; nothing left to schedule
        }
        st.abort = true;
        if st.failure.is_none() {
            st.failure = Some(format!(
                "deadlock: no schedulable thread (thread states {:?}, held mutexes {:?})",
                st.threads, st.locked
            ));
        }
        return;
    }
    let c = choose(st, ready.len());
    if st.abort {
        return;
    }
    st.current = ready[c];
}

/// Block until the baton returns to `me`.  Returns `false` when the
/// run aborted instead.
fn wait_for_turn(ctx: &Ctx, me: usize) -> bool {
    let mut st = ctx.sched.lock().unwrap();
    loop {
        if st.abort {
            return false;
        }
        if st.current == me {
            return true;
        }
        st = ctx.cv.wait(st).unwrap();
    }
}

/// One schedule point: offer the baton to any schedulable thread.
fn step(ctx: &Ctx, me: usize) {
    {
        let mut st = ctx.sched.lock().unwrap();
        if st.abort {
            drop(st);
            abort_exit();
            return;
        }
        pick_next(&mut st);
        if !st.abort && st.current == me {
            return; // kept the baton; no one to wake
        }
    }
    ctx.cv.notify_all();
    if !wait_for_turn(ctx, me) {
        abort_exit();
    }
}

/// Schedule point before an atomic operation (the operation itself
/// then runs atomically under the baton).
pub(crate) fn hook_atomic() {
    if let Some((ctx, me)) = tls_get() {
        step(&ctx, me);
    }
}

/// Cooperatively acquire model mutex `id` (schedule point first).  On
/// return the caller owns the model mutex and may take the inner
/// `std` lock, which is guaranteed uncontended.
pub(crate) fn hook_mutex_lock(id: u64) {
    let Some((ctx, me)) = tls_get() else { return };
    step(&ctx, me);
    loop {
        {
            let mut st = ctx.sched.lock().unwrap();
            if st.abort {
                drop(st);
                abort_exit();
                return; // free-run: fall through to the real lock
            }
            if !st.locked.contains(&id) {
                st.locked.insert(id);
                return;
            }
            st.threads[me] = TState::BlockedMutex(id);
            pick_next(&mut st);
        }
        ctx.cv.notify_all();
        if !wait_for_turn(&ctx, me) {
            abort_exit();
            return;
        }
        ctx.sched.lock().unwrap().threads[me] = TState::Runnable;
    }
}

/// Release model mutex `id`.  Deliberately not a schedule point: the
/// next shared-memory operation is, and keeping release silent makes
/// `Condvar::wait`'s release-then-block atomic under the baton.
pub(crate) fn hook_mutex_unlock(id: u64) {
    let Some((ctx, _me)) = tls_get() else { return };
    let mut st = ctx.sched.lock().unwrap();
    st.locked.remove(&id);
}

/// Block on condvar `cv` (the caller has already released `mutex` via
/// [`hook_mutex_unlock`]); returns once notified and scheduled, after
/// which the caller reacquires the mutex through the normal lock path.
pub(crate) fn hook_cv_wait(cv: u64, mutex: u64) {
    let Some((ctx, me)) = tls_get() else { return };
    {
        let mut st = ctx.sched.lock().unwrap();
        if st.abort {
            drop(st);
            abort_exit();
            return; // free-run: behave as a spurious wakeup
        }
        st.threads[me] = TState::BlockedCv { cv, mutex };
        pick_next(&mut st);
    }
    ctx.cv.notify_all();
    if !wait_for_turn(&ctx, me) {
        abort_exit();
        return;
    }
    ctx.sched.lock().unwrap().threads[me] = TState::Runnable;
}

/// Move waiters on `cv` to the mutex-reacquisition state.  For
/// `notify_one`, *which* waiter wakes is an explored schedule choice.
pub(crate) fn hook_notify(cv: u64, all: bool) {
    let Some((ctx, _me)) = tls_get() else { return };
    let mut st = ctx.sched.lock().unwrap();
    if st.abort {
        return;
    }
    let waiters: Vec<(usize, u64)> = st
        .threads
        .iter()
        .enumerate()
        .filter_map(|(i, t)| match t {
            TState::BlockedCv { cv: c, mutex } if *c == cv => Some((i, *mutex)),
            _ => None,
        })
        .collect();
    if waiters.is_empty() {
        return;
    }
    if all {
        for (i, m) in waiters {
            st.threads[i] = TState::BlockedMutex(m);
        }
    } else {
        let c = choose(&mut st, waiters.len());
        if st.abort {
            drop(st);
            ctx.cv.notify_all();
            abort_exit();
            return;
        }
        let (i, m) = waiters[c];
        st.threads[i] = TState::BlockedMutex(m);
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> Option<String> {
    if p.downcast_ref::<AbortRun>().is_some() {
        return None;
    }
    if let Some(s) = p.downcast_ref::<&str>() {
        Some((*s).to_string())
    } else if let Some(s) = p.downcast_ref::<String>() {
        Some(s.clone())
    } else {
        Some("<non-string panic>".to_string())
    }
}

fn finish_thread(ctx: &Ctx, index: usize, failure: Option<String>) {
    let mut st = ctx.sched.lock().unwrap();
    st.threads[index] = TState::Finished;
    if let Some(msg) = failure {
        if st.failure.is_none() {
            st.failure = Some(msg);
        }
        st.abort = true;
    }
    if !st.abort {
        pick_next(&mut st);
    }
    drop(st);
    ctx.cv.notify_all();
}

fn run_thread<T, F: FnOnce() -> T>(ctx: Arc<Ctx>, index: usize, f: F) -> Option<T> {
    TLS.with(|t| *t.borrow_mut() = Some((ctx.clone(), index)));
    if !wait_for_turn(&ctx, index) {
        finish_thread(&ctx, index, None);
        return None;
    }
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => {
            finish_thread(&ctx, index, None);
            Some(v)
        }
        Err(p) => {
            finish_thread(&ctx, index, panic_text(p.as_ref()));
            None
        }
    }
}

/// Handle to a scenario thread created with [`spawn`].
pub struct JoinHandle<T> {
    ctx: Arc<Ctx>,
    index: usize,
    inner: std::thread::JoinHandle<Option<T>>,
}

impl<T> JoinHandle<T> {
    /// Cooperatively wait for the thread to finish; returns its value,
    /// or `None` if it panicked (the panic is recorded as the run's
    /// failure by the explorer).
    pub fn join(self) -> Option<T> {
        if let Some((ctx, me)) = tls_get() {
            loop {
                {
                    let mut st = ctx.sched.lock().unwrap();
                    if st.abort {
                        break;
                    }
                    if matches!(st.threads[self.index], TState::Finished) {
                        break;
                    }
                    st.threads[me] = TState::BlockedJoin(self.index);
                    pick_next(&mut st);
                }
                ctx.cv.notify_all();
                if !wait_for_turn(&ctx, me) {
                    break;
                }
                ctx.sched.lock().unwrap().threads[me] = TState::Runnable;
            }
            if ctx.sched.lock().unwrap().abort {
                abort_exit();
            }
        }
        self.inner.join().unwrap_or(None)
    }
}

/// Spawn a scenario thread under the current model run.  Panics if the
/// caller is not itself a model thread.
pub fn spawn<T, F>(f: F) -> JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (ctx, _me) = tls_get().expect("model::spawn called outside a model run");
    let index = {
        let mut st = ctx.sched.lock().unwrap();
        st.threads.push(TState::Runnable);
        st.threads.len() - 1
    };
    let c2 = ctx.clone();
    let inner = std::thread::Builder::new()
        .name(format!("osmax-model-{index}"))
        .spawn(move || run_thread(c2, index, f))
        .expect("failed to spawn model thread");
    JoinHandle { ctx, index, inner }
}

struct RunResult {
    trace: Vec<(usize, usize)>,
    failure: Option<String>,
    truncated: bool,
}

fn run_once(
    decider: Decider,
    max_choices: usize,
    scenario: &Arc<dyn Fn() + Send + Sync>,
) -> RunResult {
    let ctx = Arc::new(Ctx {
        sched: Mutex::new(Sched {
            threads: vec![TState::Runnable],
            current: 0,
            locked: BTreeSet::new(),
            decider,
            trace: Vec::new(),
            max_choices,
            steps: 0,
            max_steps: max_choices.saturating_mul(8).saturating_add(4096),
            abort: false,
            failure: None,
        }),
        cv: Condvar::new(),
    });
    let c2 = ctx.clone();
    let sc = scenario.clone();
    let root = std::thread::Builder::new()
        .name("osmax-model-0".to_string())
        .spawn(move || run_thread(c2, 0, move || sc()))
        .expect("failed to spawn model root thread");
    {
        let mut st = ctx.sched.lock().unwrap();
        while !st.threads.iter().all(|t| matches!(t, TState::Finished)) {
            st = ctx.cv.wait(st).unwrap();
        }
    }
    let _ = root.join();
    let mut st = ctx.sched.lock().unwrap();
    let truncated = st.abort && st.failure.is_none();
    RunResult {
        trace: std::mem::take(&mut st.trace),
        failure: st.failure.take(),
        truncated,
    }
}

/// DFS backtracking: the forced prefix for the next unexplored
/// schedule, or `None` when the bounded tree is exhausted.
fn next_prefix(trace: &[(usize, usize)]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        let (c, n) = trace[i];
        if c + 1 < n {
            let mut p: Vec<usize> = trace[..i].iter().map(|t| t.0).collect();
            p.push(c + 1);
            return Some(p);
        }
    }
    None
}

/// Explorer budgets for one [`check`]/[`run_explorer`] call.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Max schedules explored depth-first (bounded-exhaustive phase).
    pub dfs_schedules: usize,
    /// Schedules drawn from the seeded random stream after (or instead
    /// of) DFS.
    pub random_schedules: usize,
    /// Base seed for the random phase; schedule `i` uses
    /// `seed + i`, reported on failure for replay.
    pub seed: u64,
    /// Per-run cap on recorded (> 1 option) schedule choices; deeper
    /// runs are truncated, not failed.
    pub max_choices: usize,
}

impl Config {
    /// The tier-1 default budget: small enough to keep unit-test suites
    /// fast, large enough to exhaust the bounded scenarios in this
    /// module (and catch the seeded mutants deterministically).
    pub fn small() -> Self {
        Self { dfs_schedules: 300, random_schedules: 150, seed: 0x05_AD5C_0FFE, max_choices: 4096 }
    }
}

/// A failing schedule found by the explorer.
#[derive(Debug)]
pub struct Failure {
    /// What failed (assertion message, panic text, or deadlock report).
    pub message: String,
    /// How to reproduce it (`OSMAX_MODEL_SEED=...` for random-phase
    /// failures; the deterministic choice trace for DFS failures).
    pub replay: String,
}

/// What one explorer invocation did.
#[derive(Debug)]
pub struct Outcome {
    /// Schedules actually run.
    pub schedules: usize,
    /// Runs cut short by the choice budget.
    pub truncated: usize,
    /// `true` when DFS exhausted every interleaving within budget (and
    /// nothing was truncated): full coverage, not sampling.
    pub exhaustive: bool,
    /// The first failing schedule, if any.
    pub failure: Option<Failure>,
}

fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(h) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(h, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Re-run exactly one randomized schedule by seed (the programmatic
/// twin of `OSMAX_MODEL_SEED`).
pub fn replay(
    name: &str,
    seed: u64,
    max_choices: usize,
    scenario: impl Fn() + Send + Sync + 'static,
) -> Outcome {
    let scenario: Arc<dyn Fn() + Send + Sync> = Arc::new(scenario);
    let r = run_once(Decider::Random { state: seed_to_state(seed) }, max_choices, &scenario);
    Outcome {
        schedules: 1,
        truncated: usize::from(r.truncated),
        exhaustive: false,
        failure: r.failure.map(|msg| Failure {
            message: format!("model `{name}`: {msg}"),
            replay: format!("schedule seed 0x{seed:x} (replay with OSMAX_MODEL_SEED=0x{seed:x})"),
        }),
    }
}

/// Explore `scenario` under `cfg`, returning what happened.
/// `OSMAX_MODEL_SEED` (hex `0x…` or decimal) short-circuits to a
/// single-seed replay; `OSMAX_MODEL_SCHEDULES` overrides both budgets.
pub fn run_explorer(
    name: &str,
    cfg: Config,
    scenario: impl Fn() + Send + Sync + 'static,
) -> Outcome {
    let scenario: Arc<dyn Fn() + Send + Sync> = Arc::new(scenario);
    if let Some(seed) = std::env::var("OSMAX_MODEL_SEED").ok().as_deref().and_then(parse_seed) {
        let decider = Decider::Random { state: seed_to_state(seed) };
        let r = run_once(decider, cfg.max_choices, &scenario);
        return Outcome {
            schedules: 1,
            truncated: usize::from(r.truncated),
            exhaustive: false,
            failure: r.failure.map(|msg| Failure {
                message: format!("model `{name}`: {msg}"),
                replay: format!(
                    "schedule seed 0x{seed:x} (replay with OSMAX_MODEL_SEED=0x{seed:x})"
                ),
            }),
        };
    }
    let (dfs_budget, rand_budget) = match std::env::var("OSMAX_MODEL_SCHEDULES")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
    {
        Some(n) => (n, n),
        None => (cfg.dfs_schedules, cfg.random_schedules),
    };

    let mut schedules = 0usize;
    let mut truncated = 0usize;

    // Phase 1: bounded-exhaustive DFS over the schedule tree.
    let mut prefix: Vec<usize> = Vec::new();
    let mut exhausted = false;
    while schedules < dfs_budget {
        let r = run_once(
            Decider::Dfs { prefix: std::mem::take(&mut prefix) },
            cfg.max_choices,
            &scenario,
        );
        schedules += 1;
        if r.truncated {
            truncated += 1;
        }
        if let Some(msg) = r.failure {
            let choices: Vec<usize> = r.trace.iter().map(|t| t.0).collect();
            return Outcome {
                schedules,
                truncated,
                exhaustive: false,
                failure: Some(Failure {
                    message: format!("model `{name}`: {msg}"),
                    replay: format!(
                        "DFS schedule #{schedules}, choice trace {choices:?} \
                         (deterministic: rerunning this test reproduces it)"
                    ),
                }),
            };
        }
        match next_prefix(&r.trace) {
            Some(p) => prefix = p,
            None => {
                exhausted = true;
                break;
            }
        }
    }
    if exhausted && truncated == 0 {
        return Outcome { schedules, truncated, exhaustive: true, failure: None };
    }

    // Phase 2: seeded random schedules, one derived seed per run.
    for i in 0..rand_budget {
        let seed = cfg.seed.wrapping_add(i as u64);
        let decider = Decider::Random { state: seed_to_state(seed) };
        let r = run_once(decider, cfg.max_choices, &scenario);
        schedules += 1;
        if r.truncated {
            truncated += 1;
        }
        if let Some(msg) = r.failure {
            return Outcome {
                schedules,
                truncated,
                exhaustive: false,
                failure: Some(Failure {
                    message: format!("model `{name}`: {msg}"),
                    replay: format!(
                        "schedule seed 0x{seed:x} (replay with OSMAX_MODEL_SEED=0x{seed:x})"
                    ),
                }),
            };
        }
    }
    Outcome { schedules, truncated, exhaustive: exhausted && truncated == 0, failure: None }
}

/// Explore `scenario` under `cfg`; panics with the failure message and
/// its replay handle if any schedule fails — the assertion form used
/// by the regression suites.
pub fn check(name: &str, cfg: Config, scenario: impl Fn() + Send + Sync + 'static) {
    let o = run_explorer(name, cfg, scenario);
    if let Some(f) = o.failure {
        panic!("{}\n  replay: {}", f.message, f.replay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::sync::{AtomicUsize, Condvar as ShimCondvar, Mutex as ShimMutex, Ordering};
    use crate::exec::{StealDeque, WaitGroup};

    #[test]
    fn explorer_exhausts_trivial_single_thread_scenario() {
        let o = run_explorer(
            "trivial",
            Config { dfs_schedules: 64, random_schedules: 0, seed: 1, max_choices: 512 },
            || {
                let a = AtomicUsize::new(0);
                a.fetch_add(1, Ordering::SeqCst);
                assert_eq!(a.load(Ordering::SeqCst), 1);
            },
        );
        assert!(o.failure.is_none(), "{:?}", o.failure);
        assert!(o.exhaustive, "single-threaded scenario must exhaust");
        assert_eq!(o.schedules, 1, "no branch points → exactly one schedule");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // schedule-exploration volume; nothing for miri here
    fn explorer_reports_deadlock_with_thread_states() {
        let o = run_explorer(
            "deadlock",
            Config { dfs_schedules: 16, random_schedules: 0, seed: 1, max_choices: 512 },
            || {
                let m = ShimMutex::new(());
                let cv = ShimCondvar::new();
                let g = m.lock().unwrap();
                let _g = cv.wait(g); // never notified: must be detected, not hang
            },
        );
        let f = o.failure.expect("un-notified wait must be reported as deadlock");
        assert!(f.message.contains("deadlock"), "{}", f.message);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn model_deque_last_element_goes_to_exactly_one_side() {
        check("deque_last_element", Config::small(), || {
            let d = Arc::new(StealDeque::new(4));
            d.push(7usize).unwrap();
            let owner = {
                let d = d.clone();
                spawn(move || d.pop())
            };
            let thief = {
                let d = d.clone();
                spawn(move || d.steal())
            };
            let a = owner.join().flatten();
            let b = thief.join().flatten();
            assert!(
                a.is_some() != b.is_some(),
                "last element must go to exactly one side: owner={a:?} thief={b:?}"
            );
            assert_eq!(a.or(b), Some(7));
            assert!(d.is_empty());
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn model_deque_conserves_and_keeps_steal_fifo() {
        check("deque_owner_vs_thief", Config::small(), || {
            let d = Arc::new(StealDeque::new(8));
            for i in 0..3usize {
                d.push(i).unwrap();
            }
            let thief = {
                let d = d.clone();
                spawn(move || d.steal())
            };
            let owner = {
                let d = d.clone();
                spawn(move || (d.pop(), d.pop()))
            };
            let stolen = thief.join().flatten();
            let (p1, p2) = owner.join().expect("owner thread result");
            // 3 items, 3 takes: every schedule consumes each exactly
            // once; the thief always sees the FIFO end (oldest = 0) and
            // the owner the LIFO end, whatever the interleaving.
            assert_eq!(stolen, Some(0), "thief must take the oldest");
            assert_eq!((p1, p2), (Some(2), Some(1)), "owner must pop newest-first");
            assert!(d.is_empty());
        });
    }

    /// The pool's claim protocol (`next_task`/`join_idle`), distilled:
    /// `active` must be bumped BEFORE popping, so the idle predicate
    /// ("queues empty and active == 0") can never be transiently true
    /// while a task is in flight between a queue and its worker.
    fn claim_scenario(claim_before_pop: bool) -> impl Fn() + Send + Sync + 'static {
        move || {
            let deque = Arc::new(StealDeque::new(2));
            deque.push(1usize).unwrap();
            let active = Arc::new(AtomicUsize::new(0));
            let done = Arc::new(AtomicUsize::new(0));
            let worker = {
                let deque = deque.clone();
                let active = active.clone();
                let done = done.clone();
                spawn(move || {
                    if claim_before_pop {
                        active.fetch_add(1, Ordering::SeqCst);
                        if deque.pop().is_some() {
                            done.store(1, Ordering::SeqCst);
                        }
                        active.fetch_sub(1, Ordering::SeqCst);
                    } else {
                        // MUTATION: pop first, claim after — the
                        // pre-claim window the real protocol forbids.
                        let t = deque.pop();
                        active.fetch_add(1, Ordering::SeqCst);
                        if t.is_some() {
                            done.store(1, Ordering::SeqCst);
                        }
                        active.fetch_sub(1, Ordering::SeqCst);
                    }
                })
            };
            let joiner = {
                let deque = deque.clone();
                let active = active.clone();
                let done = done.clone();
                spawn(move || {
                    if deque.is_empty() && active.load(Ordering::SeqCst) == 0 {
                        assert_eq!(
                            done.load(Ordering::SeqCst),
                            1,
                            "idle predicate observed while the claimed task had not finished"
                        );
                    }
                })
            };
            worker.join();
            joiner.join();
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn model_pool_claim_protocol_holds_under_all_schedules() {
        check("pool_claim_protocol", Config::small(), claim_scenario(true));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn model_pool_claim_protocol_mutant_is_caught() {
        let o = run_explorer("pool_claim_mutant", Config::small(), claim_scenario(false));
        let f = o.failure.expect("pop-before-claim mutant must be caught");
        assert!(f.message.contains("idle predicate"), "{}", f.message);
    }

    /// The grid's per-row countdown, distilled: each tile publishes its
    /// partial BEFORE decrementing `remaining`, so the tile that
    /// observes the count hit zero sees every partial.
    fn grid_scenario(publish_before_decrement: bool) -> impl Fn() + Send + Sync + 'static {
        move || {
            let remaining = Arc::new(AtomicUsize::new(2));
            let contrib = Arc::new(AtomicUsize::new(0));
            let reductions = Arc::new(AtomicUsize::new(0));
            let workers: Vec<_> = (0..2)
                .map(|_| {
                    let remaining = remaining.clone();
                    let contrib = contrib.clone();
                    let reductions = reductions.clone();
                    spawn(move || {
                        if publish_before_decrement {
                            contrib.fetch_add(1, Ordering::SeqCst);
                            if remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
                                assert_eq!(
                                    contrib.load(Ordering::SeqCst),
                                    2,
                                    "row reduced before every tile partial was published"
                                );
                                reductions.fetch_add(1, Ordering::SeqCst);
                            }
                        } else {
                            // MUTATION: decrement first — the last
                            // decrementer can reduce a row whose other
                            // partial is not yet published.
                            let last = remaining.fetch_sub(1, Ordering::SeqCst) == 1;
                            contrib.fetch_add(1, Ordering::SeqCst);
                            if last {
                                assert_eq!(
                                    contrib.load(Ordering::SeqCst),
                                    2,
                                    "row reduced before every tile partial was published"
                                );
                                reductions.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    })
                })
                .collect();
            for w in workers {
                w.join();
            }
            assert_eq!(
                reductions.load(Ordering::SeqCst),
                1,
                "exactly one tile must observe the final countdown"
            );
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn model_grid_countdown_reduces_once_with_all_partials() {
        check("grid_countdown", Config::small(), grid_scenario(true));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn model_grid_countdown_mutant_is_caught() {
        let o = run_explorer("grid_countdown_mutant", Config::small(), grid_scenario(false));
        let f = o.failure.expect("decrement-before-publish mutant must be caught");
        assert!(f.message.contains("partial was published"), "{}", f.message);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn model_waitgroup_wait_covers_every_preregistered_guard() {
        check("waitgroup_add_racing_completions", Config::small(), || {
            let wg = WaitGroup::new();
            let d1 = Arc::new(AtomicUsize::new(0));
            let d2 = Arc::new(AtomicUsize::new(0));
            let g1 = wg.add();
            let g2 = wg.add();
            let t1 = {
                let d1 = d1.clone();
                spawn(move || {
                    d1.store(1, Ordering::SeqCst);
                    drop(g1);
                })
            };
            let t2 = {
                let wg = wg.clone();
                let d2 = d2.clone();
                spawn(move || {
                    let g3 = wg.add(); // later epoch: must not be waited for
                    drop(g3);
                    d2.store(1, Ordering::SeqCst);
                    drop(g2);
                })
            };
            wg.wait();
            assert_eq!(d1.load(Ordering::SeqCst), 1, "wait returned before g1 completed");
            assert_eq!(d2.load(Ordering::SeqCst), 1, "wait returned before g2 completed");
            t1.join();
            t2.join();
        });
    }

    // ------------------------------------------------------------------
    // Mutation self-test: the pre-PR-3 `WaitGroup::wait` bug, rebuilt.
    //
    // `TallyWaitGroup` tracks monotone added/done counts instead of
    // live guard ids; `wait()` latches `target = added` and returns
    // when `done >= target`.  A later-epoch add+drop bumps `done` and
    // satisfies an earlier epoch's target while one of that epoch's own
    // guards is still live — the early-return race the epoch/id set in
    // `exec::waitgroup` was built to fix.  The explorer must catch it
    // and hand back a replayable seed.
    // ------------------------------------------------------------------

    struct Tally {
        added: u64,
        done: u64,
    }

    struct TallyInner {
        st: ShimMutex<Tally>,
        cv: ShimCondvar,
    }

    #[derive(Clone)]
    struct TallyWaitGroup {
        inner: Arc<TallyInner>,
    }

    struct TallyGuard {
        inner: Arc<TallyInner>,
    }

    impl TallyWaitGroup {
        fn new() -> Self {
            Self {
                inner: Arc::new(TallyInner {
                    st: ShimMutex::new(Tally { added: 0, done: 0 }),
                    cv: ShimCondvar::new(),
                }),
            }
        }

        fn add(&self) -> TallyGuard {
            self.inner.st.lock().unwrap().added += 1;
            TallyGuard { inner: self.inner.clone() }
        }

        fn wait(&self) {
            let mut st = self.inner.st.lock().unwrap();
            let target = st.added; // the buggy latch: a count, not an id set
            while st.done < target {
                st = self.inner.cv.wait(st).unwrap();
            }
        }
    }

    impl Drop for TallyGuard {
        fn drop(&mut self) {
            let mut st = self.inner.st.lock().unwrap();
            st.done += 1;
            drop(st);
            self.inner.cv.notify_all();
        }
    }

    /// g1+g2 registered, then a waiter races a churn thread that adds
    /// and drops a later-epoch g3 before finishing g1 and (last) g2.
    /// Correct epoch semantics: `wait()` returns only after g2's drop,
    /// which is preceded by the flag store.  The tally mutant returns
    /// at done == 2 (g3 + g1) with g2 still live → flag still 0.
    fn tally_scenario() {
        let wg = TallyWaitGroup::new();
        let g2_dropped = Arc::new(AtomicUsize::new(0));
        let g1 = wg.add();
        let g2 = wg.add();
        let waiter = {
            let wg = wg.clone();
            let flag = g2_dropped.clone();
            spawn(move || {
                wg.wait();
                assert_eq!(
                    flag.load(Ordering::SeqCst),
                    1,
                    "wait returned while pre-registered guard g2 was still live"
                );
            })
        };
        let churn = {
            let wg = wg.clone();
            let flag = g2_dropped.clone();
            spawn(move || {
                let g3 = wg.add();
                drop(g3);
                drop(g1);
                flag.store(1, Ordering::SeqCst);
                drop(g2);
            })
        };
        waiter.join();
        churn.join();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn mutation_tally_waitgroup_caught_by_dfs_and_by_seeded_random_with_replay() {
        // Bounded-exhaustive phase finds the early return…
        let o = run_explorer(
            "wg_tally_mutant_dfs",
            Config { dfs_schedules: 400, random_schedules: 0, seed: 7, max_choices: 4096 },
            tally_scenario,
        );
        let f = o.failure.expect("DFS must catch the tally early-return race");
        assert!(f.message.contains("g2 was still live"), "{}", f.message);

        // …the randomized explorer finds it too and names a seed…
        let o = run_explorer(
            "wg_tally_mutant_rand",
            Config { dfs_schedules: 0, random_schedules: 400, seed: 0xBAD_5EED, max_choices: 4096 },
            tally_scenario,
        );
        let f = o.failure.expect("randomized explorer must catch the race");
        assert!(f.replay.contains("OSMAX_MODEL_SEED="), "no replay seed in: {}", f.replay);

        // …and replaying exactly that seed reproduces the failure.
        let seed_text = f.replay.split("OSMAX_MODEL_SEED=").nth(1).expect("seed in replay text");
        let seed = parse_seed(seed_text).expect("parsable replay seed");
        let r = replay("wg_tally_mutant_replay", seed, 4096, tally_scenario);
        assert!(r.failure.is_some(), "replayed seed 0x{seed:x} must reproduce the failure");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn real_waitgroup_survives_the_tally_killer_scenario() {
        // The same churn scenario, driven through the real epoch-based
        // WaitGroup: no schedule may produce an early return.
        check("wg_epoch_vs_churn", Config::small(), || {
            let wg = WaitGroup::new();
            let g2_dropped = Arc::new(AtomicUsize::new(0));
            let g1 = wg.add();
            let g2 = wg.add();
            let waiter = {
                let wg = wg.clone();
                let flag = g2_dropped.clone();
                spawn(move || {
                    wg.wait();
                    assert_eq!(
                        flag.load(Ordering::SeqCst),
                        1,
                        "wait returned while pre-registered guard g2 was still live"
                    );
                })
            };
            let churn = {
                let wg = wg.clone();
                let flag = g2_dropped.clone();
                spawn(move || {
                    let g3 = wg.add();
                    drop(g3);
                    drop(g1);
                    flag.store(1, Ordering::SeqCst);
                    drop(g2);
                })
            };
            waiter.join();
            churn.join();
        });
    }
}
