//! Channels: a blocking bounded MPMC queue and a oneshot rendezvous.
//!
//! `std::sync::mpsc` lacks both a *bounded multi-consumer* queue (the
//! batcher needs competing worker-consumers with backpressure) and an
//! ergonomic oneshot (request/response).  Both are built here on
//! `Mutex` + `Condvar`, with timeout variants the scheduler relies on.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Send failed because all receivers hung up (payload returned).
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(
    /// The value that could not be delivered.
    pub T,
);

/// Receive failed.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    /// Channel empty and all senders dropped.
    Disconnected,
    /// Timed out waiting (timeout variants only).
    Timeout,
}

struct Chan<T> {
    inner: Mutex<ChanState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct ChanState<T> {
    queue: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

/// Sending half of a bounded channel (clonable).
pub struct Sender<T>(Arc<Chan<T>>);

/// Receiving half of a bounded channel (clonable: MPMC).
pub struct Receiver<T>(Arc<Chan<T>>);

/// Create a bounded blocking MPMC channel with the given capacity.
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0, "capacity must be positive");
    let chan = Arc::new(Chan {
        inner: Mutex::new(ChanState { queue: VecDeque::new(), senders: 1, receivers: 1 }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        capacity,
    });
    (Sender(chan.clone()), Receiver(chan))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.inner.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.inner.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            drop(st);
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.inner.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.inner.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            drop(st);
            self.0.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Block until space is available (backpressure) or receivers vanish.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.0.inner.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(SendError(value));
            }
            if st.queue.len() < self.0.capacity {
                st.queue.push_back(value);
                drop(st);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; returns the value back if the queue is full.
    pub fn try_send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.0.inner.lock().unwrap();
        if st.receivers == 0 || st.queue.len() >= self.0.capacity {
            return Err(SendError(value));
        }
        st.queue.push_back(value);
        drop(st);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Queue occupancy (for metrics/backpressure decisions).
    pub fn len(&self) -> usize {
        self.0.inner.lock().unwrap().queue.len()
    }

    /// Whether the queue currently holds no values.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The fixed capacity the channel was created with.
    pub fn capacity(&self) -> usize {
        self.0.capacity
    }
}

impl<T> Receiver<T> {
    /// Block until a value arrives or all senders hang up.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.0.inner.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Block up to `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.0.inner.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, res) = self.0.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.queue.is_empty() {
                if st.senders == 0 {
                    return Err(RecvError::Disconnected);
                }
                return Err(RecvError::Timeout);
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.inner.lock().unwrap();
        let v = st.queue.pop_front();
        if v.is_some() {
            drop(st);
            self.0.not_full.notify_one();
        }
        v
    }

    /// Drain up to `max` immediately-available values (batch formation).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut st = self.0.inner.lock().unwrap();
        let n = st.queue.len().min(max);
        let out: Vec<T> = st.queue.drain(..n).collect();
        if !out.is_empty() {
            drop(st);
            self.0.not_full.notify_all();
        }
        out
    }

    /// Queue occupancy (for metrics/backpressure decisions).
    pub fn len(&self) -> usize {
        self.0.inner.lock().unwrap().queue.len()
    }

    /// Whether the queue currently holds no values.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// oneshot
// ---------------------------------------------------------------------------

struct OnceChan<T> {
    slot: Mutex<OnceState<T>>,
    cv: Condvar,
}

enum OnceState<T> {
    Empty,
    Value(T),
    SenderDropped,
    Taken,
}

/// Producer half of a oneshot channel.
pub struct OnceSender<T>(Arc<OnceChan<T>>);

/// Consumer half of a oneshot channel.
pub struct OnceReceiver<T>(Arc<OnceChan<T>>);

/// Create a oneshot (single-value) channel.
pub fn oneshot<T>() -> (OnceSender<T>, OnceReceiver<T>) {
    let chan = Arc::new(OnceChan { slot: Mutex::new(OnceState::Empty), cv: Condvar::new() });
    (OnceSender(chan.clone()), OnceReceiver(chan))
}

impl<T> OnceSender<T> {
    /// Deliver the value; consumes the sender.  Returns the value back
    /// if the receiver is already gone.
    pub fn send(self, value: T) -> Result<(), T> {
        let mut st = self.0.slot.lock().unwrap();
        match &*st {
            OnceState::Empty => {
                *st = OnceState::Value(value);
                drop(st);
                self.0.cv.notify_one();
                // Suppress the Drop impl's SenderDropped write.
                std::mem::forget(self);
                Ok(())
            }
            _ => Err(value),
        }
    }
}

impl<T> Drop for OnceSender<T> {
    fn drop(&mut self) {
        let mut st = self.0.slot.lock().unwrap();
        if matches!(*st, OnceState::Empty) {
            *st = OnceState::SenderDropped;
            drop(st);
            self.0.cv.notify_one();
        }
    }
}

impl<T> OnceReceiver<T> {
    /// Block for the value.
    pub fn recv(self) -> Result<T, RecvError> {
        let mut st = self.0.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, OnceState::Taken) {
                OnceState::Value(v) => return Ok(v),
                OnceState::SenderDropped => return Err(RecvError::Disconnected),
                prev @ OnceState::Empty => {
                    *st = prev;
                    st = self.0.cv.wait(st).unwrap();
                }
                OnceState::Taken => unreachable!("oneshot consumed twice"),
            }
        }
    }

    /// Block up to `timeout` for the value.
    pub fn recv_timeout(self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.0.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, OnceState::Taken) {
                OnceState::Value(v) => return Ok(v),
                OnceState::SenderDropped => return Err(RecvError::Disconnected),
                prev @ OnceState::Empty => {
                    *st = prev;
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(RecvError::Timeout);
                    }
                    let (guard, _) = self.0.cv.wait_timeout(st, deadline - now).unwrap();
                    st = guard;
                }
                OnceState::Taken => unreachable!("oneshot consumed twice"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn bounded_fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let got: Vec<i32> = (0..5).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn bounded_backpressure_blocks_then_unblocks() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err(), "full queue rejects try_send");
        let t = thread::spawn(move || tx.send(3)); // blocks
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        t.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }

    #[test]
    fn recv_disconnected_when_senders_drop() {
        let (tx, rx) = bounded::<u8>(4);
        tx.send(9).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 9);
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));
    }

    #[test]
    fn send_fails_without_receivers() {
        let (tx, rx) = bounded::<u8>(4);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError(1)));
    }

    #[test]
    fn recv_timeout_expires() {
        let (_tx, rx) = bounded::<u8>(1);
        let err = rx.recv_timeout(Duration::from_millis(10)).unwrap_err();
        assert_eq!(err, RecvError::Timeout);
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(16);
        let n_items = 1000;
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        drop(rx);
        for i in 0..n_items {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut all: Vec<u32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    fn drain_up_to_takes_available() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain_up_to(3), vec![0, 1, 2]);
        assert_eq!(rx.drain_up_to(10), vec![3, 4]);
        assert!(rx.drain_up_to(10).is_empty());
    }

    #[test]
    fn oneshot_roundtrip() {
        let (tx, rx) = oneshot();
        thread::spawn(move || tx.send(42).unwrap());
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn oneshot_sender_dropped() {
        let (tx, rx) = oneshot::<u8>();
        drop(tx);
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));
    }

    #[test]
    fn oneshot_timeout() {
        let (_tx, rx) = oneshot::<u8>();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Err(RecvError::Timeout));
    }

    #[test]
    fn oneshot_send_after_receiver_dropped() {
        let (tx, rx) = oneshot::<u8>();
        drop(rx);
        // Value comes back — no receiver will ever take it.
        // (send still succeeds into the slot only if receiver exists; our
        // implementation stores it regardless, which is fine — but the
        // contract we assert is: no panic, deterministic result.)
        let _ = tx.send(5);
    }
}
