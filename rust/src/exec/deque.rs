//! Per-worker work-stealing deques: LIFO for the owner, FIFO for
//! thieves.
//!
//! Each [`ThreadPool`](super::ThreadPool) worker running under
//! [`SchedPolicy::Steal`](super::SchedPolicy) owns one bounded
//! [`StealDeque`].  Batch submissions scatter tasks across the deques;
//! the owner pops its own back (LIFO — the most recently assigned task
//! is the cache-warmest), while idle workers steal from the *front*
//! (FIFO — the oldest task, the one the owner is furthest from
//! reaching).  The two ends never compete for the same task until the
//! deque is down to a single element, which is exactly the regime where
//! a lock is cheap.
//!
//! The paper's ⊕ monoid is what makes this scheduler legal at all:
//! shard partials merge associatively in any order, so tile *placement*
//! and *execution order* are pure performance knobs — stealing can
//! never change a result (the grid property tests pin this under both
//! scheduling policies).
//!
//! Implementation note: the offline registry has no `crossbeam`, so
//! this is a mutexed ring rather than a Chase–Lev array.  Every deque
//! has its *own* mutex: in steady state the owner is the only thread
//! touching it, so the lock is uncontended and the cost is one
//! uncontended atomic RMW per push/pop — contention only appears when
//! a thief shows up, i.e. when the owner is the straggler and paying a
//! lock round-trip is irrelevant.  A SeqCst `len` mirror lets parking
//! workers and `join_idle` poll emptiness without taking S locks (see
//! the field docs for why the ordering matters).

// xtask:atomics-allowlist: SeqCst
// SeqCst: the lock-free `len` mirror must sit in the same total order
// as the pool's `active` counter — see the field docs and the per-site
// comments below.  Test-only atomics reuse the same ordering.

use std::collections::VecDeque;

use crate::exec::sync::{AtomicUsize, Mutex, Ordering};

/// A bounded double-ended queue supporting owner LIFO pops and thief
/// FIFO steals.
pub struct StealDeque<T> {
    inner: Mutex<VecDeque<T>>,
    /// Mirror of `inner.len()`, updated under the lock, readable
    /// without it.  SeqCst on both sides: the pool's idle/park
    /// predicates interleave these reads with reads of its `active`
    /// counter, and their correctness argument needs all of them to
    /// sit in the single sequentially-consistent order (a relaxed
    /// mirror could report a pop's `0` while an older `active` value
    /// is still visible, making a claimed-but-running task invisible
    /// to `join_idle`).
    len: AtomicUsize,
    cap: usize,
}

impl<T> StealDeque<T> {
    /// An empty deque holding at most `cap` tasks (`push` rejects
    /// beyond that so submitters overflow to the shared injector
    /// instead of buffering unboundedly on one worker).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "deque capacity must be positive");
        Self { inner: Mutex::new(VecDeque::new()), len: AtomicUsize::new(0), cap }
    }

    /// Maximum number of queued tasks.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Queued-task count (lock-free snapshot; exact only to the holder
    /// of the lock).
    pub fn len(&self) -> usize {
        // SeqCst: read side of the mirror.  `join_idle` and the parking
        // predicate interleave this with `active` loads; both reads must
        // come from the single total order or an empty-looking deque
        // could be paired with a stale `active == 0`.
        self.len.load(Ordering::SeqCst)
    }

    /// Whether the snapshot length is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Push onto the owner end (back).  Returns the task back to the
    /// caller when the deque is full.
    pub fn push(&self, t: T) -> Result<(), T> {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.cap {
            return Err(t);
        }
        q.push_back(t);
        // SeqCst: publish the new length while still holding the lock
        // so a parked worker's wake-up scan cannot order this store
        // after the `active` traffic of the task it is about to claim.
        self.len.store(q.len(), Ordering::SeqCst);
        Ok(())
    }

    /// Owner pop: newest task first (LIFO).
    pub fn pop(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        let t = q.pop_back();
        // SeqCst: this store must not become visible before the popping
        // worker's preceding `active.fetch_add` — `join_idle` relies on
        // "len says empty ⇒ the claimer is already counted in `active`".
        self.len.store(q.len(), Ordering::SeqCst);
        t
    }

    /// Thief pop: oldest task first (FIFO) — the opposite end from the
    /// owner, so steals drain the work the owner is furthest from.
    pub fn steal(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        let t = q.pop_front();
        // SeqCst: same claim-protocol argument as `pop` — a thief has
        // also pre-claimed via `active` before emptying the deque.
        self.len.store(q.len(), Ordering::SeqCst);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn owner_pops_lifo() {
        let d = StealDeque::new(16);
        for i in 0..4 {
            d.push(i).unwrap();
        }
        assert_eq!(d.len(), 4);
        assert_eq!((d.pop(), d.pop(), d.pop(), d.pop()), (Some(3), Some(2), Some(1), Some(0)));
        assert!(d.pop().is_none());
        assert!(d.is_empty());
    }

    #[test]
    fn thief_steals_fifo_from_the_far_end() {
        let d = StealDeque::new(16);
        for i in 0..4 {
            d.push(i).unwrap();
        }
        assert_eq!(d.steal(), Some(0), "thief takes the oldest");
        assert_eq!(d.pop(), Some(3), "owner takes the newest");
        assert_eq!(d.steal(), Some(1));
        assert_eq!(d.pop(), Some(2));
        assert!(d.steal().is_none());
    }

    #[test]
    fn push_bounces_when_full() {
        let d = StealDeque::new(2);
        d.push("a").unwrap();
        d.push("b").unwrap();
        assert_eq!(d.push("c"), Err("c"), "overflow returns the task");
        assert_eq!(d.len(), 2);
        d.pop().unwrap();
        d.push("c").unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 10k-token spin torture; deque unsafe-free paths are miri-covered above
    fn concurrent_steal_torture_conserves_tasks() {
        // 1 owner pushing + popping, 3 thieves stealing: every pushed
        // token is consumed exactly once, none duplicated or lost.
        const N: usize = 10_000;
        let d = Arc::new(StealDeque::new(64));
        let done = Arc::new(AtomicBool::new(false));
        let seen: Vec<AtomicUsize> = (0..N).map(|_| AtomicUsize::new(0)).collect();
        let seen = Arc::new(seen);

        let thieves: Vec<_> = (0..3)
            .map(|_| {
                let d = d.clone();
                let done = done.clone();
                let seen = seen.clone();
                std::thread::spawn(move || {
                    loop {
                        match d.steal() {
                            Some(i) => {
                                seen[i].fetch_add(1, Ordering::SeqCst);
                            }
                            None if done.load(Ordering::SeqCst) => break,
                            None => std::thread::yield_now(),
                        }
                    }
                })
            })
            .collect();

        let mut i = 0usize;
        let mut pending = 0usize;
        while i < N || pending > 0 {
            if i < N {
                match d.push(i) {
                    Ok(()) => {
                        pending += 1;
                        i += 1;
                    }
                    Err(_) => {
                        // full: drain one from the owner end instead
                        if let Some(j) = d.pop() {
                            seen[j].fetch_add(1, Ordering::SeqCst);
                            pending -= 1;
                        }
                    }
                }
            } else if let Some(j) = d.pop() {
                seen[j].fetch_add(1, Ordering::SeqCst);
                pending -= 1;
            } else {
                // thieves may still hold the remaining tokens
                pending = d.len();
                if pending == 0 {
                    break;
                }
            }
        }
        // let thieves drain whatever is left, then stop them
        while !d.is_empty() {
            std::thread::yield_now();
        }
        done.store(true, Ordering::SeqCst);
        for t in thieves {
            t.join().unwrap();
        }
        for (i, s) in seen.iter().enumerate() {
            assert_eq!(s.load(Ordering::SeqCst), 1, "token {i} consumed exactly once");
        }
    }
}
