//! Fixed-size worker pool with a shared FIFO injector queue.
//!
//! Semantics match the classic `ThreadPool` contract:
//! [`ThreadPool::execute`] enqueues a boxed `'static` task; workers
//! drain the queue; dropping the pool signals shutdown and joins all
//! workers after the queue is empty.  [`ThreadPool::join_idle`] lets
//! tests and the coordinator quiesce without tearing the pool down.
//! [`ThreadPool::execute_all`] admits a whole batch of tasks under one
//! lock acquisition — the enqueue path behind the shard layer's grid
//! dispatch, where an R×S tile fan-out would otherwise pay R·S
//! lock/notify round-trips.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::waitgroup::WaitGroup;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<State>,
    /// Signals workers when tasks arrive or shutdown begins.
    work_cv: Condvar,
    /// Signals joiners when the pool drains to idle.
    idle_cv: Condvar,
}

struct State {
    tasks: VecDeque<Task>,
    shutdown: bool,
    /// Tasks currently executing (for join_idle).
    active: usize,
}

/// A fixed pool of named worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers named `{name}-{i}`.
    pub fn new(size: usize, name: &str) -> Self {
        assert!(size > 0, "pool must have at least one worker");
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { tasks: VecDeque::new(), shutdown: false, active: 0 }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Self { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a task.  Panics if called after shutdown began (drop).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "execute on shut-down pool");
        st.tasks.push_back(Box::new(f));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Enqueue a batch of tasks atomically: one lock acquisition, one
    /// wake-all, FIFO order preserved.  Panics if called after shutdown
    /// began (drop).
    pub fn execute_all(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'static>>) {
        if tasks.is_empty() {
            return;
        }
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "execute on shut-down pool");
        st.tasks.extend(tasks);
        drop(st);
        self.shared.work_cv.notify_all();
    }

    /// Number of queued (not yet running) tasks.
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().tasks.len()
    }

    /// Block until the queue is empty and no task is executing.
    pub fn join_idle(&self) {
        let mut st = self.shared.queue.lock().unwrap();
        while !st.tasks.is_empty() || st.active > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }

    /// Run a batch of *borrowing* tasks on the pool and block until all
    /// of them have completed — a scoped fan-out/fan-in on persistent
    /// workers (no per-call thread spawns, unlike `std::thread::scope`).
    ///
    /// Used by the shard engine: each task scans one vocabulary shard of
    /// a borrowed logits slice.  A panicking task is caught by the
    /// worker loop (logged, pool survives) and still counts as
    /// completed.
    ///
    /// Do NOT call this from inside a task running on the same pool:
    /// the caller blocks a slot while waiting, which can deadlock.
    pub fn run_scoped<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        let wg = WaitGroup::new();
        let tasks: Vec<Task> = tasks
            .into_iter()
            .map(|task| {
                let guard = wg.add();
                // SAFETY: `wg.wait()` below does not return until every
                // task has run (or unwound) and dropped its guard, so
                // all 'scope borrows captured by `task` strictly
                // outlive its execution on the worker thread.  The
                // transmute only erases the lifetime; layout is
                // identical.
                let task: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(task) };
                Box::new(move || {
                    let _guard = guard;
                    task();
                }) as Task
            })
            .collect();
        self.execute_all(tasks);
        wg.wait();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = st.tasks.pop_front() {
                    st.active += 1;
                    break t;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Panics in tasks poison nothing: catch and continue, matching
        // production pool behaviour (a bad request must not kill workers).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
        let mut st = shared.queue.lock().unwrap();
        st.active -= 1;
        let idle = st.tasks.is_empty() && st.active == 0;
        drop(st);
        if idle {
            shared.idle_cv.notify_all();
        }
        if let Err(p) = result {
            crate::error!(
                "exec.pool",
                "worker task panicked: {}",
                panic_message(&p)
            );
        }
    }
}

fn panic_message(p: &Box<dyn std::any::Any + Send>) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins_and_drains() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "t");
            for _ in 0..50 {
                let c = counter.clone();
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop: must finish queued work before join returns
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn survives_panicking_task() {
        crate::logging::init(crate::logging::Level::Error);
        let pool = ThreadPool::new(1, "t");
        pool.execute(|| panic!("boom"));
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        pool.execute(move || {
            d.store(7, Ordering::Relaxed);
        });
        pool.join_idle();
        assert_eq!(done.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn join_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2, "t");
        pool.join_idle();
        assert_eq!(pool.queued(), 0);
        assert_eq!(pool.size(), 2);
    }

    #[test]
    fn run_scoped_borrows_and_joins() {
        let pool = ThreadPool::new(4, "t");
        let data: Vec<u64> = (0..100).collect();
        let partials = Mutex::new(vec![0u64; 4]);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|i| {
                let data = &data;
                let partials = &partials;
                Box::new(move || {
                    let sum: u64 = data[i * 25..(i + 1) * 25].iter().sum();
                    partials.lock().unwrap()[i] = sum;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(partials.into_inner().unwrap().iter().sum::<u64>(), 4950);
    }

    #[test]
    fn run_scoped_with_empty_task_list_returns() {
        let pool = ThreadPool::new(1, "t");
        pool.run_scoped(Vec::new());
    }

    #[test]
    fn execute_all_runs_batch_in_fifo_order() {
        let pool = ThreadPool::new(1, "t"); // one worker → strict FIFO
        let order = Arc::new(Mutex::new(Vec::new()));
        let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..16)
            .map(|i| {
                let order = order.clone();
                Box::new(move || order.lock().unwrap().push(i))
                    as Box<dyn FnOnce() + Send + 'static>
            })
            .collect();
        pool.execute_all(tasks);
        pool.execute_all(Vec::new()); // empty batch is a no-op
        pool.join_idle();
        assert_eq!(*order.lock().unwrap(), (0..16).collect::<Vec<i32>>());
    }

    #[test]
    fn run_scoped_survives_panicking_task() {
        crate::logging::init(crate::logging::Level::Error);
        let pool = ThreadPool::new(2, "t");
        let ok = Mutex::new(false);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("shard scan failed")),
            Box::new(|| *ok.lock().unwrap() = true),
        ];
        pool.run_scoped(tasks); // must not hang or propagate the panic
        assert!(*ok.lock().unwrap());
    }
}
