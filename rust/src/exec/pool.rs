//! Fixed-size worker pool with a shared FIFO injector queue.
//!
//! Semantics match the classic `ThreadPool` contract: [`execute`]
//! enqueues a boxed `'static` task; workers drain the queue; dropping
//! the pool signals shutdown and joins all workers after the queue is
//! empty.  [`ThreadPool::join_idle`] lets tests and the coordinator
//! quiesce without tearing the pool down.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<State>,
    /// Signals workers when tasks arrive or shutdown begins.
    work_cv: Condvar,
    /// Signals joiners when the pool drains to idle.
    idle_cv: Condvar,
}

struct State {
    tasks: VecDeque<Task>,
    shutdown: bool,
    /// Tasks currently executing (for join_idle).
    active: usize,
}

/// A fixed pool of named worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers named `{name}-{i}`.
    pub fn new(size: usize, name: &str) -> Self {
        assert!(size > 0, "pool must have at least one worker");
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { tasks: VecDeque::new(), shutdown: false, active: 0 }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Self { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a task.  Panics if called after shutdown began (drop).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "execute on shut-down pool");
        st.tasks.push_back(Box::new(f));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Number of queued (not yet running) tasks.
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().tasks.len()
    }

    /// Block until the queue is empty and no task is executing.
    pub fn join_idle(&self) {
        let mut st = self.shared.queue.lock().unwrap();
        while !st.tasks.is_empty() || st.active > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = st.tasks.pop_front() {
                    st.active += 1;
                    break t;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Panics in tasks poison nothing: catch and continue, matching
        // production pool behaviour (a bad request must not kill workers).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
        let mut st = shared.queue.lock().unwrap();
        st.active -= 1;
        let idle = st.tasks.is_empty() && st.active == 0;
        drop(st);
        if idle {
            shared.idle_cv.notify_all();
        }
        if let Err(p) = result {
            crate::error!(
                "exec.pool",
                "worker task panicked: {}",
                panic_message(&p)
            );
        }
    }
}

fn panic_message(p: &Box<dyn std::any::Any + Send>) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins_and_drains() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "t");
            for _ in 0..50 {
                let c = counter.clone();
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop: must finish queued work before join returns
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn survives_panicking_task() {
        crate::logging::init(crate::logging::Level::Error);
        let pool = ThreadPool::new(1, "t");
        pool.execute(|| panic!("boom"));
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        pool.execute(move || {
            d.store(7, Ordering::Relaxed);
        });
        pool.join_idle();
        assert_eq!(done.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn join_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2, "t");
        pool.join_idle();
        assert_eq!(pool.queued(), 0);
        assert_eq!(pool.size(), 2);
    }
}
