//! Fixed-size worker pool with a pluggable scheduling policy.
//!
//! Semantics match the classic `ThreadPool` contract:
//! [`ThreadPool::execute`] enqueues a boxed `'static` task; workers
//! drain the queues; dropping the pool signals shutdown and joins all
//! workers after every queue is empty.  [`ThreadPool::join_idle`] lets
//! tests and the coordinator quiesce without tearing the pool down.
//! [`ThreadPool::execute_all`] admits a whole batch of tasks in one
//! scheduling pass — the enqueue path behind the shard layer's grid
//! dispatch.
//!
//! Two policies ([`SchedPolicy`]) schedule the same contract:
//!
//! * **Fifo** — every task goes through one shared injector queue,
//!   strictly oldest-first.  Deterministic dequeue order (the unit
//!   tests rely on it with one worker), but every worker contends on
//!   the single injector lock, and a straggler task pins its worker
//!   while the queue behind it is served by the rest.
//! * **Steal** — each worker owns a bounded [`StealDeque`]
//!   (`exec::deque`): batch submissions scatter tasks round-robin
//!   across the deques, owners pop LIFO, and an idle worker steals
//!   FIFO from its siblings before sleeping.  The shared injector is
//!   demoted to a submission/overflow channel ([`ThreadPool::execute`]
//!   and deque overflow land there; workers drain it between own-deque
//!   and steal attempts).  Under skewed tile costs this keeps every
//!   core fed: the deque of a worker stuck on a long tile is emptied
//!   from the far end by its idle siblings.
//!
//! Task *results* never depend on the policy — the shard layer's ⊕
//! merge is associative and its bracketing is fixed by the plan, not
//! by arrival order (the grid property tests pin bitwise identity
//! under both policies).  Only completion order and occupancy change.
//!
//! Observability (`metrics::global()`, process-wide across pools):
//! `exec.pool.steal.steals` (tasks obtained from a sibling's deque),
//! `exec.pool.steal.failed` (steal sweeps that found every sibling
//! empty), `exec.pool.steal.overflows` (tasks bounced from a full
//! deque to the injector).

// xtask:atomics-allowlist: Relaxed, SeqCst
// SeqCst: every `active` claim-protocol site — the pairing with the
// deque `len` mirror needs the single total order; see the per-site
// comments in `next_task`, `run_task`, and `join_idle`.
// Relaxed: `cursor` (scatter origin) and test counters — pure tallies
// with no ordering role.

use std::collections::VecDeque;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use super::deque::StealDeque;
use super::sync::{AtomicUsize, Condvar, Mutex, Ordering};
use super::waitgroup::WaitGroup;
use crate::metrics::{self, Counter};

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Per-worker deque capacity under [`SchedPolicy::Steal`].  Submissions
/// beyond it overflow to the shared injector, so one worker can never
/// buffer an unbounded backlog that its siblings cannot reach quickly.
const DEQUE_CAP: usize = 256;

/// How a [`ThreadPool`] routes tasks to workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// One shared FIFO injector queue (strict submission order).
    Fifo,
    /// Per-worker deques, LIFO owner pop, FIFO steal; injector as the
    /// submission/overflow channel.
    Steal,
}

impl SchedPolicy {
    /// Parse a config/CLI value (`fifo` or `steal`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fifo" => Ok(SchedPolicy::Fifo),
            "steal" => Ok(SchedPolicy::Steal),
            _ => bail!("invalid pool scheduler `{s}` (expected `fifo` or `steal`)"),
        }
    }

    /// The canonical config/CLI spelling of this policy.
    pub fn as_str(self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::Steal => "steal",
        }
    }

    /// The policy named by the `OSMAX_POOL_SCHED` environment variable
    /// (how CI's scheduler matrix threads a policy through the e2e
    /// suites), or `default` when unset.  An unparsable value panics —
    /// a matrix job silently testing the wrong scheduler is worse than
    /// a loud failure.
    pub fn from_env_or(default: SchedPolicy) -> SchedPolicy {
        Self::resolve(std::env::var("OSMAX_POOL_SCHED").ok().as_deref(), default)
    }

    /// Testable core of [`Self::from_env_or`] — kept free of
    /// environment reads so tests never mutate process-global env vars
    /// (`set_var` races the other threads of the test binary, and
    /// clobbering `OSMAX_POOL_SCHED` would defeat CI's scheduler
    /// matrix for every test that runs afterwards).
    fn resolve(value: Option<&str>, default: SchedPolicy) -> SchedPolicy {
        match value {
            Some(s) => SchedPolicy::parse(s).expect("OSMAX_POOL_SCHED"),
            None => default,
        }
    }
}

struct Shared {
    /// The injector: sole queue under `Fifo`, submission/overflow
    /// channel under `Steal`.  Also guards `shutdown`, and serializes
    /// the sleep/notify handshake for both condvars.
    queue: Mutex<State>,
    /// Signals workers when tasks arrive or shutdown begins.
    work_cv: Condvar,
    /// Signals joiners when the pool drains to idle.
    idle_cv: Condvar,
    /// One deque per worker (`Steal` only; empty under `Fifo`).
    deques: Vec<StealDeque<Task>>,
    /// Tasks claimed or executing.  A task is counted here *before* it
    /// leaves any queue (claim protocol), so `join_idle` can never
    /// observe "all queues empty, nothing active" while a task is in
    /// flight between a queue and its worker.
    active: AtomicUsize,
    /// Rotates the scatter origin across batch submissions so repeated
    /// small batches don't all land on worker 0.
    cursor: AtomicUsize,
    steals: Arc<Counter>,
    failed_steals: Arc<Counter>,
    overflows: Arc<Counter>,
}

struct State {
    tasks: VecDeque<Task>,
    shutdown: bool,
}

impl Shared {
    fn any_deque_nonempty(&self) -> bool {
        self.deques.iter().any(|d| !d.is_empty())
    }
}

/// A fixed pool of named worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    policy: SchedPolicy,
}

impl ThreadPool {
    /// Spawn `size` workers named `{name}-{i}` under the default
    /// [`SchedPolicy::Fifo`] (strict submission order — what the
    /// server/coordinator pools and the ordering-sensitive unit tests
    /// expect).  The shard engine opts into `Steal` via
    /// [`ThreadPool::with_policy`].
    pub fn new(size: usize, name: &str) -> Self {
        Self::with_policy(size, name, SchedPolicy::Fifo)
    }

    /// Spawn `size` workers named `{name}-{i}` under `policy`.
    pub fn with_policy(size: usize, name: &str, policy: SchedPolicy) -> Self {
        assert!(size > 0, "pool must have at least one worker");
        let reg = metrics::global();
        let deques = match policy {
            SchedPolicy::Fifo => Vec::new(),
            SchedPolicy::Steal => (0..size).map(|_| StealDeque::new(DEQUE_CAP)).collect(),
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { tasks: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            deques,
            active: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            steals: reg.counter("exec.pool.steal.steals"),
            failed_steals: reg.counter("exec.pool.steal.failed"),
            overflows: reg.counter("exec.pool.steal.overflows"),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(shared, i))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Self { shared, workers, size, policy }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// The scheduling policy this pool runs.
    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Enqueue a task on the injector (the submission channel under
    /// both policies).  Panics if called after shutdown began (drop).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "execute on shut-down pool");
        st.tasks.push_back(Box::new(f));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Enqueue a batch of tasks in one scheduling pass, then wake all
    /// workers.  `Fifo`: one injector lock acquisition, submission
    /// order preserved.  `Steal`: tasks scatter round-robin across the
    /// worker deques (rotating origin), overflow beyond a deque's bound
    /// lands on the injector; dequeue order is a scheduling detail.
    /// Panics if called after shutdown began (drop).
    pub fn execute_all(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'static>>) {
        if tasks.is_empty() {
            return;
        }
        match self.policy {
            SchedPolicy::Fifo => {
                let mut st = self.shared.queue.lock().unwrap();
                assert!(!st.shutdown, "execute on shut-down pool");
                st.tasks.extend(tasks);
                drop(st);
            }
            SchedPolicy::Steal => {
                {
                    let st = self.shared.queue.lock().unwrap();
                    assert!(!st.shutdown, "execute on shut-down pool");
                }
                let n = self.shared.deques.len();
                // Relaxed: the cursor only rotates the scatter origin
                // for load spreading; any value is correct, so no
                // ordering with other memory is needed.
                let start = self.shared.cursor.fetch_add(1, Ordering::Relaxed) % n;
                let mut overflow: Vec<Task> = Vec::new();
                for (i, t) in tasks.into_iter().enumerate() {
                    if let Err(t) = self.shared.deques[(start + i) % n].push(t) {
                        overflow.push(t);
                    }
                }
                if !overflow.is_empty() {
                    self.shared.overflows.add(overflow.len() as u64);
                }
                // Acquire the queue mutex even when there is no
                // overflow: a worker parks only while holding it, so
                // passing through the lock guarantees every parked (or
                // parking) worker either sees the deque lengths written
                // above or receives the notify below — no lost wakeups.
                let mut st = self.shared.queue.lock().unwrap();
                st.tasks.extend(overflow);
                drop(st);
            }
        }
        self.shared.work_cv.notify_all();
    }

    /// Number of queued (not yet claimed) tasks across the injector and
    /// every worker deque.
    pub fn queued(&self) -> usize {
        let injected = self.shared.queue.lock().unwrap().tasks.len();
        injected + self.shared.deques.iter().map(|d| d.len()).sum::<usize>()
    }

    /// Snapshot of the steal metrics `(steals, failed_sweeps,
    /// overflows)`.  Process-wide counters shared by every pool (they
    /// live in the global metrics registry), so tests assert on deltas
    /// or lower bounds, not exact values.
    pub fn steal_stats(&self) -> (u64, u64, u64) {
        (
            self.shared.steals.get(),
            self.shared.failed_steals.get(),
            self.shared.overflows.get(),
        )
    }

    /// Block until every queue is empty and no task is executing.
    pub fn join_idle(&self) {
        // Ordering matters: scan the deques BEFORE loading `active`.
        // A steal-policy claim goes fetch_add(active) → pop(len := 0),
        // both SeqCst, so in the seq-cst total order a deque observed
        // empty means any claim of its last task has already bumped
        // `active` — the subsequent `active` load cannot miss it.  Read
        // the other way around, a task claimed between the two loads
        // would be invisible to both and join_idle could return while
        // it is still executing.  (Injector claims need no such care:
        // they run under the mutex held here.)
        let mut st = self.shared.queue.lock().unwrap();
        while !st.tasks.is_empty()
            || self.shared.any_deque_nonempty()
            || self.shared.active.load(Ordering::SeqCst) > 0
        {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }

    /// Run a batch of *borrowing* tasks on the pool and block until all
    /// of them have completed — a scoped fan-out/fan-in on persistent
    /// workers (no per-call thread spawns, unlike `std::thread::scope`).
    ///
    /// Used by the shard engine: each task scans one vocabulary shard of
    /// a borrowed logits slice.  A panicking task is caught by the
    /// worker loop (logged, pool survives) and still counts as
    /// completed.
    ///
    /// Do NOT call this from inside a task running on the same pool:
    /// the caller blocks a slot while waiting, which can deadlock.
    pub fn run_scoped<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        let wg = WaitGroup::new();
        let tasks: Vec<Task> = tasks
            .into_iter()
            .map(|task| {
                let guard = wg.add();
                // SAFETY: `wg.wait()` below does not return until every
                // task has run (or unwound) and dropped its guard, so
                // all 'scope borrows captured by `task` strictly
                // outlive its execution on the worker thread.  The
                // transmute only erases the lifetime; layout is
                // identical.
                let task: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(task) };
                Box::new(move || {
                    let _guard = guard;
                    task();
                }) as Task
            })
            .collect();
        self.execute_all(tasks);
        wg.wait();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    while let Some(task) = next_task(&shared, id) {
        run_task(&shared, task);
    }
}

/// Claim the next task for worker `id`: own deque (LIFO) → injector →
/// steal sweep (FIFO from siblings) → park.  Returns `None` only at
/// shutdown with every queue drained (the drop-drains contract).
///
/// Claim protocol: `active` is incremented *before* attempting to pop
/// from any queue and rolled back if the pop comes up empty, so the
/// idle predicate ("all queues empty and active == 0") is never
/// transiently true while a task is moving from a queue to a worker.
fn next_task(shared: &Shared, id: usize) -> Option<Task> {
    loop {
        // 1. Own deque, newest first (Steal policy only).
        if let Some(own) = shared.deques.get(id) {
            // SeqCst: the claim must precede the pop's `len := 0` in
            // the total order, so "deque looks empty" always implies
            // "its claimer is already counted in `active`" — the fact
            // `join_idle`'s deques-then-active scan relies on.
            shared.active.fetch_add(1, Ordering::SeqCst);
            if let Some(t) = own.pop() {
                return Some(t);
            }
            // SeqCst: roll the claim back in the same total order so a
            // joiner never sees a phantom claim outlive this probe.
            shared.active.fetch_sub(1, Ordering::SeqCst);
        }

        // 2. Shared injector, oldest first.
        {
            let mut st = shared.queue.lock().unwrap();
            // SeqCst (claim + rollback): injector claims happen under
            // the queue mutex that `join_idle` also holds, so the mutex
            // already orders them; SeqCst keeps the counter's *other*
            // (lock-free) sites in one total order rather than mixing
            // orderings on a single atomic.
            shared.active.fetch_add(1, Ordering::SeqCst);
            if let Some(t) = st.tasks.pop_front() {
                return Some(t);
            }
            shared.active.fetch_sub(1, Ordering::SeqCst);
        }

        // 3. Steal sweep: siblings' deques, oldest first, starting just
        // past our own slot.
        let n = shared.deques.len();
        if n > 1 {
            let mut stolen = None;
            for off in 1..n {
                let victim = &shared.deques[(id + off) % n];
                if victim.is_empty() {
                    continue; // cheap skip without touching its lock
                }
                // SeqCst: same claim-before-pop argument as step 1 —
                // a thief emptying a victim's deque must already be
                // visible in `active` when the `len` mirror reads 0.
                shared.active.fetch_add(1, Ordering::SeqCst);
                if let Some(t) = victim.steal() {
                    shared.steals.inc();
                    stolen = Some(t);
                    break;
                }
                // lost the race for the victim's last task: SeqCst
                // rollback, as in step 1.
                shared.active.fetch_sub(1, Ordering::SeqCst);
            }
            match stolen {
                Some(t) => return Some(t),
                None => shared.failed_steals.inc(),
            }
        }

        // 4. Park.  The checks below run under the queue mutex, and
        // every submission passes through that mutex before notifying,
        // so a task can never be published between our checks and the
        // wait (no lost wakeups).
        {
            let mut st = shared.queue.lock().unwrap();
            loop {
                // SeqCst (claim + rollback): as in step 2 — mutex-held
                // site kept on the counter's single total order.
                shared.active.fetch_add(1, Ordering::SeqCst);
                if let Some(t) = st.tasks.pop_front() {
                    return Some(t);
                }
                shared.active.fetch_sub(1, Ordering::SeqCst);
                if shared.any_deque_nonempty() {
                    break; // retry the fast paths instead of sleeping
                }
                // Everything is empty and nothing is claimed: the pool
                // is genuinely idle at this instant.  Wake joiners —
                // they may have gone to sleep after observing a
                // *transient* `active > 0` from one of the lock-free
                // claim probes above (steps 1/3 roll their claim back
                // without ever notifying), and `run_task` only notifies
                // after real task completions.
                // SeqCst load: must observe every claim that preceded a
                // deque emptying in the total order (see step 1).
                if shared.active.load(Ordering::SeqCst) == 0 {
                    shared.idle_cv.notify_all();
                }
                if st.shutdown {
                    return None;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        }
    }
}

fn run_task(shared: &Shared, task: Task) {
    // Panics in tasks poison nothing: catch and continue, matching
    // production pool behaviour (a bad request must not kill workers).
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
    // SeqCst: the completion decrement must precede this worker's idle
    // re-check below in the total order, or the worker could skip the
    // notify that an already-scanning joiner is waiting for.
    shared.active.fetch_sub(1, Ordering::SeqCst);
    let st = shared.queue.lock().unwrap();
    // Deques before `active` — same reasoning as `join_idle`; SeqCst
    // load for the same claim-visibility argument.
    let idle = st.tasks.is_empty()
        && !shared.any_deque_nonempty()
        && shared.active.load(Ordering::SeqCst) == 0;
    drop(st);
    if idle {
        shared.idle_cv.notify_all();
    }
    if let Err(p) = result {
        crate::error!("exec.pool", "worker task panicked: {}", panic_message(&p));
    }
}

fn panic_message(p: &Box<dyn std::any::Any + Send>) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    #[cfg_attr(miri, ignore)] // 100-task volume; small pool paths are miri-covered below
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // sleep-paced 50-task drain
    fn drop_joins_and_drains() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "t");
            for _ in 0..50 {
                let c = counter.clone();
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop: must finish queued work before join returns
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn survives_panicking_task() {
        crate::logging::init(crate::logging::Level::Error);
        let pool = ThreadPool::new(1, "t");
        pool.execute(|| panic!("boom"));
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        pool.execute(move || {
            d.store(7, Ordering::Relaxed);
        });
        pool.join_idle();
        assert_eq!(done.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn join_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2, "t");
        pool.join_idle();
        assert_eq!(pool.queued(), 0);
        assert_eq!(pool.size(), 2);
        assert_eq!(pool.policy(), SchedPolicy::Fifo);
    }

    #[test]
    fn run_scoped_borrows_and_joins() {
        let pool = ThreadPool::new(4, "t");
        let data: Vec<u64> = (0..100).collect();
        let partials = Mutex::new(vec![0u64; 4]);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|i| {
                let data = &data;
                let partials = &partials;
                Box::new(move || {
                    let sum: u64 = data[i * 25..(i + 1) * 25].iter().sum();
                    partials.lock().unwrap()[i] = sum;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(partials.into_inner().unwrap().iter().sum::<u64>(), 4950);
    }

    #[test]
    fn run_scoped_with_empty_task_list_returns() {
        let pool = ThreadPool::new(1, "t");
        pool.run_scoped(Vec::new());
    }

    #[test]
    fn execute_all_runs_batch_in_fifo_order() {
        let pool = ThreadPool::new(1, "t"); // one worker → strict FIFO
        let order = Arc::new(Mutex::new(Vec::new()));
        let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..16)
            .map(|i| {
                let order = order.clone();
                Box::new(move || order.lock().unwrap().push(i))
                    as Box<dyn FnOnce() + Send + 'static>
            })
            .collect();
        pool.execute_all(tasks);
        pool.execute_all(Vec::new()); // empty batch is a no-op
        pool.join_idle();
        assert_eq!(*order.lock().unwrap(), (0..16).collect::<Vec<i32>>());
    }

    #[test]
    fn run_scoped_survives_panicking_task() {
        crate::logging::init(crate::logging::Level::Error);
        let pool = ThreadPool::new(2, "t");
        let ok = Mutex::new(false);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("shard scan failed")),
            Box::new(|| *ok.lock().unwrap() = true),
        ];
        pool.run_scoped(tasks); // must not hang or propagate the panic
        assert!(*ok.lock().unwrap());
    }

    // --- Steal policy ----------------------------------------------------

    #[test]
    fn sched_policy_parses() {
        assert_eq!(SchedPolicy::parse("fifo").unwrap(), SchedPolicy::Fifo);
        assert_eq!(SchedPolicy::parse("steal").unwrap(), SchedPolicy::Steal);
        assert!(SchedPolicy::parse("lifo").is_err());
        assert_eq!(SchedPolicy::Steal.as_str(), "steal");
        assert_eq!(SchedPolicy::Fifo.as_str(), "fifo");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 100-task volume; steal paths are miri-covered by run_scoped below
    fn steal_pool_executes_all_tasks() {
        let pool = ThreadPool::with_policy(4, "t", SchedPolicy::Steal);
        assert_eq!(pool.policy(), SchedPolicy::Steal);
        let counter = Arc::new(AtomicUsize::new(0));
        // execute() lands on the injector, execute_all scatters across
        // the deques — both must drain.
        for _ in 0..40 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..60)
            .map(|_| {
                let c = counter.clone();
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + 'static>
            })
            .collect();
        pool.execute_all(tasks);
        pool.join_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.queued(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // sleep-paced 900-task drain
    fn steal_pool_drop_drains_deques_and_injector() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::with_policy(3, "t", SchedPolicy::Steal);
            // More tasks than DEQUE_CAP·workers would hold per deque
            // slot parity, so both the deques and (potentially) the
            // injector overflow path carry work at drop time.
            let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..900)
                .map(|_| {
                    let c = counter.clone();
                    Box::new(move || {
                        std::thread::sleep(std::time::Duration::from_micros(10));
                        c.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + 'static>
                })
                .collect();
            pool.execute_all(tasks);
        } // drop: must finish queued work before join returns
        assert_eq!(counter.load(Ordering::Relaxed), 900);
    }

    #[test]
    fn steal_pool_run_scoped_borrows_and_joins() {
        let pool = ThreadPool::with_policy(4, "t", SchedPolicy::Steal);
        let data: Vec<u64> = (0..1000).collect();
        let total = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks(100)
            .map(|chunk| {
                let total = &total;
                Box::new(move || {
                    let sum: u64 = chunk.iter().sum();
                    total.fetch_add(sum as usize, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(total.load(Ordering::Relaxed), 499_500);
    }

    #[test]
    fn steal_pool_survives_panicking_task() {
        crate::logging::init(crate::logging::Level::Error);
        let pool = ThreadPool::with_policy(2, "t", SchedPolicy::Steal);
        let ok = Mutex::new(false);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("tile scan failed")),
            Box::new(|| *ok.lock().unwrap() = true),
        ];
        pool.run_scoped(tasks);
        assert!(*ok.lock().unwrap());
        pool.join_idle();
    }

    #[test]
    fn env_policy_resolution() {
        // Pure-value test of the env resolution — deliberately no
        // set_var/remove_var (see SchedPolicy::resolve docs).
        assert_eq!(SchedPolicy::resolve(None, SchedPolicy::Steal), SchedPolicy::Steal);
        assert_eq!(SchedPolicy::resolve(None, SchedPolicy::Fifo), SchedPolicy::Fifo);
        assert_eq!(SchedPolicy::resolve(Some("fifo"), SchedPolicy::Steal), SchedPolicy::Fifo);
        assert_eq!(SchedPolicy::resolve(Some("steal"), SchedPolicy::Fifo), SchedPolicy::Steal);
    }
}
