//! Swappable concurrency primitives: `std::sync` in production,
//! schedule-instrumented under the model checker.
//!
//! [`Mutex`], [`Condvar`], and [`AtomicUsize`] mirror the exact API
//! surface of their `std::sync` counterparts that the exec substrate
//! uses (`lock().unwrap()`, `Condvar::wait(guard)`, atomic
//! `load`/`store`/`fetch_add`/`fetch_sub` taking an [`Ordering`]).  In
//! a plain build they are zero-cost pass-throughs.  In builds where the
//! model checker is compiled in (`cfg(test)` or the `osmax_model`
//! feature), every operation first calls into [`super::model`]: when
//! the calling thread belongs to an active model run, the operation
//! becomes a *schedule point* — the model's explorer decides which
//! thread runs next — and blocking primitives block *cooperatively*
//! inside the model scheduler instead of in the OS.  Threads outside a
//! model run take the pass-through path even in instrumented builds
//! (the hooks are a thread-local lookup that comes back empty).
//!
//! This is how `StealDeque`, `WaitGroup`, the pool's `active`-counter
//! claim protocol, and the grid's per-row countdown can be driven
//! through every interleaving of a bounded schedule without external
//! crates: the *production* code paths run unchanged, only the
//! primitives underneath them are schedule-aware.  See
//! `docs/VERIFICATION.md` for the contract catalogue.
//!
//! Model-run invariant that keeps the pass-through `std` types sound:
//! the model serializes execution (one runnable thread at a time), and
//! a model thread only takes the inner `std::sync::Mutex` *after* the
//! model granted it the mutex — so the inner lock is always
//! uncontended and never blocks the baton holder.

// xtask:atomics-allowlist: Relaxed, SeqCst
// Relaxed: `NEXT_SYNC_ID` is a pure id dispenser — uniqueness comes
// from the atomicity of fetch_add; no other memory is published.
// SeqCst: unit tests only (pass-through smoke of the wrapper ops).

use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError};

pub use std::sync::atomic::Ordering;

#[cfg(any(test, feature = "osmax_model"))]
use super::model;

/// Process-unique id for every shim `Mutex`/`Condvar` so the model
/// scheduler can track who holds / waits on what.  Ids are assigned in
/// construction order; model scenarios construct their state inside
/// the per-schedule closure, so id *assignment* never becomes a hidden
/// source of cross-schedule nondeterminism.
fn next_sync_id() -> u64 {
    static NEXT_SYNC_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    NEXT_SYNC_ID.fetch_add(1, Ordering::Relaxed)
}

/// A mutual-exclusion lock with the `std::sync::Mutex` contract,
/// instrumented as a schedule point under the model checker.
pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
    id: u64,
}

impl<T> Mutex<T> {
    /// Wrap `value` in a new lock.
    pub fn new(value: T) -> Self {
        Self { inner: std::sync::Mutex::new(value), id: next_sync_id() }
    }

    /// Acquire the lock, blocking (cooperatively, under the model)
    /// until it is available.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        #[cfg(any(test, feature = "osmax_model"))]
        model::hook_mutex_lock(self.id);
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g) }),
            Err(poisoned) => Err(PoisonError::new(MutexGuard {
                lock: self,
                inner: Some(poisoned.into_inner()),
            })),
        }
    }

    /// Consume the lock, returning the inner value.
    pub fn into_inner(self) -> LockResult<T> {
        match self.inner.into_inner() {
            Ok(v) => Ok(v),
            Err(poisoned) => Err(PoisonError::new(poisoned.into_inner())),
        }
    }
}

/// RAII guard for [`Mutex`]; releases the lock (and notifies the model
/// scheduler) on drop.
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    /// `Some` while the guard actually holds the inner `std` lock;
    /// taken out by [`Condvar::wait`], which manages the release and
    /// reacquisition itself.
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        let g = self.inner.take();
        if g.is_some() {
            // Release the inner std lock BEFORE telling the model the
            // mutex is free: the model may immediately schedule another
            // thread into `Mutex::lock`, whose inner `lock()` must not
            // find the std mutex still held.
            drop(g);
            #[cfg(any(test, feature = "osmax_model"))]
            model::hook_mutex_unlock(self.lock.id);
            #[cfg(not(any(test, feature = "osmax_model")))]
            let _ = self.lock.id;
        }
    }
}

/// A condition variable with the `std::sync::Condvar` contract,
/// instrumented as a schedule point under the model checker.
pub struct Condvar {
    inner: std::sync::Condvar,
    id: u64,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    /// Create a new condition variable.
    pub fn new() -> Self {
        Self { inner: std::sync::Condvar::new(), id: next_sync_id() }
    }

    /// Atomically release `guard`'s lock and block until notified, then
    /// reacquire.  Spurious wakeups are possible (in both modes), so
    /// callers loop on their predicate — exactly the `std` contract.
    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        let inner = guard.inner.take().expect("guard already released");
        #[cfg(any(test, feature = "osmax_model"))]
        {
            if model::in_model() {
                // Model path: under the serialized schedule, "release
                // then block" is atomic — no other thread runs between
                // the two steps, so no wakeup can be lost.
                drop(inner);
                model::hook_mutex_unlock(lock.id);
                drop(guard); // inner already taken: Drop is a no-op
                model::hook_cv_wait(self.id, lock.id);
                return lock.lock();
            }
        }
        drop(guard); // inner already taken: Drop is a no-op
        match self.inner.wait(inner) {
            Ok(g) => Ok(MutexGuard { lock, inner: Some(g) }),
            Err(poisoned) => Err(PoisonError::new(MutexGuard {
                lock,
                inner: Some(poisoned.into_inner()),
            })),
        }
    }

    /// Wake one waiter.  Under the model, *which* waiter is a schedule
    /// choice of the explorer.
    pub fn notify_one(&self) {
        #[cfg(any(test, feature = "osmax_model"))]
        model::hook_notify(self.id, false);
        self.inner.notify_one();
    }

    /// Wake all waiters.
    pub fn notify_all(&self) {
        #[cfg(any(test, feature = "osmax_model"))]
        model::hook_notify(self.id, true);
        self.inner.notify_all();
    }
}

/// An atomic `usize` with the `std` API, instrumented as a schedule
/// point under the model checker.  The model serializes execution, so
/// instrumented runs see sequentially-consistent semantics regardless
/// of the `Ordering` argument — the model checks *interleavings*, not
/// weak-memory reorderings (Miri and TSan cover those; see
/// `docs/VERIFICATION.md`).
pub struct AtomicUsize {
    inner: std::sync::atomic::AtomicUsize,
}

impl AtomicUsize {
    /// A new atomic holding `value`.
    pub const fn new(value: usize) -> Self {
        Self { inner: std::sync::atomic::AtomicUsize::new(value) }
    }

    /// Atomic load.
    #[inline]
    pub fn load(&self, order: Ordering) -> usize {
        #[cfg(any(test, feature = "osmax_model"))]
        model::hook_atomic();
        self.inner.load(order)
    }

    /// Atomic store.
    #[inline]
    pub fn store(&self, value: usize, order: Ordering) {
        #[cfg(any(test, feature = "osmax_model"))]
        model::hook_atomic();
        self.inner.store(value, order)
    }

    /// Atomic fetch-then-add; returns the previous value.
    #[inline]
    pub fn fetch_add(&self, value: usize, order: Ordering) -> usize {
        #[cfg(any(test, feature = "osmax_model"))]
        model::hook_atomic();
        self.inner.fetch_add(value, order)
    }

    /// Atomic fetch-then-subtract; returns the previous value.
    #[inline]
    pub fn fetch_sub(&self, value: usize, order: Ordering) -> usize {
        #[cfg(any(test, feature = "osmax_model"))]
        model::hook_atomic();
        self.inner.fetch_sub(value, order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mutex_passthrough_outside_model() {
        let m = Mutex::new(5usize);
        {
            let mut g = m.lock().unwrap();
            *g += 1;
        }
        assert_eq!(*m.lock().unwrap(), 6);
        assert_eq!(m.into_inner().unwrap(), 6);
    }

    #[test]
    fn condvar_passthrough_wakes_real_waiter() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = pair.clone();
        let waiter = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut ready = m.lock().unwrap();
            while !*ready {
                ready = cv.wait(ready).unwrap();
            }
        });
        {
            let (m, cv) = &*pair;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        waiter.join().unwrap();
    }

    #[test]
    fn atomic_passthrough_ops() {
        let a = AtomicUsize::new(10);
        assert_eq!(a.fetch_add(5, Ordering::SeqCst), 10);
        assert_eq!(a.fetch_sub(1, Ordering::SeqCst), 15);
        a.store(3, Ordering::SeqCst);
        assert_eq!(a.load(Ordering::SeqCst), 3);
    }
}
