//! Go-style WaitGroup: `add()` hands out RAII guards, `wait()` blocks
//! until every guard has dropped.  Used for fan-out/fan-in joins in the
//! coordinator and the scoped parallel helpers.

use std::sync::{Arc, Condvar, Mutex};

struct Inner {
    count: Mutex<usize>,
    cv: Condvar,
}

/// Completion barrier over a dynamic set of tasks.
#[derive(Clone)]
pub struct WaitGroup {
    inner: Arc<Inner>,
}

/// RAII task guard; dropping it decrements the group.
pub struct WaitGuard {
    inner: Arc<Inner>,
}

impl Default for WaitGroup {
    fn default() -> Self {
        Self::new()
    }
}

impl WaitGroup {
    pub fn new() -> Self {
        Self { inner: Arc::new(Inner { count: Mutex::new(0), cv: Condvar::new() }) }
    }

    /// Register one task; drop the returned guard on completion.
    pub fn add(&self) -> WaitGuard {
        *self.inner.count.lock().unwrap() += 1;
        WaitGuard { inner: self.inner.clone() }
    }

    /// Block until the count returns to zero.
    pub fn wait(&self) {
        let mut count = self.inner.count.lock().unwrap();
        while *count > 0 {
            count = self.inner.cv.wait(count).unwrap();
        }
    }

    /// Current outstanding count (diagnostics only — racy by nature).
    pub fn pending(&self) -> usize {
        *self.inner.count.lock().unwrap()
    }
}

impl Drop for WaitGuard {
    fn drop(&mut self) {
        let mut count = self.inner.count.lock().unwrap();
        *count -= 1;
        if *count == 0 {
            drop(count);
            self.inner.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn waits_for_all_guards() {
        let wg = WaitGroup::new();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let guard = wg.add();
            let done = done.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                done.fetch_add(1, Ordering::SeqCst);
                drop(guard);
            });
        }
        wg.wait();
        assert_eq!(done.load(Ordering::SeqCst), 8);
        assert_eq!(wg.pending(), 0);
    }

    #[test]
    fn wait_with_no_tasks_returns_immediately() {
        WaitGroup::new().wait();
    }

    #[test]
    fn guard_drop_via_panic_still_decrements() {
        let wg = WaitGroup::new();
        let guard = wg.add();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _g = guard;
            panic!("task failed");
        }));
        assert!(r.is_err());
        wg.wait(); // must not hang
    }
}
