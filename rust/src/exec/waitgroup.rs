//! Go-style WaitGroup: `add()` hands out RAII guards, `wait()` blocks
//! until every guard has dropped.  Used for fan-out/fan-in joins in the
//! coordinator and the scoped parallel helpers.
//!
//! `wait()` is an *epoch* barrier, not a zero-crossing watch: guards
//! carry monotonically-assigned ids, `wait()` latches the id horizon at
//! the moment of the call, and returns when no guard below that horizon
//! is still live.  A plain outstanding-count condition (`count == 0`)
//! has two failure modes when `add()` races with completions: a waiter
//! can miss a transient zero between registrations and then block on
//! guards registered *after* its call (potentially forever if those are
//! long-lived), and the "what am I waiting for" set silently shifts
//! under it.  (A subtler pair of monotone added/done tallies fails too:
//! a later guard's drop bumps `done` and satisfies an earlier epoch's
//! count while one of its own guards still runs.)  Tracking the live
//! ids makes the contract exact: `wait()` returns when, and only when,
//! every guard registered before the call has dropped.

// xtask:atomics-allowlist: SeqCst
// SeqCst: unit-test flags only — the WaitGroup itself is lock-based
// (shim Mutex/Condvar, so the model checker can drive its schedules).

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::exec::sync::{Condvar, Mutex};

struct State {
    /// Next guard id == total guards ever registered; ids below this
    /// at `wait()` time are that waiter's epoch.
    next_id: u64,
    /// Ids of live (not-yet-dropped) guards.
    outstanding: BTreeSet<u64>,
}

struct Inner {
    state: Mutex<State>,
    cv: Condvar,
}

/// Completion barrier over a dynamic set of tasks.
#[derive(Clone)]
pub struct WaitGroup {
    inner: Arc<Inner>,
}

/// RAII task guard; dropping it marks one task complete.
pub struct WaitGuard {
    inner: Arc<Inner>,
    id: u64,
}

impl Default for WaitGroup {
    fn default() -> Self {
        Self::new()
    }
}

impl WaitGroup {
    /// Create an empty group (no outstanding guards).
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Inner {
                state: Mutex::new(State { next_id: 0, outstanding: BTreeSet::new() }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Register one task; drop the returned guard on completion.
    pub fn add(&self) -> WaitGuard {
        let mut st = self.inner.state.lock().unwrap();
        let id = st.next_id;
        st.next_id += 1;
        st.outstanding.insert(id);
        drop(st);
        WaitGuard { inner: self.inner.clone(), id }
    }

    /// Block until every guard registered before this call has dropped.
    /// Guards registered after the call are a later epoch: they are not
    /// waited for, and their drops cannot satisfy this wait.
    pub fn wait(&self) {
        let mut st = self.inner.state.lock().unwrap();
        let horizon = st.next_id;
        while st.outstanding.range(..horizon).next().is_some() {
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    /// Current outstanding count (diagnostics only — racy by nature).
    pub fn pending(&self) -> usize {
        self.inner.state.lock().unwrap().outstanding.len()
    }
}

impl Drop for WaitGuard {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap();
        st.outstanding.remove(&self.id);
        // Only removing the minimum live id can empty some waiter's
        // `range(..horizon)`: while a smaller id stays live, it keeps
        // blocking every horizon this id was below.  Skipping the
        // broadcast otherwise spares the scoped-dispatch hot path
        // O(tiles) futile waiter wakeups per grid (the waiter would
        // just re-scan and sleep again).
        let may_unblock = match st.outstanding.iter().next() {
            None => true,
            Some(&m) => m > self.id,
        };
        drop(st);
        if may_unblock {
            self.inner.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    #[cfg_attr(miri, ignore)] // 8 sleeping threads; epoch protocol is model-checked instead
    fn waits_for_all_guards() {
        let wg = WaitGroup::new();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let guard = wg.add();
            let done = done.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                done.fetch_add(1, Ordering::SeqCst);
                drop(guard);
            });
        }
        wg.wait();
        assert_eq!(done.load(Ordering::SeqCst), 8);
        assert_eq!(wg.pending(), 0);
    }

    #[test]
    fn wait_with_no_tasks_returns_immediately() {
        WaitGroup::new().wait();
    }

    #[test]
    fn guard_drop_via_panic_still_decrements() {
        let wg = WaitGroup::new();
        let guard = wg.add();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _g = guard;
            panic!("task failed");
        }));
        assert!(r.is_err());
        wg.wait(); // must not hang
    }

    #[test]
    #[cfg_attr(miri, ignore)] // sleep-paced; covered exhaustively by exec::model suites
    fn transient_zero_between_registrations_is_not_an_early_return() {
        // add → drop → add: the outstanding count dips to zero between
        // the registrations.  A wait() issued after the second add must
        // still block until the second guard drops.
        let wg = WaitGroup::new();
        let g1 = wg.add();
        drop(g1);
        let g2 = wg.add();

        let finished = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let wg = wg.clone();
            let finished = finished.clone();
            std::thread::spawn(move || {
                wg.wait();
                finished.store(1, Ordering::SeqCst);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(finished.load(Ordering::SeqCst), 0, "wait returned with a live guard");
        drop(g2);
        waiter.join().unwrap();
        assert_eq!(finished.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // sleep-paced; covered exhaustively by exec::model suites
    fn later_epoch_churn_does_not_satisfy_an_earlier_epoch() {
        // Two pre-wait guards; after the waiter latches its horizon, a
        // later guard is added AND dropped, then one pre-wait guard
        // drops.  A drop-tally implementation would count the churn
        // (two drops ≥ target two) and return with g2 still live; the
        // id-set must keep waiting until g2 itself drops.
        let wg = WaitGroup::new();
        let g1 = wg.add();
        let g2 = wg.add();
        let entered = Arc::new(AtomicUsize::new(0));
        let finished = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let wg = wg.clone();
            let entered = entered.clone();
            let finished = finished.clone();
            std::thread::spawn(move || {
                entered.store(1, Ordering::SeqCst);
                wg.wait();
                finished.store(1, Ordering::SeqCst);
            })
        };
        while entered.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
        let g3 = wg.add();
        drop(g3); // later-epoch churn
        drop(g1); // one of the two the waiter actually covers
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(
            finished.load(Ordering::SeqCst),
            0,
            "wait returned while a pre-call guard was still live"
        );
        drop(g2);
        waiter.join().unwrap();
        assert_eq!(finished.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // sleep-paced; covered exhaustively by exec::model suites
    fn wait_ignores_guards_added_after_the_call() {
        // The race the epoch counter fixes: a waiter whose epoch is
        // {g1} must not block on g2, a guard registered after wait()
        // latched its target.  Under the old zero-crossing condition
        // this interleaving (g1 drops while g2 is live) blocked the
        // waiter until g2 dropped — forever, for a long-lived g2.
        let wg = WaitGroup::new();
        let g1 = wg.add();
        let entered = Arc::new(AtomicUsize::new(0));
        let finished = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let wg = wg.clone();
            let entered = entered.clone();
            let finished = finished.clone();
            std::thread::spawn(move || {
                entered.store(1, Ordering::SeqCst);
                wg.wait();
                finished.store(1, Ordering::SeqCst);
            })
        };
        while entered.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        // Give the waiter ample time to latch its epoch inside wait().
        std::thread::sleep(std::time::Duration::from_millis(50));
        let g2 = wg.add(); // next epoch — not the waiter's problem
        drop(g1);

        // The waiter must finish while g2 is still alive.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while finished.load(Ordering::SeqCst) == 0 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        let ok = finished.load(Ordering::SeqCst) == 1;
        drop(g2); // release before asserting so a failure can't hang the join
        waiter.join().unwrap();
        assert!(ok, "wait blocked on a guard registered after the call");
    }
}
