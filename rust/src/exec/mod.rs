//! Concurrency substrate (no `tokio`/`rayon` in the offline registry).
//!
//! * [`ThreadPool`] — fixed-size worker pool for `'static` tasks with a
//!   pluggable [`SchedPolicy`] (shared FIFO injector, or per-worker
//!   work-stealing deques); powers the server's connection handling,
//!   the coordinator's background workers, and the shard engine.
//! * [`StealDeque`] — the bounded per-worker deque behind
//!   [`SchedPolicy::Steal`] (LIFO owner pop, FIFO steal).
//! * [`oneshot`] — single-value rendezvous channel (request → response).
//! * [`bounded`] — blocking MPMC channel with capacity-based
//!   backpressure (the batcher's admission queue).
//! * [`WaitGroup`] — Go-style completion barrier for fan-out/fan-in
//!   (epoch-based: `wait()` covers exactly the guards registered
//!   before the call).
//! * [`parallel_chunks`] — scoped data-parallel map over slice chunks
//!   with an atomic work queue (rayon-style, borrow-friendly); powers
//!   the parallel ⊕ reduction of §3.1.
//! * [`sync`] — swappable Mutex/Condvar/atomic primitives: `std::sync`
//!   pass-throughs in production, schedule points under the model
//!   checker.
//! * [`model`] — deterministic-schedule model checker (cfg-gated:
//!   `cfg(test)` or the `osmax_model` feature) driving the deque,
//!   WaitGroup, claim-protocol, and grid-countdown invariants through
//!   bounded-exhaustive and seed-replayable random schedules.

#![warn(missing_docs)]

// xtask:atomics-allowlist: Relaxed
// Relaxed: `parallel_chunks`' work counter only partitions indices —
// each fetch_add claims a distinct chunk; result publication is
// ordered by the scope join, not by this atomic.

pub mod channel;
pub mod deque;
#[cfg(any(test, feature = "osmax_model"))]
pub mod model;
pub mod pool;
pub mod sync;
pub mod waitgroup;

pub use channel::{bounded, oneshot, RecvError, SendError};
pub use deque::StealDeque;
pub use pool::{SchedPolicy, ThreadPool};
pub use waitgroup::WaitGroup;

use crate::exec::sync::{AtomicUsize, Ordering};

/// Run `f(chunk_index, chunk)` over disjoint `chunk`-sized pieces of
/// `data` on up to `threads` scoped workers, returning results in chunk
/// order.  Workers claim chunks from an atomic counter, so uneven chunk
/// costs balance dynamically.  `threads == 1` (or a single chunk) runs
/// inline with zero spawns.
pub fn parallel_chunks<T, R, F>(threads: usize, data: &[T], chunk: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &[T]) -> R + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    let n_chunks = data.len().div_ceil(chunk);
    if n_chunks == 0 {
        return Vec::new();
    }
    if threads <= 1 || n_chunks == 1 {
        return data.chunks(chunk).enumerate().map(|(i, c)| f(i, c)).collect();
    }

    let mut slots: Vec<Option<R>> = Vec::with_capacity(n_chunks);
    slots.resize_with(n_chunks, || None);
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    let next = AtomicUsize::new(0);
    let workers = threads.min(n_chunks);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let next = &next;
            let slots_ptr = &slots_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_chunks {
                    break;
                }
                let start = i * chunk;
                let end = (start + chunk).min(data.len());
                let r = f(i, &data[start..end]);
                // SAFETY: each index i is claimed exactly once via the
                // atomic counter, so writes to slots[i] are disjoint;
                // the scope joins all workers before `slots` is read.
                unsafe { *slots_ptr.0.add(i) = Some(r) };
            });
        }
    });

    slots.into_iter().map(|s| s.expect("chunk result missing")).collect()
}

/// Raw pointer wrapper asserting cross-thread transfer is safe under the
/// disjoint-write discipline documented at the use site.
///
/// SAFETY contract: holders may only *write* `T` values through the
/// pointer, each index from exactly one thread (the atomic work counter
/// guarantees disjointness), and the owning scope must join all workers
/// before the pointee is read.  Writing a `T` on another thread is a
/// cross-thread transfer of `T`, hence the `T: Send` bound — an
/// unbounded impl would let `parallel_chunks` smuggle `!Send` types
/// (e.g. `Rc` results) across threads.
struct SendPtr<T>(*mut T);
// SAFETY: per the contract above — holders only write, each index from
// exactly one thread, and the scope joins all workers before the
// pointee is read; `T: Send` makes the cross-thread write of `T` sound.
unsafe impl<T: Send> Sync for SendPtr<T> {}
// SAFETY: as above — moving the wrapper only moves the raw pointer;
// the `T: Send` bound covers the values written through it.
unsafe impl<T: Send> Send for SendPtr<T> {}

/// Default parallelism: physical parallelism reported by the OS.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_chunks_orders_results() {
        let data: Vec<u64> = (0..1000).collect();
        let sums = parallel_chunks(4, &data, 64, |_, c| c.iter().sum::<u64>());
        assert_eq!(sums.len(), 16);
        assert_eq!(sums.iter().sum::<u64>(), 499_500);
        assert_eq!(sums[0], (0..64).sum::<u64>());
        assert_eq!(*sums.last().unwrap(), (960..1000).sum::<u64>());
    }

    #[test]
    fn parallel_matches_serial_for_all_thread_counts() {
        let data: Vec<u64> = (0..777).collect();
        let serial = parallel_chunks(1, &data, 50, |i, c| (i, c.iter().sum::<u64>()));
        for threads in [2, 3, 8, 32] {
            let par = parallel_chunks(threads, &data, 50, |i, c| (i, c.iter().sum::<u64>()));
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn parallel_chunks_empty_and_tiny() {
        let out: Vec<usize> = parallel_chunks(4, &[] as &[u8], 4, |_, c| c.len());
        assert!(out.is_empty());
        let out = parallel_chunks(4, &[9u8], 4, |i, c| (i, c.len()));
        assert_eq!(out, vec![(0, 1)]);
    }

    #[test]
    fn uneven_tail_chunk() {
        let data: Vec<u8> = vec![1; 10];
        let lens = parallel_chunks(3, &data, 4, |_, c| c.len());
        assert_eq!(lens, vec![4, 4, 2]);
    }
}
