//! Deterministic PRNG substrate (the offline registry has no `rand`).
//!
//! [`SplitMix64`] seeds [`Xoshiro256pp`] (xoshiro256++, Blackman &
//! Vigna), which drives uniform/normal/logit-like distributions used by
//! the workload generators, the property-testing harness, and the
//! benchmark drivers.  Everything is seedable and reproducible; no
//! global state.

/// SplitMix64 — used for seeding and as a tiny standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64, per the reference implementation's guidance.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` (f32).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is undefined");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (cached second draw omitted for
    /// simplicity; callers in this crate are not throughput-bound here).
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > f64::MIN_POSITIVE {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// A logits-like vector: `scale * N(0,1)` per element — the workload
    /// shape used throughout the paper's benchmarks (fp32 logits).
    pub fn logits(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_normal() * scale).collect()
    }

    /// Fill a slice with `scale * N(0,1)` without allocating.
    pub fn fill_logits(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal() * scale;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 from the reference C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(1);
        let mut c = Xoshiro256pp::seed_from_u64(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..10_000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
            let d = r.next_f64();
            assert!((0.0..1.0).contains(&d));
        }
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Xoshiro256pp::seed_from_u64(99);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn logits_shape_and_scale() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let v = r.logits(4096, 10.0);
        assert_eq!(v.len(), 4096);
        let spread = v.iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(spread > 10.0, "scale should widen the distribution");
    }
}
