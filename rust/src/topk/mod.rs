//! Top-K selection: the insertion buffer of Algorithm 4 plus heap-based
//! and merge utilities used by the coordinator's shard reduction.
//!
//! [`TopKBuffer`] is the paper's (K+1)-slot structure (lines 3–4 and
//! 8–15): a descending-sorted value/index array where each new candidate
//! is written into slot K+1 and bubbled into place with a single
//! insertion loop.  Cost grows with K — exactly the effect the paper's
//! K-sweep (§5.2) measures, which the `k_sweep` bench reproduces.
//!
//! For large K (where the paper notes TopK dominates), [`heap_topk`]
//! gives the O(V log K) alternative used by the unfused baseline.

/// The running top-k candidate buffer of Algorithm 4.
#[derive(Clone, Debug)]
pub struct TopKBuffer {
    /// Values, descending; length K+1 (slot K+1 is insertion scratch).
    u: Vec<f32>,
    /// Indices aligned with `u`.
    p: Vec<i64>,
    k: usize,
}

impl TopKBuffer {
    /// Lines 3–4: initialize with −∞ values and −1 indices.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        Self { u: vec![f32::NEG_INFINITY; k + 1], p: vec![-1; k + 1], k }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Current k-th best value — candidates must strictly exceed this
    /// to enter the buffer (the hot-loop rejection threshold).
    #[inline]
    pub fn threshold(&self) -> f32 {
        self.u[self.k - 1]
    }

    /// Lines 8–15: place `(value, index)` in slot K+1 and bubble it up.
    ///
    /// Tie-breaking: on equal values the incumbent (earlier index in
    /// scan order) wins, matching the strict `<` of line 11.
    #[inline]
    pub fn push(&mut self, value: f32, index: i64) {
        let k = self.k;
        // Fast reject: strictly-not-better than the current k-th value.
        // (Equal values lose to the incumbent per line 11's strict `<`.)
        if value <= self.u[k - 1] {
            return;
        }
        self.u[k] = value;
        self.p[k] = index;
        let mut i = k;
        while i >= 1 && self.u[i - 1] < self.u[i] {
            self.u.swap(i - 1, i);
            self.p.swap(i - 1, i);
            i -= 1;
        }
    }

    /// The first K (value, index) pairs — lines 17–19's source.
    pub fn entries(&self) -> impl Iterator<Item = (f32, i64)> + '_ {
        self.u[..self.k].iter().copied().zip(self.p[..self.k].iter().copied())
    }

    /// Values only (descending).
    pub fn values(&self) -> &[f32] {
        &self.u[..self.k]
    }

    /// Indices aligned with [`values`](Self::values).
    pub fn indices(&self) -> &[i64] {
        &self.p[..self.k]
    }

    /// Number of real (non-sentinel) entries.
    pub fn len_filled(&self) -> usize {
        self.p[..self.k].iter().filter(|&&i| i >= 0).count()
    }

    /// Merge another buffer into this one (associative: used for lane,
    /// thread, and vocabulary-shard combination).
    pub fn merge(&mut self, other: &TopKBuffer) {
        assert_eq!(self.k, other.k, "cannot merge buffers of different k");
        for (v, i) in other.entries() {
            if i >= 0 {
                self.push(v, i);
            }
        }
    }
}

/// Scan a slice into a fresh buffer: `TopK(x)` with global indices
/// offset by `base` (vocabulary shards pass their shard offset).
pub fn scan_topk(x: &[f32], k: usize, base: i64) -> TopKBuffer {
    let mut buf = TopKBuffer::new(k);
    for (i, &v) in x.iter().enumerate() {
        buf.push(v, base + i as i64);
    }
    buf
}

/// O(V log K) heap-based top-k (the conventional unfused TopK kernel).
/// Returns (values, indices) sorted descending, ties broken by lower index.
pub fn heap_topk(x: &[f32], k: usize) -> (Vec<f32>, Vec<i64>) {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Min-heap entry ordered by (value, Reverse(index)) so the heap
    /// root is the weakest entry: smallest value, then largest index.
    #[derive(PartialEq)]
    struct Entry(f32, Reverse<i64>);
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            self.0.total_cmp(&o.0).then(self.1.cmp(&o.1))
        }
    }

    let k = k.min(x.len());
    let mut heap: BinaryHeap<Reverse<Entry>> = BinaryHeap::with_capacity(k + 1);
    for (i, &v) in x.iter().enumerate() {
        if heap.len() < k {
            heap.push(Reverse(Entry(v, Reverse(i as i64))));
        } else if let Some(Reverse(weakest)) = heap.peek() {
            if Entry(v, Reverse(i as i64)) > *weakest {
                heap.pop();
                heap.push(Reverse(Entry(v, Reverse(i as i64))));
            }
        }
    }
    let mut pairs: Vec<(f32, i64)> =
        heap.into_iter().map(|Reverse(Entry(v, Reverse(i)))| (v, i)).collect();
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    pairs.into_iter().unzip()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_finds_true_topk() {
        let x = [3.0f32, 9.0, -1.0, 7.0, 7.5, 0.0, 8.0];
        let buf = scan_topk(&x, 3, 0);
        assert_eq!(buf.values(), &[9.0, 8.0, 7.5]);
        assert_eq!(buf.indices(), &[1, 6, 4]);
        assert_eq!(buf.len_filled(), 3);
    }

    #[test]
    fn ties_keep_earliest_index() {
        let x = [5.0f32, 5.0, 5.0, 5.0];
        let buf = scan_topk(&x, 2, 0);
        assert_eq!(buf.indices(), &[0, 1], "line 11 strict `<` keeps incumbents");
    }

    #[test]
    fn k_larger_than_input_leaves_sentinels() {
        let buf = scan_topk(&[1.0, 2.0], 4, 0);
        assert_eq!(buf.len_filled(), 2);
        assert_eq!(buf.values()[..2], [2.0, 1.0]);
        assert_eq!(buf.indices()[2..], [-1, -1]);
    }

    #[test]
    fn base_offset_globalizes_indices() {
        let buf = scan_topk(&[1.0, 9.0], 1, 1000);
        assert_eq!(buf.indices(), &[1001]);
    }

    #[test]
    fn merge_equals_whole_scan() {
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(7);
        let x = rng.logits(500, 10.0);
        let whole = scan_topk(&x, 8, 0);
        let mut merged = TopKBuffer::new(8);
        for (c, chunk) in x.chunks(97).enumerate() {
            let part = scan_topk(chunk, 8, (c * 97) as i64);
            merged.merge(&part);
        }
        assert_eq!(whole.values(), merged.values());
        assert_eq!(whole.indices(), merged.indices());
    }

    #[test]
    fn heap_matches_buffer() {
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(11);
        for n in [1usize, 5, 100, 1000] {
            let x = rng.logits(n, 5.0);
            for k in [1usize, 3, 10] {
                let keff = k.min(n);
                let buf = scan_topk(&x, keff, 0);
                let (hv, hi) = heap_topk(&x, k);
                assert_eq!(hv.len(), keff);
                assert_eq!(buf.values()[..keff], hv[..], "n={n} k={k}");
                assert_eq!(buf.indices()[..keff], hi[..], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn heap_tie_break_matches_buffer() {
        let x = [2.0f32, 3.0, 3.0, 1.0, 3.0];
        let (hv, hi) = heap_topk(&x, 3);
        assert_eq!(hv, vec![3.0, 3.0, 3.0]);
        assert_eq!(hi, vec![1, 2, 4]);
        let buf = scan_topk(&x, 3, 0);
        assert_eq!(buf.indices(), &hi[..]);
    }

    #[test]
    fn k_equals_one() {
        let x = [0.5f32, -3.0, 9.0, 9.0, 2.0];
        let buf = scan_topk(&x, 1, 0);
        assert_eq!(buf.values(), &[9.0]);
        assert_eq!(buf.indices(), &[2], "first occurrence wins the tie");
        assert_eq!(buf.threshold(), 9.0);
        // merge of two k=1 buffers keeps the global argmax
        let mut left = scan_topk(&x[..2], 1, 0);
        left.merge(&scan_topk(&x[2..], 1, 2));
        assert_eq!(left.indices(), &[2]);
    }

    #[test]
    fn k_at_and_above_v_returns_everything() {
        let x = [2.0f32, 7.0, -1.0];
        for k in [3usize, 4, 10] {
            let buf = scan_topk(&x, k, 0);
            assert_eq!(buf.len_filled(), 3, "k={k}");
            assert_eq!(&buf.values()[..3], &[7.0, 2.0, -1.0], "k={k}");
            assert_eq!(&buf.indices()[..3], &[1, 0, 2], "k={k}");
            // sentinel tail stays untouched (indices() has length k ≥ 3)
            assert!(buf.indices()[3..].iter().all(|&i| i == -1), "k={k}");
        }
    }

    #[test]
    fn all_equal_values_keep_scan_order_across_merge() {
        // Incumbent-wins (line 11's strict `<`) must survive a
        // cross-shard merge: shard 0's indices beat shard 1's.
        let a = scan_topk(&[5.0f32; 4], 3, 0);
        let b = scan_topk(&[5.0f32; 4], 3, 4);
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.indices(), &[0, 1, 2]);
        // ... and merge order decides nothing the values don't: b-first
        // still yields b's earliest indices as incumbents.
        let mut merged = b.clone();
        merged.merge(&a);
        assert_eq!(merged.indices(), &[4, 5, 6]);
    }

    #[test]
    fn nan_candidates_are_dropped() {
        // NaN fails every `>` comparison, so it can neither pass the
        // rejection gate nor bubble past slot K+1 — the buffer stays
        // NaN-free and ordered.
        let x = [1.0f32, f32::NAN, 3.0, f32::NAN, 2.0];
        let buf = scan_topk(&x, 2, 0);
        assert_eq!(buf.values(), &[3.0, 2.0]);
        assert_eq!(buf.indices(), &[2, 4]);
        assert!(buf.values().iter().all(|v| !v.is_nan()));
        // an all-NaN scan leaves only sentinels
        let buf = scan_topk(&[f32::NAN; 3], 2, 0);
        assert_eq!(buf.len_filled(), 0);
    }

    #[test]
    fn neg_infinity_never_displaces_sentinels() {
        // −∞ (vocabulary padding) ties the sentinel value and loses to
        // the incumbent, so it never enters as a "real" entry.
        let buf = scan_topk(&[f32::NEG_INFINITY; 5], 3, 0);
        assert_eq!(buf.len_filled(), 0);
        assert_eq!(buf.indices(), &[-1, -1, -1]);
        // mixed: finite values fill, −∞ stays out
        let buf = scan_topk(&[f32::NEG_INFINITY, 4.0, f32::NEG_INFINITY], 2, 0);
        assert_eq!(buf.len_filled(), 1);
        assert_eq!(buf.indices()[0], 1);
    }

    #[test]
    fn cross_shard_merge_with_uneven_and_sentinel_shards() {
        // Shards smaller than k contribute fewer than k real entries;
        // the merge must take exactly the global top-k anyway.
        let x = [9.0f32, 1.0, 8.0, 2.0, 7.0, 3.0, 6.0];
        let k = 4;
        let whole = scan_topk(&x, k, 0);
        let mut merged = TopKBuffer::new(k);
        for (base, chunk) in [(0usize, &x[..2]), (2, &x[2..3]), (3, &x[3..])] {
            merged.merge(&scan_topk(chunk, k, base as i64));
        }
        assert_eq!(merged.values(), whole.values());
        assert_eq!(merged.indices(), whole.indices());
    }

    #[test]
    #[should_panic(expected = "k must be positive")]
    fn zero_k_panics() {
        TopKBuffer::new(0);
    }

    #[test]
    #[should_panic(expected = "different k")]
    fn merge_mismatched_k_panics() {
        let mut a = TopKBuffer::new(2);
        a.merge(&TopKBuffer::new(3));
    }
}
