//! Router tier: multi-process sharded serving over stock `osmax`
//! workers.
//!
//! The paper's ⊕ merge (eq. 4) is associative and location-transparent:
//! a `ShardPartial` computed in another *process* merges exactly like
//! one computed on another thread.  This module exploits that to scale
//! the serving surface across worker processes — each worker is a
//! normal host-backend server assigned a vocabulary slice
//! (`--worker-slice START:END`), and the router fans every request's
//! shards out over `shard_scan` frames, then runs **the same tree
//! reduction the in-process grid path runs**:
//!
//! ```text
//!  client ──► router (Backend::Router)
//!               │ ShardPlan::with_shards(vocab, N)   [fixed at startup]
//!               ├── shard 0 ── shard_scan ──► worker 0 ─► partial₀ ┐
//!               ├── shard 1 ── shard_scan ──► worker 1 ─► partial₁ ├─ ⊕ tree
//!               └── shard 2 ── shard_scan ──► worker 2 ─► partial₂ ┘    │
//!                                                                finalize ─► reply
//! ```
//!
//! **Bitwise identity.**  The router's decomposition is pinned at
//! startup (`with_shards(vocab, workers)`) and never changes — not for
//! failures, not for hedges.  Partial failure and load shedding change
//! only *which worker* computes a slice, never the slice boundaries, so
//! merged results are bitwise-identical to a single process serving the
//! same plan (`router_e2e` pins this across shard backends × pool
//! schedulers).
//!
//! **Partial failure.**  Per-worker connection pools with per-shard
//! timeouts; a transport failure excludes the worker and requeues its
//! slice onto the next healthy peer (one bounded retry,
//! `router.retry.requeued`).  A background prober pings every worker
//! each `probe_interval`, feeding the exclude/readmit list
//! (`router.worker.*`).  Typed worker rejections (a `ServeError`) are
//! **not** retried — they are deterministic and would fail anywhere.
//!
//! **Hedging.**  With `hedge_quantile ∈ (0, 1)` set, a shard still
//! outstanding past that latency quantile is duplicated onto a second
//! healthy worker; the first successful reply wins and the loser is
//! discarded *before* the merge (`router.hedge.*`).  The ⊕ tree always
//! sees exactly one partial per shard — the top-k buffer merge
//! re-inserts equal values, so merging a duplicate partial would NOT
//! be idempotent; winner-selection at the channel is what makes hedges
//! safe.

#![warn(missing_docs)]

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{ServeError, ShardScan, ShardScanKind};
use crate::json::Value;
use crate::metrics::{self, Counter, Gauge, Histogram};
use crate::sample::SampleSpec;
use crate::server::wire;
use crate::shard::{reduce, ShardPartial, ShardPlan, ShardRange};
use crate::softmax::monoid::{self, MD};

/// Lock acquisition that survives a poisoned mutex: router state is
/// plain data (no invariants broken by a panicking holder), so
/// recovering the inner value is always sound here.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Latency-ring capacity backing the hedge quantile estimate.
const LATENCY_RING: usize = 256;

/// Minimum observed shard calls before hedging arms — quantiles over
/// fewer samples are noise.
const HEDGE_MIN_SAMPLES: usize = 16;

/// Router construction parameters (derived from `ServeConfig` by the
/// executor's `Backend::Router` arm).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Worker addresses, one per vocabulary shard (`host:port`).
    pub workers: Vec<String>,
    /// Global vocabulary size; sliced as `with_shards(vocab, workers)`.
    pub vocab: usize,
    /// Health-probe period.
    pub probe_interval: Duration,
    /// Per-shard call budget (connect + roundtrip).
    pub shard_timeout: Duration,
    /// Straggler-hedging latency quantile in `[0, 1)`; `0` disables
    /// hedging.
    pub hedge_quantile: f64,
}

/// How a single worker call failed.
#[derive(Debug)]
enum CallError {
    /// Connection-level failure (connect, io, timeout, malformed
    /// reply): the worker is suspect — exclude and requeue.
    Transport(String),
    /// A typed rejection from a healthy worker: deterministic, never
    /// retried.
    App(ServeError),
}

/// One pooled worker connection.
struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

/// A per-worker connection pool (lazy: connections are dialed on first
/// use, so the router starts cleanly with workers still booting).
struct WorkerPool {
    addr: String,
    timeout: Duration,
    idle: Mutex<Vec<Conn>>,
}

impl WorkerPool {
    fn new(addr: String, timeout: Duration) -> WorkerPool {
        WorkerPool { addr, timeout, idle: Mutex::new(Vec::new()) }
    }

    fn checkout(&self) -> Result<Conn, CallError> {
        if let Some(conn) = lock(&self.idle).pop() {
            return Ok(conn);
        }
        let mut addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| CallError::Transport(format!("resolve {}: {e}", self.addr)))?;
        let addr = addrs
            .next()
            .ok_or_else(|| CallError::Transport(format!("{} resolves to nothing", self.addr)))?;
        let stream = TcpStream::connect_timeout(&addr, self.timeout)
            .map_err(|e| CallError::Transport(format!("connect {}: {e}", self.addr)))?;
        let transport = |e: std::io::Error| CallError::Transport(format!("{}: {e}", self.addr));
        stream.set_nodelay(true).map_err(transport)?;
        stream.set_read_timeout(Some(self.timeout)).map_err(transport)?;
        stream.set_write_timeout(Some(self.timeout)).map_err(transport)?;
        let writer = stream.try_clone().map_err(transport)?;
        Ok(Conn { writer, reader: BufReader::new(stream) })
    }

    /// One request/response roundtrip.  The connection returns to the
    /// pool only after a *complete* roundtrip — a failed connection is
    /// dropped (closing the socket), so request/response framing can
    /// never desynchronize across calls.
    fn call(&self, line: &str) -> Result<Value, CallError> {
        let mut conn = self.checkout()?;
        let transport = |e: std::io::Error| CallError::Transport(format!("{}: {e}", self.addr));
        conn.writer.write_all(line.as_bytes()).map_err(transport)?;
        conn.writer.write_all(b"\n").map_err(transport)?;
        conn.writer.flush().map_err(transport)?;
        let mut response = String::new();
        let n = conn.reader.read_line(&mut response).map_err(transport)?;
        if n == 0 {
            return Err(CallError::Transport(format!("{}: connection closed", self.addr)));
        }
        match wire::decode_response(&response) {
            Ok(v) => {
                lock(&self.idle).push(conn);
                Ok(v)
            }
            Err(e) => match e.downcast_ref::<wire::WireError>() {
                // A structured rejection still completed its roundtrip:
                // the connection stays poolable and the error is typed.
                Some(w) => {
                    let code = w.code.unwrap_or(crate::coordinator::ErrorCode::Internal);
                    lock(&self.idle).push(conn);
                    Err(CallError::App(ServeError::new(code, w.message.clone())))
                }
                None => Err(CallError::Transport(format!("{}: {e:#}", self.addr))),
            },
        }
    }
}

/// Mutable router state shared with the prober thread.
struct RouterState {
    /// Exclude list, indexed like `Router::pools`.
    excluded: Mutex<Vec<bool>>,
    /// Recent shard-call latencies (µs) feeding the hedge quantile.
    latencies: Mutex<VecDeque<u64>>,
    /// Prober shutdown flag + wakeup (Mutex/Condvar rather than an
    /// atomic: stop is control-plane, no need for lock-free).
    stop: Mutex<bool>,
    stop_cv: Condvar,
    shard_timeout: Duration,
    hedge_quantile: f64,
    probes: Arc<Counter>,
    probe_failures: Arc<Counter>,
    readmitted: Arc<Counter>,
    excluded_gauge: Arc<Gauge>,
    retry_requeued: Arc<Counter>,
    retry_failed: Arc<Counter>,
    hedge_launched: Arc<Counter>,
    hedge_wins: Arc<Counter>,
    latency_hist: Arc<Histogram>,
}

impl RouterState {
    fn exclude(&self, worker: usize, why: &str) {
        let mut ex = lock(&self.excluded);
        if !ex[worker] {
            ex[worker] = true;
            crate::warn_!("router", "excluding worker {worker}: {why}");
        }
        self.excluded_gauge.set(ex.iter().filter(|&&e| e).count() as i64);
    }

    fn readmit(&self, worker: usize) {
        let mut ex = lock(&self.excluded);
        if ex[worker] {
            ex[worker] = false;
            self.readmitted.inc();
            crate::info!("router", "readmitting worker {worker} (probe succeeded)");
        }
        self.excluded_gauge.set(ex.iter().filter(|&&e| e).count() as i64);
    }

    /// First non-excluded worker at or after `from` (wrapping), or
    /// `None` when every worker is excluded.
    fn next_healthy(&self, from: usize, n: usize) -> Option<usize> {
        let ex = lock(&self.excluded);
        (0..n).map(|i| (from + i) % n).find(|&w| !ex[w])
    }

    fn record_latency(&self, d: Duration) {
        self.latency_hist.record(d);
        let mut ring = lock(&self.latencies);
        if ring.len() == LATENCY_RING {
            ring.pop_front();
        }
        ring.push_back(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Hedge launch delay: the configured latency quantile over the
    /// recent ring.  `None` (hedging off) until the quantile is armed,
    /// sampled, and meaningfully below the shard timeout.
    fn hedge_delay(&self) -> Option<Duration> {
        if self.hedge_quantile <= 0.0 {
            return None;
        }
        let ring = lock(&self.latencies);
        if ring.len() < HEDGE_MIN_SAMPLES {
            return None;
        }
        let mut sorted: Vec<u64> = ring.iter().copied().collect();
        drop(ring);
        sorted.sort_unstable();
        let idx = ((sorted.len() - 1) as f64 * self.hedge_quantile) as usize;
        let delay = Duration::from_micros(sorted[idx]);
        (delay < self.shard_timeout).then_some(delay)
    }
}

/// The router tier: a fixed shard plan over N worker processes, with
/// health probing, bounded requeue retry, and straggler hedging.  See
/// the module docs for the full semantics.
pub struct Router {
    plan: ShardPlan,
    pools: Vec<Arc<WorkerPool>>,
    state: Arc<RouterState>,
    prober: Mutex<Option<JoinHandle<()>>>,
}

impl Router {
    /// Build the tier and start its health prober.  Connections are
    /// lazy — construction succeeds with every worker still down; the
    /// first request (or probe) discovers actual health.
    pub fn new(cfg: RouterConfig) -> Result<Router> {
        if cfg.workers.is_empty() {
            bail!("router backend requires at least one worker address");
        }
        if cfg.vocab < cfg.workers.len() {
            bail!(
                "vocab {} cannot be sliced over {} workers",
                cfg.vocab,
                cfg.workers.len()
            );
        }
        let reg = metrics::global();
        let state = Arc::new(RouterState {
            excluded: Mutex::new(vec![false; cfg.workers.len()]),
            latencies: Mutex::new(VecDeque::with_capacity(LATENCY_RING)),
            stop: Mutex::new(false),
            stop_cv: Condvar::new(),
            shard_timeout: cfg.shard_timeout,
            hedge_quantile: cfg.hedge_quantile,
            probes: reg.counter("router.worker.probes"),
            probe_failures: reg.counter("router.worker.probe_failures"),
            readmitted: reg.counter("router.worker.readmitted"),
            excluded_gauge: reg.gauge("router.worker.excluded"),
            retry_requeued: reg.counter("router.retry.requeued"),
            retry_failed: reg.counter("router.retry.failed"),
            hedge_launched: reg.counter("router.hedge.launched"),
            hedge_wins: reg.counter("router.hedge.wins"),
            latency_hist: reg.histogram("router.shard.call_us"),
        });
        let pools: Vec<Arc<WorkerPool>> = cfg
            .workers
            .iter()
            .map(|addr| Arc::new(WorkerPool::new(addr.clone(), cfg.shard_timeout)))
            .collect();
        let plan = ShardPlan::with_shards(cfg.vocab, pools.len());
        crate::info!(
            "router",
            "router tier over {} workers, {} vocab slices, probe every {:?}",
            pools.len(),
            plan.shards(),
            cfg.probe_interval
        );
        let prober = {
            let state = state.clone();
            let pools = pools.clone();
            std::thread::Builder::new()
                .name("router-prober".to_string())
                .spawn(move || prober_loop(&state, &pools, cfg.probe_interval))?
        };
        Ok(Router { plan, pools, state, prober: Mutex::new(Some(prober)) })
    }

    /// The fixed vocabulary decomposition (one slice per worker).
    pub fn plan(&self) -> ShardPlan {
        self.plan
    }

    /// Number of worker processes behind the tier.
    pub fn workers(&self) -> usize {
        self.pools.len()
    }

    /// Stop the health prober (idempotent).
    pub fn shutdown(&self) {
        *lock(&self.state.stop) = true;
        self.state.stop_cv.notify_all();
        if let Some(h) = lock(&self.prober).take() {
            let _ = h.join();
        }
    }

    // ----- public query surface (called by the executor) ------------------

    /// Distributed decode: fan hidden states out as `shard_scan
    /// kind=decode` frames, ⊕-merge the returned partials per row, and
    /// finalize — greedy rows via [`ShardPartial::finalize`], sampled
    /// rows via [`ShardPartial::finalize_sampled`].  Bitwise-identical
    /// to the in-process grid path under the same plan.
    pub fn decode(
        &self,
        states: &[&[f32]],
        k: usize,
        specs: &[Option<SampleSpec>],
    ) -> Result<Vec<(Vec<f32>, Vec<i64>)>, ServeError> {
        assert_eq!(states.len(), specs.len(), "specs must align with states");
        let rows: Vec<Vec<f32>> = states.iter().map(|s| s.to_vec()).collect();
        let sampled: Vec<bool> = specs.iter().map(Option::is_some).collect();
        // Per shard: call, then decode + validate the partials reply.
        let shard_parts: Vec<Vec<ShardPartial>> = self.scatter(|range| {
            let scan = ShardScan {
                kind: ShardScanKind::Decode,
                start: range.start,
                end: range.end,
                k,
                rows: rows.clone(),
                samples: specs.to_vec(),
                norms: Vec::new(),
            };
            let reply = self.shard_call(&scan, range.index)?;
            wire::decode_shard_partials(&reply, rows.len(), k, range.start, range.end, &sampled)
                .map_err(|e| {
                    ServeError::internal(format!("shard {} reply: {e:#}", range.index))
                })
        })?;
        // Per row: transpose to shard order and run the same ⊕ tree the
        // in-process grid reduction runs.
        Ok((0..rows.len())
            .map(|r| {
                let parts: Vec<ShardPartial> =
                    shard_parts.iter().map(|shard| shard[r].clone()).collect();
                let merged = reduce::tree_reduce(parts);
                if sampled[r] {
                    merged.finalize_sampled()
                } else {
                    merged.finalize()
                }
            })
            .collect())
    }

    /// Distributed softmax: pass 1 collects per-shard `(m, d)` partials
    /// and ⊕-reduces them per row ([`monoid::tree_reduce`], the same
    /// bracketing as the in-process normalizer grid); pass 2 ships the
    /// merged normalizers back out for the scale pass and concatenates
    /// the returned probability slices in shard order.
    pub fn softmax(&self, rows: &[&[f32]]) -> Result<Vec<Vec<f32>>, ServeError> {
        let v = self.plan.v();
        for row in rows {
            assert_eq!(row.len(), v, "router softmax rows must match the vocab");
        }
        // Pass 1: per-shard partial normalizers.
        let shard_norms: Vec<Vec<MD>> = self.scatter(|range| {
            let scan = ShardScan {
                kind: ShardScanKind::Softmax,
                start: range.start,
                end: range.end,
                k: 0,
                rows: rows.iter().map(|r| r[range.start..range.end].to_vec()).collect(),
                samples: Vec::new(),
                norms: Vec::new(),
            };
            let reply = self.shard_call(&scan, range.index)?;
            wire::decode_shard_norms(&reply, rows.len()).map_err(|e| {
                ServeError::internal(format!("shard {} reply: {e:#}", range.index))
            })
        })?;
        let merged: Vec<MD> = (0..rows.len())
            .map(|r| {
                let mds: Vec<MD> = shard_norms.iter().map(|shard| shard[r]).collect();
                monoid::tree_reduce(&mds)
            })
            .collect();
        // Pass 2: scale each slice under its row's global (m, d).
        let shard_slices: Vec<Vec<Vec<f32>>> = self.scatter(|range| {
            let scan = ShardScan {
                kind: ShardScanKind::Scale,
                start: range.start,
                end: range.end,
                k: 0,
                rows: rows.iter().map(|r| r[range.start..range.end].to_vec()).collect(),
                samples: Vec::new(),
                norms: merged.clone(),
            };
            let reply = self.shard_call(&scan, range.index)?;
            wire::decode_shard_slices(&reply, rows.len(), range.end - range.start).map_err(
                |e| ServeError::internal(format!("shard {} reply: {e:#}", range.index)),
            )
        })?;
        Ok((0..rows.len())
            .map(|r| {
                let mut out = Vec::with_capacity(v);
                for shard in &shard_slices {
                    out.extend_from_slice(&shard[r]);
                }
                out
            })
            .collect())
    }

    // ----- fan-out machinery ----------------------------------------------

    /// Run `f` once per shard range on scoped threads; first error
    /// wins.  The decomposition is `self.plan` — always, which is what
    /// keeps failure handling orthogonal to numerics.
    fn scatter<T: Send>(
        &self,
        f: impl Fn(ShardRange) -> Result<T, ServeError> + Sync,
    ) -> Result<Vec<T>, ServeError> {
        let ranges: Vec<ShardRange> = self.plan.ranges().collect();
        let f = &f;
        let joined: Vec<std::thread::Result<Result<T, ServeError>>> =
            std::thread::scope(|s| {
                let handles: Vec<_> =
                    ranges.iter().map(|&range| s.spawn(move || f(range))).collect();
                handles.into_iter().map(|h| h.join()).collect()
            });
        joined
            .into_iter()
            .map(|j| match j {
                Ok(r) => r,
                Err(_) => Err(ServeError::internal("router shard task panicked")),
            })
            .collect()
    }

    /// Issue one shard's scan with the full recovery ladder: excluded
    /// primary → requeue to a healthy peer; transport failure → exclude
    /// + one bounded retry on the next healthy peer; hedging inside
    /// each attempt.  Typed worker rejections propagate immediately.
    fn shard_call(&self, scan: &ShardScan, shard: usize) -> Result<Value, ServeError> {
        let n = self.pools.len();
        let line = wire::encode_shard_scan(scan);
        let primary = shard % n;
        let first = match self.state.next_healthy(primary, n) {
            Some(w) => w,
            None => {
                // Every worker is excluded: optimistically try the
                // primary anyway (probes may simply not have caught a
                // recovery yet); its own failure handling applies.
                primary
            }
        };
        if first != primary {
            self.state.retry_requeued.inc();
            crate::debug!("router", "shard {shard}: primary {primary} excluded, requeued to {first}");
        }
        match self.attempt(&line, first) {
            Ok(v) => Ok(v),
            Err(CallError::App(e)) => Err(worker_rejection(first, e)),
            Err(CallError::Transport(why)) => {
                self.state.exclude(first, &why);
                let Some(second) =
                    self.state.next_healthy((first + 1) % n, n).filter(|&w| w != first)
                else {
                    self.state.retry_failed.inc();
                    return Err(ServeError::internal(format!(
                        "shard {shard} failed with no healthy peer to requeue onto: {why}"
                    )));
                };
                self.state.retry_requeued.inc();
                crate::warn_!(
                    "router",
                    "shard {shard}: worker {first} failed ({why}), requeueing onto {second}"
                );
                match self.attempt(&line, second) {
                    Ok(v) => Ok(v),
                    Err(CallError::App(e)) => Err(worker_rejection(second, e)),
                    Err(CallError::Transport(why2)) => {
                        self.state.exclude(second, &why2);
                        self.state.retry_failed.inc();
                        Err(ServeError::internal(format!(
                            "shard {shard} failed on worker {first} ({why}) and requeued \
                             worker {second} ({why2})"
                        )))
                    }
                }
            }
        }
    }

    /// One attempt against `worker`, hedged: if the call is still
    /// outstanding past the hedge delay, duplicate it onto another
    /// healthy worker and take the first success.  Exactly one reply is
    /// ever returned — the loser is discarded here, so the ⊕ merge
    /// never sees a duplicated shard.
    fn attempt(&self, line: &str, worker: usize) -> Result<Value, CallError> {
        let t0 = Instant::now();
        let deadline = t0 + self.state.shard_timeout;
        let hedge_at = self.state.hedge_delay().map(|d| t0 + d);
        let (tx, rx) = mpsc::channel::<(usize, Result<Value, CallError>)>();
        let spawn_call = |w: usize| {
            let pool = self.pools[w].clone();
            let line = line.to_string();
            let tx = tx.clone();
            std::thread::spawn(move || {
                let _ = tx.send((w, pool.call(&line)));
            });
        };
        spawn_call(worker);
        let mut outstanding = 1usize;
        let mut hedged = false;
        let mut last_err = CallError::Transport("no attempt completed".to_string());
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Err(CallError::Transport(format!(
                    "shard call timed out after {:?}",
                    self.state.shard_timeout
                )));
            }
            let wake = match hedge_at {
                Some(at) if !hedged && at < deadline => at.max(now),
                _ => deadline,
            };
            match rx.recv_timeout(wake - now) {
                Ok((from, Ok(v))) => {
                    self.state.record_latency(t0.elapsed());
                    if from != worker {
                        self.state.hedge_wins.inc();
                        crate::debug!("router", "hedge won: worker {from} beat {worker}");
                    }
                    return Ok(v);
                }
                Ok((_, Err(CallError::App(e)))) => {
                    // Deterministic rejection: any peer would answer
                    // the same, so don't wait out a hedge.
                    return Err(CallError::App(e));
                }
                Ok((from, Err(CallError::Transport(why)))) => {
                    outstanding -= 1;
                    if hedged {
                        // A hedged sibling may still win; only exclude
                        // the failed copy's worker if it wasn't the
                        // last hope.
                        if from != worker {
                            self.state.exclude(from, &why);
                        }
                    }
                    last_err = CallError::Transport(why);
                    if outstanding == 0 {
                        return Err(last_err);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let past_hedge = hedge_at.is_some_and(|at| Instant::now() >= at);
                    if !hedged && past_hedge {
                        hedged = true; // arm once whether or not a peer exists
                        let n = self.pools.len();
                        if let Some(backup) = self
                            .state
                            .next_healthy((worker + 1) % n, n)
                            .filter(|&w| w != worker)
                        {
                            self.state.hedge_launched.inc();
                            crate::debug!(
                                "router",
                                "hedging straggler on worker {worker} with {backup}"
                            );
                            spawn_call(backup);
                            outstanding += 1;
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // All attempt threads died without sending —
                    // impossible (they always send), but never hang.
                    return Err(last_err);
                }
            }
        }
    }
}

/// Propagate a typed worker rejection, naming the worker.  The code is
/// preserved — a worker's `deadline_exceeded` or `invalid_argument` is
/// the client-visible truth, not a router fault.
fn worker_rejection(worker: usize, e: ServeError) -> ServeError {
    ServeError::new(e.code, format!("worker {worker}: {}", e.message))
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Health-probe loop: ping every worker each period, excluding failures
/// and readmitting recoveries.
fn prober_loop(state: &RouterState, pools: &[Arc<WorkerPool>], period: Duration) {
    let ping = {
        let mut v = Value::object();
        v.set("v", Value::Number(wire::PROTOCOL_VERSION as f64))
            .set("op", Value::String("ping".to_string()));
        v.to_json()
    };
    loop {
        let stopped = {
            let guard = lock(&state.stop);
            let (guard, _timeout) = state
                .stop_cv
                .wait_timeout(guard, period)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            *guard
        };
        if stopped {
            return;
        }
        for (w, pool) in pools.iter().enumerate() {
            state.probes.inc();
            match pool.call(&ping) {
                Ok(_) => state.readmit(w),
                Err(e) => {
                    state.probe_failures.inc();
                    let why = match e {
                        CallError::Transport(why) => why,
                        CallError::App(e) => e.to_string(),
                    };
                    state.exclude(w, &format!("probe failed: {why}"));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_state(hedge_quantile: f64, timeout_ms: u64) -> RouterState {
        let reg = metrics::global();
        RouterState {
            excluded: Mutex::new(vec![false; 3]),
            latencies: Mutex::new(VecDeque::new()),
            stop: Mutex::new(false),
            stop_cv: Condvar::new(),
            shard_timeout: Duration::from_millis(timeout_ms),
            hedge_quantile,
            probes: reg.counter("router.test.probes"),
            probe_failures: reg.counter("router.test.probe_failures"),
            readmitted: reg.counter("router.test.readmitted"),
            excluded_gauge: reg.gauge("router.test.excluded"),
            retry_requeued: reg.counter("router.test.retry_requeued"),
            retry_failed: reg.counter("router.test.retry_failed"),
            hedge_launched: reg.counter("router.test.hedge_launched"),
            hedge_wins: reg.counter("router.test.hedge_wins"),
            latency_hist: reg.histogram("router.test.call_us"),
        }
    }

    #[test]
    fn exclude_readmit_and_next_healthy() {
        let s = test_state(0.0, 100);
        assert_eq!(s.next_healthy(0, 3), Some(0));
        assert_eq!(s.next_healthy(2, 3), Some(2));
        s.exclude(1, "test");
        assert_eq!(s.next_healthy(1, 3), Some(2), "skips the excluded worker");
        s.exclude(2, "test");
        assert_eq!(s.next_healthy(1, 3), Some(0), "wraps to the healthy one");
        s.exclude(0, "test");
        assert_eq!(s.next_healthy(0, 3), None, "all excluded");
        s.readmit(2);
        assert_eq!(s.next_healthy(0, 3), Some(2));
        // exclude/readmit are idempotent
        s.readmit(2);
        s.exclude(0, "again");
        assert_eq!(s.next_healthy(2, 3), Some(2));
    }

    #[test]
    fn hedge_delay_arms_only_with_data() {
        // quantile 0 = off, regardless of samples
        let s = test_state(0.0, 1000);
        for _ in 0..64 {
            s.record_latency(Duration::from_micros(500));
        }
        assert_eq!(s.hedge_delay(), None);

        // too few samples = off
        let s = test_state(0.9, 1000);
        for _ in 0..HEDGE_MIN_SAMPLES - 1 {
            s.record_latency(Duration::from_micros(500));
        }
        assert_eq!(s.hedge_delay(), None);
        // one more sample arms it at the ring's quantile
        s.record_latency(Duration::from_micros(500));
        assert_eq!(s.hedge_delay(), Some(Duration::from_micros(500)));

        // a delay at/above the shard timeout never hedges
        let s = test_state(0.9, 1);
        for _ in 0..64 {
            s.record_latency(Duration::from_millis(5));
        }
        assert_eq!(s.hedge_delay(), None, "quantile ≥ timeout disarms hedging");
    }

    #[test]
    fn hedge_quantile_picks_the_tail() {
        let s = test_state(0.5, 10_000);
        for us in 1..=100u64 {
            s.record_latency(Duration::from_micros(us));
        }
        let d = s.hedge_delay().expect("armed");
        assert!(
            (Duration::from_micros(40)..=Duration::from_micros(60)).contains(&d),
            "p50 of 1..=100µs should be ~50µs, got {d:?}"
        );
    }

    #[test]
    fn latency_ring_is_bounded() {
        let s = test_state(0.9, 10_000);
        for _ in 0..(LATENCY_RING + 100) {
            s.record_latency(Duration::from_micros(10));
        }
        assert_eq!(lock(&s.latencies).len(), LATENCY_RING);
    }

    #[test]
    fn router_rejects_bad_configs() {
        assert!(Router::new(RouterConfig {
            workers: vec![],
            vocab: 100,
            probe_interval: Duration::from_millis(100),
            shard_timeout: Duration::from_millis(100),
            hedge_quantile: 0.0,
        })
        .is_err());
        assert!(Router::new(RouterConfig {
            workers: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            vocab: 1,
            probe_interval: Duration::from_millis(100),
            shard_timeout: Duration::from_millis(100),
            hedge_quantile: 0.0,
        })
        .is_err());
    }

    #[test]
    fn shard_call_with_all_workers_down_is_typed_internal() {
        // Unroutable workers (reserved port 0 region): every attempt is
        // a fast transport failure → exclude + requeue once → typed
        // internal error, never a panic or hang.
        let router = Router::new(RouterConfig {
            workers: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into(), "127.0.0.1:3".into()],
            vocab: 30,
            probe_interval: Duration::from_secs(3600),
            shard_timeout: Duration::from_millis(200),
            hedge_quantile: 0.0,
        })
        .expect("lazy construction succeeds with workers down");
        assert_eq!(router.workers(), 3);
        assert_eq!(router.plan().shards(), 3);
        let rows = vec![vec![1.0f32; 30]];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let err = router.softmax(&refs).expect_err("no worker can serve");
        assert_eq!(err.code, crate::coordinator::ErrorCode::Internal);
        router.shutdown();
        router.shutdown(); // idempotent
    }
}
