//! Structured leveled logging substrate (no `tracing`/`env_logger` offline).
//!
//! A process-global logger with `error/warn/info/debug/trace` levels,
//! monotonic timestamps, and per-module targets.  Level is configured
//! via [`init`] or the `OSMAX_LOG` environment variable
//! (`error|warn|info|debug|trace`, default `info`).  Thread-safe;
//! writes are line-atomic via an internal mutex.

// xtask:atomics-allowlist: Relaxed
// Relaxed: the global level filter is an independent u8 cell — readers
// tolerate a stale level for a few records; no other memory is
// published through it (the writer mutex orders the output itself).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static SINK: Mutex<Option<Box<dyn Write + Send>>> = Mutex::new(None);

fn start_instant() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the global level explicitly (overrides `OSMAX_LOG`).
pub fn init(level: Level) {
    let _ = start_instant();
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the `OSMAX_LOG` environment variable.
pub fn init_from_env() {
    let level = std::env::var("OSMAX_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    init(level);
}

/// Redirect log output (tests use this to capture lines).
pub fn set_sink(sink: Option<Box<dyn Write + Send>>) {
    *SINK.lock().unwrap() = sink;
}

pub fn max_level() -> Level {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Core emit function — prefer the [`log!`](crate::log)/[`info!`](crate::info)
/// macros.
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start_instant().elapsed();
    let line = format!(
        "[{:>10.4}s {:5} {}] {}\n",
        t.as_secs_f64(),
        level.as_str(),
        target,
        msg
    );
    let mut guard = SINK.lock().unwrap();
    match guard.as_mut() {
        Some(w) => {
            let _ = w.write_all(line.as_bytes());
        }
        None => {
            let _ = std::io::stderr().write_all(line.as_bytes());
        }
    }
}

/// `log!(Level::Info, "target", "format {}", 1)`
#[macro_export]
macro_rules! log {
    ($lvl:expr, $target:expr, $($arg:tt)*) => {
        $crate::logging::emit($lvl, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Error, $target, $($arg)*) };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Warn, $target, $($arg)*) };
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Info, $target, $($arg)*) };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Debug, $target, $($arg)*) };
}

#[macro_export]
macro_rules! trace {
    ($target:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Trace, $target, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex as StdMutex};

    /// Shared buffer sink for capturing output in tests.
    struct BufSink(Arc<StdMutex<Vec<u8>>>);
    impl Write for BufSink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("bogus"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn filtering_and_capture() {
        let buf = Arc::new(StdMutex::new(Vec::new()));
        set_sink(Some(Box::new(BufSink(buf.clone()))));
        init(Level::Warn);
        crate::info!("test", "should be filtered");
        crate::warn_!("test", "visible {}", 42);
        set_sink(None);
        init(Level::Info);
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        assert!(text.contains("visible 42"), "{text}");
        assert!(!text.contains("filtered"), "{text}");
        assert!(text.contains("WARN"));
    }
}
