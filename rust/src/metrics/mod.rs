//! Metrics substrate: counters, gauges, and latency histograms with a
//! process-global registry (`prometheus`-style, but in-crate).
//!
//! The coordinator records queue depths, batch sizes, merge latencies,
//! and end-to-end request latencies here; `snapshot()` renders either a
//! human table or JSON for the server's `stats` endpoint.

// xtask:atomics-allowlist: Relaxed
// Relaxed: counters/gauges are independent monotonic cells scraped for
// telemetry; cross-metric consistency is explicitly not promised, so
// no ordering stronger than atomicity is needed (incl. the set_max
// CAS loop — each cell is self-contained).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous value (queue depth, active sessions, ...).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if it is below it (monotone high-water
    /// mark, e.g. peak batch occupancy).
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram: 2 buckets per octave from 1 µs to
/// ~1 hour, constant-time record, percentile estimation at bucket
/// resolution (≤ ~41% relative error worst case, fine for p50/p95/p99
/// serving dashboards).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const BUCKETS: usize = 64;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(ns: u64) -> usize {
        // Two buckets per octave of microseconds: [2^o, 1.5·2^o) and
        // [1.5·2^o, 2^{o+1}).  <1µs → bucket 0.
        let us = ns / 1_000;
        if us == 0 {
            return 0;
        }
        let octave = 63 - us.leading_zeros() as usize;
        let mid = (3u64 << octave) / 2; // 1.5 · 2^octave
        let half = usize::from(us >= mid);
        (2 * octave + half).min(BUCKETS - 1)
    }

    fn bucket_upper_ns(idx: usize) -> u64 {
        let octave = idx / 2;
        let half = idx % 2;
        let lo_us = 1u64 << octave;
        let upper_us = if half == 0 { lo_us + lo_us / 2 } else { lo_us * 2 };
        upper_us.max(1) * 1_000
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Percentile in [0, 100] estimated at bucket-boundary resolution.
    pub fn percentile(&self, p: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(Self::bucket_upper_ns(i));
            }
        }
        self.max()
    }
}

/// Named metric registry.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// JSON snapshot of every metric (served by the `stats` RPC).
    pub fn snapshot_json(&self) -> crate::json::Value {
        use crate::json::Value;
        let mut root = Value::object();
        let mut counters = Value::object();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.set(k, Value::Number(v.get() as f64));
        }
        let mut gauges = Value::object();
        for (k, v) in self.gauges.lock().unwrap().iter() {
            gauges.set(k, Value::Number(v.get() as f64));
        }
        let mut hists = Value::object();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let mut entry = Value::object();
            entry
                .set("count", Value::Number(h.count() as f64))
                .set("mean_us", Value::Number(h.mean().as_secs_f64() * 1e6))
                .set("p50_us", Value::Number(h.percentile(50.0).as_secs_f64() * 1e6))
                .set("p95_us", Value::Number(h.percentile(95.0).as_secs_f64() * 1e6))
                .set("p99_us", Value::Number(h.percentile(99.0).as_secs_f64() * 1e6))
                .set("max_us", Value::Number(h.max().as_secs_f64() * 1e6));
            hists.set(k, entry);
        }
        root.set("counters", counters).set("gauges", gauges).set("histograms", hists);
        root
    }
}

/// Process-global registry.
pub fn global() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::default)
}

/// Time a closure into a histogram.
pub fn timed<R>(h: &Histogram, f: impl FnOnce() -> R) -> R {
    let t0 = std::time::Instant::now();
    let r = f();
    h.record(t0.elapsed());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let reg = Registry::default();
        let c = reg.counter("reqs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        assert_eq!(reg.counter("reqs").get(), 5, "same instance by name");
        let g = reg.gauge("depth");
        g.set(3);
        g.inc();
        g.dec();
        assert_eq!(g.get(), 3);
        g.set_max(7);
        assert_eq!(g.get(), 7, "set_max raises");
        g.set_max(2);
        assert_eq!(g.get(), 7, "set_max never lowers");
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for us in [10u64, 20, 50, 100, 100, 200, 500, 1000, 5000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50:?} {p95:?} {p99:?}");
        assert!(h.mean() >= Duration::from_micros(100));
        assert!(h.max() >= Duration::from_micros(100_000));
        // p50 of this set is 100µs; bucket resolution allows ≤ 2x error.
        assert!(p50 >= Duration::from_micros(50) && p50 <= Duration::from_micros(300), "{p50:?}");
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn bucket_mapping_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 3, 5, 8, 16, 100, 1_000, 10_000, 1_000_000] {
            let b = Histogram::bucket_of(us * 1_000);
            assert!(b >= last, "bucket({us}µs)={b} < {last}");
            last = b;
        }
    }

    #[test]
    fn snapshot_json_shape() {
        let reg = Registry::default();
        reg.counter("a").inc();
        reg.histogram("lat").record(Duration::from_micros(42));
        let snap = reg.snapshot_json();
        assert_eq!(snap.get("counters").unwrap().get("a").unwrap().as_f64(), Some(1.0));
        assert!(snap.get("histograms").unwrap().get("lat").unwrap().get("p50_us").is_some());
    }

    #[test]
    fn timed_records() {
        let h = Histogram::new();
        let out = timed(&h, || 7);
        assert_eq!(out, 7);
        assert_eq!(h.count(), 1);
    }
}
