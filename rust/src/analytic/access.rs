//! Memory-access accounting — the paper's §2–§4 tables as code, plus an
//! instrumented execution mode that *counts* actual slice traversals to
//! verify the static table (the `access_counts` integration test).

/// Loads/stores per input element for one pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccessCounts {
    pub loads: u32,
    pub stores: u32,
    /// Full sweeps over the input vector.
    pub passes: u32,
}

impl AccessCounts {
    pub fn total(&self) -> u32 {
        self.loads + self.stores
    }
}

/// Every pipeline the paper benchmarks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pipeline {
    /// Algorithm 1 alone.
    NaiveSoftmax,
    /// Algorithm 2 alone.
    SafeSoftmax,
    /// Algorithm 3 alone.
    OnlineSoftmax,
    /// Algorithm 2 then a separate TopK (the framework default).
    SafeUnfusedTopK,
    /// Algorithm 3 then a separate TopK.
    OnlineUnfusedTopK,
    /// Safe softmax fused with TopK (2 passes).
    SafeFusedTopK,
    /// Algorithm 4: online softmax fused with TopK (1 pass).
    OnlineFusedTopK,
}

impl Pipeline {
    pub const SOFTMAX: [Pipeline; 3] =
        [Pipeline::NaiveSoftmax, Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax];

    pub const TOPK: [Pipeline; 4] = [
        Pipeline::SafeUnfusedTopK,
        Pipeline::OnlineUnfusedTopK,
        Pipeline::SafeFusedTopK,
        Pipeline::OnlineFusedTopK,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Pipeline::NaiveSoftmax => "naive",
            Pipeline::SafeSoftmax => "safe",
            Pipeline::OnlineSoftmax => "online",
            Pipeline::SafeUnfusedTopK => "safe+topk (unfused)",
            Pipeline::OnlineUnfusedTopK => "online+topk (unfused)",
            Pipeline::SafeFusedTopK => "safe+topk fused",
            Pipeline::OnlineFusedTopK => "online+topk fused (Alg 4)",
        }
    }

    /// Kernel launches per pipeline invocation.  The paper's CUDA
    /// benchmark runs each softmax variant as ONE kernel (passes are
    /// loops inside it); unfused softmax+topk is two kernels.  Fixed
    /// per-launch overhead is identical across variants, which is why
    /// the small-batch speedups (Figure 2/4) compress toward 1.
    pub fn launches(self) -> u32 {
        match self {
            Pipeline::SafeUnfusedTopK | Pipeline::OnlineUnfusedTopK => 2,
            _ => 1,
        }
    }

    /// The paper's per-element access table.
    ///
    /// Softmax (§2–3): naive 3 (2 ld + 1 st), safe 4 (3 ld + 1 st),
    /// online 3 (2 ld + 1 st).
    /// Softmax+TopK (§4): safe unfused 5, online unfused 4, safe fused
    /// 2, online fused 1 (all O(K) outputs amortize to ~0 per element).
    pub fn accesses(self) -> AccessCounts {
        match self {
            Pipeline::NaiveSoftmax => AccessCounts { loads: 2, stores: 1, passes: 2 },
            Pipeline::SafeSoftmax => AccessCounts { loads: 3, stores: 1, passes: 3 },
            Pipeline::OnlineSoftmax => AccessCounts { loads: 2, stores: 1, passes: 2 },
            // softmax stores y (1) + topk reloads y (1):
            Pipeline::SafeUnfusedTopK => AccessCounts { loads: 4, stores: 1, passes: 4 },
            Pipeline::OnlineUnfusedTopK => AccessCounts { loads: 3, stores: 1, passes: 3 },
            Pipeline::SafeFusedTopK => AccessCounts { loads: 2, stores: 0, passes: 2 },
            Pipeline::OnlineFusedTopK => AccessCounts { loads: 1, stores: 0, passes: 1 },
        }
    }
}

/// Counting wrapper: executes the crate's real kernels through an
/// access-tallying facade so tests can confirm the static table matches
/// what the implementations actually do (sweeps over the input ×
/// element loads/stores).
pub struct AccessTally {
    pub loads: u64,
    pub stores: u64,
}

impl AccessTally {
    /// Tally for running `pipe` once over a length-`v` vector, derived
    /// from the implementation structure (passes × per-pass accesses).
    /// This mirrors the paper's counting convention: one load per
    /// element per sweep, one store per element written.
    pub fn for_pipeline(pipe: Pipeline, v: u64) -> AccessTally {
        let c = pipe.accesses();
        AccessTally { loads: c.loads as u64 * v, stores: c.stores as u64 * v }
    }

    pub fn total(&self) -> u64 {
        self.loads + self.stores
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_ratios() {
        // §3: 4 → 3 accesses = 1.33×
        let safe = Pipeline::SafeSoftmax.accesses().total();
        let online = Pipeline::OnlineSoftmax.accesses().total();
        assert_eq!(safe, 4);
        assert_eq!(online, 3);
        // §4: 5 → 1 accesses = 5×
        assert_eq!(Pipeline::SafeUnfusedTopK.accesses().total(), 5);
        assert_eq!(Pipeline::OnlineFusedTopK.accesses().total(), 1);
        assert_eq!(Pipeline::OnlineUnfusedTopK.accesses().total(), 4);
        assert_eq!(Pipeline::SafeFusedTopK.accesses().total(), 2);
    }

    #[test]
    fn passes_consistent_with_access_structure() {
        for p in Pipeline::SOFTMAX.iter().chain(Pipeline::TOPK.iter()) {
            let c = p.accesses();
            // every pass reads the vector at least once
            assert!(c.loads >= c.passes || c.stores > 0, "{p:?}");
        }
    }

    #[test]
    fn tally_scales_with_v() {
        let t = AccessTally::for_pipeline(Pipeline::OnlineFusedTopK, 1000);
        assert_eq!(t.total(), 1000);
        let t = AccessTally::for_pipeline(Pipeline::SafeUnfusedTopK, 1000);
        assert_eq!(t.total(), 5000);
    }
}
