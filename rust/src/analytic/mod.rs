//! Analytic performance model — the paper's memory-access arithmetic
//! turned into a predictive device model.
//!
//! The paper's whole argument is: softmax is bandwidth-bound, so runtime
//! ≈ (memory accesses) / (bandwidth), and the access ratio between
//! algorithms bounds the speedup (4/3 ≈ 1.33× for softmax, 5/1 = 5× for
//! fused softmax+topk).  [`DeviceModel::predict`] implements
//!
//! ```text
//! time(V, B) = passes · t_pass + bytes_touched / effective_bw(working_set)
//! ```
//!
//! with a cache-aware bandwidth step (L2-resident vs DRAM) and a
//! per-pass fixed latency, which is enough to regenerate the *shape* of
//! Figures 1–4: flat ratios below the cache cliff, the paper's speedup
//! plateaus past it, and the depressed small-batch ratios (fixed
//! latencies dominate when B·V is small).  `onlinesoftmax model` prints
//! these predictions next to the paper's reported numbers.

pub mod access;

pub use access::{AccessCounts, Pipeline};

/// A bandwidth/latency device description.
#[derive(Clone, Debug)]
pub struct DeviceModel {
    pub name: String,
    /// Sustained DRAM bandwidth, bytes/sec.
    pub dram_bw: f64,
    /// Last-level-cache bandwidth, bytes/sec (≥ dram_bw).
    pub cache_bw: f64,
    /// Last-level-cache capacity, bytes.
    pub cache_bytes: f64,
    /// Fixed cost per kernel launch (identical for all variants), seconds.
    pub launch_latency: f64,
    /// Cost per in-kernel pass restart (pipeline drain/refill), seconds.
    pub pass_overhead: f64,
    /// Minimum concurrency (vectors in flight) to reach full bandwidth;
    /// below this the device is latency-limited (the paper's batch=10).
    pub saturation_vectors: f64,
}

impl DeviceModel {
    /// NVIDIA Tesla V100 PCIe 16 GB — the paper's testbed (§5).
    pub fn v100() -> DeviceModel {
        DeviceModel {
            name: "Tesla V100 PCIe".into(),
            dram_bw: 900e9,
            cache_bw: 2_500e9,
            cache_bytes: 6e6, // 6 MB L2
            launch_latency: 4e-6,
            pass_overhead: 3e-7,
            saturation_vectors: 160.0, // ~80 SMs × 2 blocks
        }
    }

    /// A generic server CPU (used when no measurement is supplied).
    pub fn generic_cpu() -> DeviceModel {
        DeviceModel {
            name: "generic CPU".into(),
            dram_bw: 20e9,
            cache_bw: 200e9,
            cache_bytes: 32e6,
            launch_latency: 2e-7,
            pass_overhead: 5e-8,
            saturation_vectors: 1.0,
        }
    }

    /// Calibrate a CPU model from a quick in-process bandwidth probe.
    pub fn measured_cpu() -> DeviceModel {
        let mut m = Self::generic_cpu();
        m.name = "measured CPU".into();
        m.dram_bw = measure_stream_bandwidth(64 << 20);
        m.cache_bw = measure_stream_bandwidth(1 << 20).max(m.dram_bw);
        m
    }

    /// Effective bandwidth for a given working-set size (smooth step
    /// between cache and DRAM regimes).
    pub fn effective_bw(&self, working_set: f64) -> f64 {
        if working_set <= self.cache_bytes {
            self.cache_bw
        } else {
            // fraction of traffic still served by cache
            let frac = self.cache_bytes / working_set;
            1.0 / (frac / self.cache_bw + (1.0 - frac) / self.dram_bw)
        }
    }

    /// Predicted runtime for a pipeline over `batch` vectors of length `v`
    /// (fp32).
    pub fn predict(&self, pipe: Pipeline, v: usize, batch: usize) -> f64 {
        let counts = pipe.accesses();
        let elems = (v * batch) as f64;
        let bytes = counts.total() as f64 * elems * 4.0;
        let working_set = (v * batch) as f64 * 4.0;
        // Latency-limited derating: with fewer than saturation_vectors
        // in flight, only a fraction of peak bandwidth is reachable.
        let occupancy = (batch as f64 / self.saturation_vectors).min(1.0);
        // Even a single vector gets some fraction of the machine (not
        // proportionally zero): floor at 6% of peak, roughly matching
        // the paper's batch=10 absolute numbers on V100.
        let occupancy = occupancy.max(0.06);
        let bw = self.effective_bw(working_set) * occupancy;
        pipe.launches() as f64 * self.launch_latency
            + counts.passes as f64 * self.pass_overhead
            + bytes / bw
    }

    /// Speedup of `b` over `a` (ratio of predicted times).
    pub fn speedup(&self, a: Pipeline, b: Pipeline, v: usize, batch: usize) -> f64 {
        self.predict(a, v, batch) / self.predict(b, v, batch)
    }
}

/// Crude in-process STREAM-like read bandwidth probe.
pub fn measure_stream_bandwidth(bytes: usize) -> f64 {
    let n = bytes / 4;
    let data = vec![1.0f32; n];
    // warm
    let mut acc = 0.0f32;
    for &x in &data {
        acc += x;
    }
    let t0 = std::time::Instant::now();
    let reps = 3;
    for _ in 0..reps {
        let mut s = 0.0f32;
        for chunk in data.chunks_exact(16) {
            // unrolled sum to keep the loop bandwidth-bound
            s += chunk.iter().sum::<f32>();
        }
        acc += s;
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    (bytes as f64 * reps as f64) / dt
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_softmax_ratio_approaches_4_over_3() {
        let dev = DeviceModel::v100();
        // Large V, large batch: bandwidth-bound regime.
        let s = dev.speedup(Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax, 100_000, 4000);
        assert!((s - 4.0 / 3.0).abs() < 0.05, "speedup {s}");
    }

    #[test]
    fn v100_fused_ratio_approaches_5() {
        let dev = DeviceModel::v100();
        let s = dev.speedup(Pipeline::SafeUnfusedTopK, Pipeline::OnlineFusedTopK, 25_000, 4000);
        assert!(s > 4.0 && s < 5.2, "speedup {s}");
    }

    #[test]
    fn small_batch_is_latency_depressed() {
        let dev = DeviceModel::v100();
        let large = dev.speedup(Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax, 10_000, 4000);
        let small = dev.speedup(Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax, 10_000, 10);
        assert!(small <= large + 1e-9, "small-batch ratio must not exceed large-batch");
    }

    #[test]
    fn cache_resident_vectors_show_no_gain() {
        let dev = DeviceModel::v100();
        // tiny working set: both algorithms run at cache speed, ratio
        // dominated by pass latency → close to 1
        let s = dev.speedup(Pipeline::SafeSoftmax, Pipeline::OnlineSoftmax, 100, 10);
        assert!(s < 1.2, "no meaningful gain in cache/latency regime: {s}");
    }

    #[test]
    fn effective_bw_monotone_decreasing() {
        let dev = DeviceModel::v100();
        let a = dev.effective_bw(1e6);
        let b = dev.effective_bw(1e7);
        let c = dev.effective_bw(1e9);
        assert!(a >= b && b >= c);
        assert!(c >= dev.dram_bw * 0.9);
    }

    #[test]
    fn predict_scales_linearly_in_bandwidth_regime() {
        let dev = DeviceModel::v100();
        let t1 = dev.predict(Pipeline::OnlineSoftmax, 50_000, 4000);
        let t2 = dev.predict(Pipeline::OnlineSoftmax, 100_000, 4000);
        let ratio = t2 / t1;
        assert!((ratio - 2.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn stream_probe_returns_plausible_bandwidth() {
        let bw = measure_stream_bandwidth(8 << 20);
        assert!(bw > 1e8, "at least 100 MB/s: {bw}");
        assert!(bw < 1e13, "below 10 TB/s: {bw}");
    }
}
